"""Elastic rescale: rolling-update semantics for sharded training state.

The paper's RollingUpdate (maxSurge/maxUnavailable) moves stateless pods one
at a time. For training, "moving a pod" means re-laying-out the sharded
TrainState onto a different mesh. The primitive here:

    plan  = RescalePlan(state_axes, old_mesh, new_mesh)
    state = plan.apply(state)        # in-memory reshard (device_put)
or through a checkpoint boundary (node count actually changed):
    ckpt.save(step, state); state = ckpt.restore(like, shardings=plan.new_shardings)

``rolling_phases`` yields the paper-faithful phase sequence (cordon/drain ≤
maxUnavailable slices -> reshard -> resume) that the trainer logs as events.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Iterator

import jax
from jax.sharding import Mesh

from repro.parallel import make_shardings


@dataclass
class RescalePlan:
    state_axes: Any
    new_mesh: Mesh
    rules: dict | None = None

    def new_shardings(self, state_shapes: Any = None):
        return make_shardings(
            self.state_axes, self.new_mesh, rules=self.rules, shapes_tree=state_shapes
        )

    def apply(self, state: Any) -> Any:
        shardings = self.new_shardings(state)
        return jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings
        )


def rolling_phases(
    old_slices: int, new_slices: int, max_unavailable: int = 1
) -> Iterator[dict]:
    """Phase records for a rolling data-parallel rescale old->new."""
    yield {"phase": "checkpoint_barrier", "old": old_slices, "new": new_slices}
    moved = 0
    delta = abs(new_slices - old_slices)
    while moved < delta:
        batch = min(max_unavailable, delta - moved)
        yield {
            "phase": "drain" if new_slices < old_slices else "surge",
            "slices": batch,
            "progress": f"{moved + batch}/{delta}",
        }
        moved += batch
    yield {"phase": "reshard", "target_slices": new_slices}
    yield {"phase": "resume", "slices": new_slices}
