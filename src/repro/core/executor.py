"""WorkerPod: the runtime stand-in for a Kubernetes pod running one sealed step.

A pod is a host thread (one per attempt) executing ``StepImage.step`` with:
  * a ``PodContext`` handle — heartbeats, kill-switch (fault injection /
    speculative-loser cancellation), store/bus access, attempt metadata;
  * outputs published to the ArtifactStore, completion records to the bus.

Step functions may accept (inputs) or (inputs, ctx); long-running steps use
ctx to heartbeat, checkpoint and die cooperatively (the SIGKILL analogue —
an uncatchable-by-design ``PodKilled`` raised at the next progress point).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from inspect import signature
from typing import Any

from repro.core.bus import TopicBus
from repro.core.events import EventLog
from repro.core.probes import HeartbeatWriter
from repro.core.storage import ArtifactStore


class PodKilled(BaseException):
    """Simulated pod death (chaos injection or cancellation)."""


@dataclass
class KillSwitch:
    _event: threading.Event = field(default_factory=threading.Event)
    reason: str = ""

    def kill(self, reason: str = "killed"):
        self.reason = reason
        self._event.set()

    def is_set(self) -> bool:
        return self._event.is_set()


@dataclass
class PodContext:
    pod_name: str
    step_name: str
    attempt: int
    bus: TopicBus
    store: ArtifactStore
    kill: KillSwitch
    heartbeat: HeartbeatWriter
    claim_path: str = ""

    def beat(self, progress: int = 0, **info):
        if self.kill.is_set():
            raise PodKilled(self.kill.reason)
        self.heartbeat.beat(progress=progress, **info)

    def check(self):
        if self.kill.is_set():
            raise PodKilled(self.kill.reason)


class WorkerPod(threading.Thread):
    def __init__(
        self,
        pod_name: str,
        image,                      # StepImage
        inputs: dict,
        bus: TopicBus,
        store: ArtifactStore,
        events: EventLog,
        attempt: int,
        claim_path: str = "",
    ):
        super().__init__(daemon=True, name=pod_name)
        self.pod_name = pod_name
        self.image = image
        self.inputs = inputs
        self.attempt = attempt
        self.kill_switch = KillSwitch()
        self.events = events
        self.ctx = PodContext(
            pod_name=pod_name,
            step_name=image.step.name,
            attempt=attempt,
            bus=bus,
            store=store,
            kill=self.kill_switch,
            heartbeat=HeartbeatWriter(bus, pod_name),
            claim_path=claim_path,
        )
        self.outputs: dict | None = None
        self.error: BaseException | None = None
        self.started_ts: float = 0.0
        self.finished_ts: float = 0.0

    # ------------------------------------------------------------------
    def run(self):
        self.started_ts = time.time()
        step = self.image.step
        try:
            self.ctx.heartbeat.ready()
            self.ctx.check()
            fn = step.fn
            if fn is not None and len(signature(fn).parameters) >= 2:
                out = fn(self.inputs, self.ctx)
            else:
                out = step.run(self.inputs)
            missing = step.writes - set(out)
            if missing:
                raise ValueError(f"step {step.name} missing outputs {missing}")
            self.ctx.check()
            self.outputs = out
        except PodKilled as e:
            self.error = e
        except BaseException as e:  # noqa: BLE001 — pod crash, report upward
            self.error = e
            self.events.error(step.name, self.attempt, e)
        finally:
            self.finished_ts = time.time()

    @property
    def state(self) -> str:
        if not self.started_ts:
            return "pending"
        if self.is_alive():
            return "running"
        if self.outputs is not None:
            return "succeeded"
        return "failed"
