"""ServiceRegistry: cluster-IP service discovery (paper §3.4), on the bus.

``get-cluster-ip()``/``communicate-with-service()`` from the paper map to
``resolve()``/liveness-gated lookups: services register an endpoint record
on the ``services`` topic; resolution replays the topic and returns the
latest record whose owner still heartbeats.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.bus import TopicBus

TOPIC = "services"


@dataclass(frozen=True)
class Endpoint:
    service: str
    namespace: str
    address: str  # e.g. "pod://train-0" or "10.0.0.12:8080" on a real cluster
    pod: str
    ts: float


class ServiceRegistry:
    def __init__(self, bus: TopicBus, liveness_window_s: float = 30.0):
        self.bus = bus
        self.window = liveness_window_s

    def register(self, service: str, address: str, pod: str, namespace: str = "default"):
        self.bus.publish(
            TOPIC,
            {"service": service, "namespace": namespace, "address": address, "pod": pod},
            key=f"{namespace}/{service}",
        )

    def deregister(self, service: str, namespace: str = "default"):
        self.bus.publish(TOPIC, {"service": service, "namespace": namespace,
                                 "address": None, "pod": None},
                         key=f"{namespace}/{service}")

    def resolve(self, service: str, namespace: str = "default",
                heartbeats: dict[str, float] | None = None) -> Endpoint | None:
        """Latest live endpoint (the get-cluster-ip analogue)."""
        latest: Endpoint | None = None
        for m in self.bus.read(TOPIC):
            v = m.value
            if v.get("service") == service and v.get("namespace") == namespace:
                if v.get("address") is None:
                    latest = None
                else:
                    latest = Endpoint(service, namespace, v["address"], v["pod"], m.ts)
        if latest and heartbeats is not None:
            hb = heartbeats.get(latest.pod, 0.0)
            if time.time() - hb > self.window:
                return None
        return latest
