"""Structured event log (paper §3.5 'error handling and logging') on the bus."""

from __future__ import annotations

import traceback
from typing import Any

from repro.core.bus import TopicBus

TOPIC = "workflow.events"


class EventLog:
    def __init__(self, bus: TopicBus, workflow: str = "wf"):
        self.bus = bus
        self.workflow = workflow

    def emit(self, kind: str, step: str = "", attempt: int = -1, **fields: Any) -> int:
        rec = {"workflow": self.workflow, "kind": kind, "step": step,
               "attempt": attempt, **fields}
        return self.bus.publish(TOPIC, rec, key=f"{step}:{attempt}")

    def error(self, step: str, attempt: int, exc: BaseException):
        self.emit(
            "step_error", step, attempt,
            error=repr(exc),
            trace="".join(traceback.format_exception(exc))[-2000:],
        )

    def history(self, kind: str | None = None) -> list[dict]:
        out = []
        for m in self.bus.read(TOPIC):
            if m.value.get("workflow") != self.workflow:
                continue
            if kind is None or m.value.get("kind") == kind:
                out.append(m.value)
        return out
