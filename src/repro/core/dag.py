"""Step graph: the workflow DAG that the splitter produces and the scheduler runs.

A ``Step`` is an executable unit (one or more fused notebook cells, or a
programmatic step like "train"). Edges carry the *pipe artifacts* — the
variable names that flow between steps (stored in the ArtifactStore at run
time, referenced by content hash on the bus).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterable

from repro.core.notebook import Cell


@dataclass
class Step:
    name: str
    cells: list[Cell] = field(default_factory=list)
    fn: Callable[[dict], dict] | None = None
    reads: set[str] = field(default_factory=set)
    writes: set[str] = field(default_factory=set)
    # deployment knobs (paper §3.2/§3.5) — consumed by PodSpec/Scheduler
    replicas: int = 1
    max_attempts: int = 3
    resources: dict = field(default_factory=dict)
    long_running: bool = False  # train-style step: checkpointed, resumable

    def run(self, inputs: dict) -> dict:
        env = dict(inputs)
        if self.fn is not None:
            out = self.fn(inputs)
            assert set(out) >= self.writes, (self.name, set(out), self.writes)
            return {k: out[k] for k in self.writes}
        for c in self.cells:
            c.run(env)
        return {k: env[k] for k in self.writes if k in env}


@dataclass
class StepGraph:
    steps: dict[str, Step]
    edges: dict[tuple[str, str], set[str]]  # (src, dst) -> pipe artifact names
    external_inputs: set[str] = field(default_factory=set)

    # ------------------------------------------------------------------
    def deps(self, name: str) -> set[str]:
        return {s for (s, d) in self.edges if d == name}

    def consumers(self, name: str) -> set[str]:
        return {d for (s, d) in self.edges if s == name}

    def topological(self) -> list[str]:
        order, seen, temp = [], set(), set()

        def visit(n: str):
            if n in seen:
                return
            if n in temp:
                raise ValueError(f"cycle involving step {n!r}")
            temp.add(n)
            for d in sorted(self.deps(n)):
                visit(d)
            temp.discard(n)
            seen.add(n)
            order.append(n)

        for n in sorted(self.steps):
            visit(n)
        return order

    def validate(self):
        self.topological()  # raises on cycles
        for (s, d), names in self.edges.items():
            assert s in self.steps and d in self.steps, (s, d)
            assert names <= self.steps[s].writes, (
                f"edge {s}->{d} carries {names - self.steps[s].writes} "
                f"not written by {s}"
            )
        return self

    def to_dot(self) -> str:
        lines = ["digraph workflow {"]
        for n in self.steps:
            lines.append(f'  "{n}";')
        for (s, d), names in sorted(self.edges.items()):
            label = ",".join(sorted(names))
            lines.append(f'  "{s}" -> "{d}" [label="{label}"];')
        lines.append("}")
        return "\n".join(lines)


def build_cell_dag(cells: Iterable[Cell]) -> list[tuple[int, int, set[str]]]:
    """Cell-level dataflow edges (i -> j means j reads something i last wrote)."""
    cells = list(cells)
    last_writer: dict[str, int] = {}
    edges: list[tuple[int, int, set[str]]] = []
    for j, c in enumerate(cells):
        by_src: dict[int, set[str]] = {}
        for name in c.reads:
            if name in last_writer:
                by_src.setdefault(last_writer[name], set()).add(name)
        for i, names in sorted(by_src.items()):
            edges.append((i, j, names))
        for name in c.writes:
            last_writer[name] = j
    return edges
