"""WorkflowScheduler: runs a StepGraph with the paper-§3.5 FT stack.

Per-step guarantees:
  * k speculative replicas (ReplicaSet analogue) — first success wins,
    losers are cancelled; long-running (checkpointed) steps force k=1 and
    get restart-based FT instead (DESIGN.md, changed-assumption #2);
  * retries with exponential backoff up to ``RetryPolicy.max_attempts``;
  * liveness: a running attempt whose heartbeats stop for longer than the
    window is declared dead and rescheduled (probe analogue);
  * at-least-once + idempotent completion: results are recorded once per
    step under an idempotency key; duplicate successes are dropped;
  * inter-step pipes go through the ArtifactStore (refs), events/heartbeats
    through the TopicBus — the paper's Kafka/PV split.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.bus import TopicBus
from repro.core.capsule import StepImage, seal_step
from repro.core.dag import StepGraph
from repro.core.events import EventLog
from repro.core.executor import WorkerPod
from repro.core.probes import HealthMonitor
from repro.core.storage import ArtifactStore


@dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0

    def delay(self, attempt: int) -> float:
        return self.backoff_s * (self.backoff_mult ** max(attempt - 1, 0))


@dataclass
class _StepState:
    image: StepImage
    attempts_used: int = 0
    pods: list[WorkerPod] = field(default_factory=list)
    done: bool = False
    outputs: dict | None = None
    next_launch_ts: float = 0.0


class WorkflowScheduler:
    def __init__(
        self,
        graph: StepGraph,
        bus: TopicBus,
        store: ArtifactStore,
        *,
        workflow: str = "wf",
        retry: RetryPolicy = RetryPolicy(),
        liveness_window_s: float = 10.0,
        fault_injector=None,
        claim_paths: dict[str, str] | None = None,
        poll_interval_s: float = 0.02,
        hedge_after_s: float | None = None,
    ):
        """``hedge_after_s``: straggler mitigation — if a (non-long-running)
        step's only attempt has been running this long, launch ONE hedged
        speculative attempt; first success wins (tail-latency hedging)."""
        self.graph = graph
        self.bus = bus
        self.store = store
        self.retry = retry
        self.events = EventLog(bus, workflow)
        self.monitor = HealthMonitor(bus, liveness_window_s)
        self.faults = fault_injector
        self.claim_paths = claim_paths or {}
        self.poll = poll_interval_s
        self.hedge_after_s = hedge_after_s
        self._state: dict[str, _StepState] = {}

    # ------------------------------------------------------------------
    def _replicas_for(self, step) -> int:
        if step.long_running:
            return 1  # restart-based FT; see DESIGN.md changed-assumption #2
        return max(1, step.replicas)

    def _launch_one(self, name: str, inputs: dict, replica: int = 0):
        st = self._state[name]
        st.attempts_used += 1
        attempt = st.attempts_used
        pod = WorkerPod(
            pod_name=f"{name}-a{attempt}",
            image=st.image,
            inputs=inputs,
            bus=self.bus,
            store=self.store,
            events=self.events,
            attempt=attempt,
            claim_path=self.claim_paths.get(name, ""),
        )
        st.pods.append(pod)
        self.events.emit("pod_start", name, attempt, replica=replica)
        pod.start()
        if self.faults is not None:
            self.faults.on_pod_start(pod)

    def _launch(self, name: str, inputs: dict):
        step = self._state[name].image.step
        for r in range(self._replicas_for(step)):
            self._launch_one(name, inputs, replica=r)

    def _inputs_for(self, name: str, artifacts: dict) -> dict:
        step = self.graph.steps[name]
        missing = {r for r in step.reads if r not in artifacts}
        if missing:
            raise KeyError(f"step {name} missing inputs {missing}")
        return {r: artifacts[r] for r in step.reads}

    # ------------------------------------------------------------------
    def run(self, external_inputs: dict | None = None, timeout_s: float = 120.0) -> dict:
        artifacts: dict = dict(external_inputs or {})
        for name, step in self.graph.steps.items():
            self._state[name] = _StepState(image=seal_step(step))
        order = self.graph.topological()
        self.events.emit("workflow_start", fields_steps=order)
        deadline = time.time() + timeout_s

        while True:
            progressed = False
            now = time.time()
            for name in order:
                st = self._state[name]
                if st.done:
                    continue
                deps = self.graph.deps(name)
                if not all(self._state[d].done for d in deps):
                    continue

                # snapshot each pod's state ONCE per iteration: state is a
                # live property, and a pod finishing between two reads must
                # not be miscounted (a fast hedge dying between the winner
                # check and the running-pod count triggered a spurious
                # second hedge)
                states = [(p, p.state) for p in st.pods]

                # 1) harvest — first success wins (idempotent record)
                winner = next((p for p, s in states if s == "succeeded"), None)
                if winner is not None:
                    for p in st.pods:
                        if p is not winner and p.is_alive():
                            p.kill_switch.kill("superseded_by_replica")
                    st.done = True
                    st.outputs = winner.outputs
                    refs = {}
                    for k, v in winner.outputs.items():
                        try:
                            refs[k] = self.store.put(v, name=f"{name}.{k}")
                        except (TypeError, AttributeError, ValueError):
                            # modules / live handles: in-process pipe only
                            refs[k] = f"inline://{name}.{k}"
                    artifacts.update(winner.outputs)
                    self.events.emit(
                        "step_done", name, winner.attempt,
                        pod=winner.pod_name, refs=refs,
                        wall_s=round(winner.finished_ts - winner.started_ts, 4),
                    )
                    progressed = True
                    continue

                # 2) liveness: kill zombie attempts whose heartbeats stopped
                for p, s in states:
                    if s == "running" and self.monitor.status(p.pod_name) == "dead":
                        p.kill_switch.kill("liveness_probe_failed")
                        self.events.emit("pod_liveness_kill", name, p.attempt)

                running_pods = [p for p, s in states if s in ("running", "pending")]
                if running_pods:
                    # straggler hedging: one extra speculative attempt
                    if (
                        self.hedge_after_s is not None
                        and not st.image.step.long_running
                        and len(running_pods) == 1
                        and st.attempts_used
                        < self.retry.max_attempts * self._replicas_for(st.image.step)
                        and running_pods[0].started_ts
                        and now - running_pods[0].started_ts > self.hedge_after_s
                    ):
                        self.events.emit("pod_hedged", name, st.attempts_used + 1)
                        self._launch_one(name, self._inputs_for(name, artifacts))
                        progressed = True
                    continue  # still working

                # 3) nothing running, no winner -> (re)launch after backoff
                if st.pods and now < st.next_launch_ts:
                    continue
                if st.attempts_used >= self.retry.max_attempts * self._replicas_for(st.image.step):
                    raise RuntimeError(
                        f"step {name} failed after {st.attempts_used} attempts; "
                        f"events={self.events.history('step_error')[-3:]}"
                    )
                if st.pods:
                    self.events.emit(
                        "step_retry_scheduled", name, st.attempts_used,
                        delay_s=self.retry.delay(st.attempts_used),
                    )
                self._launch(name, self._inputs_for(name, artifacts))
                st.next_launch_ts = now + self.retry.delay(st.attempts_used)
                progressed = True

            if all(s.done for s in self._state.values()):
                self.events.emit("workflow_done")
                return artifacts
            if time.time() > deadline:
                states = {n: [p.state for p in s.pods] for n, s in self._state.items()}
                raise TimeoutError(f"workflow timed out; pod states: {states}")
            if not progressed:
                time.sleep(self.poll)
