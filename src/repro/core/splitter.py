"""Piped-section splitting (paper §3.1 — the "algorithms" in the title).

The paper splits a notebook "based on its piped sections" (via nbmanips).
We make the algorithm precise:

  1. extract per-cell read/write sets (AST; ``notebook.py``),
  2. build the cell-level dataflow DAG,
  3. contract *linear private chains*: cell j is merged into the group of
     cell i when i is j's unique producer group, j is the only consumer of
     everything that group exports, and no group boundary was forced —
     i.e. the pipe between them is private, so shipping it through the
     broker would be pure overhead,
  4. a ``# %%pipe`` tag (or any group fan-out/fan-in) forces a boundary,
  5. each group becomes a Step; group-crossing dataflow becomes the pipe
     artifacts on the edges.

This maximizes parallelism (fan-out cells end up in distinct pods) while
never paying broker+storage latency for dataflow that no other step needs.
"""

from __future__ import annotations

from repro.core.dag import Step, StepGraph, build_cell_dag
from repro.core.notebook import Cell, Notebook


def split_pipeline(nb: Notebook) -> StepGraph:
    cells = nb.cells
    n = len(cells)
    edges = build_cell_dag(cells)
    consumers: dict[int, set[int]] = {i: set() for i in range(n)}
    producers: dict[int, set[int]] = {i: set() for i in range(n)}
    for i, j, _names in edges:
        consumers[i].add(j)
        producers[j].add(i)

    # --- group assignment (union-find over the chain-contraction rule) ---
    group = list(range(n))

    def find(i: int) -> int:
        while group[i] != i:
            group[i] = group[group[i]]
            i = group[i]
        return i

    for j in range(n):
        if "pipe" in cells[j].tags:
            continue  # forced boundary: j starts its own group
        prods = {find(i) for i in producers[j]}
        if len(prods) != 1:
            continue  # fan-in (or source cell): boundary
        g = prods.pop()
        # j must be the ONLY consumer of group g's members
        g_members = [m for m in range(n) if find(m) == g]
        outside = {
            c for m in g_members for c in consumers[m] if find(c) not in (g, find(j))
        }
        if outside:
            continue  # group g fans out elsewhere: boundary
        group[j] = g

    # --- build steps ---
    by_group: dict[int, list[int]] = {}
    for i in range(n):
        by_group.setdefault(find(i), []).append(i)

    def step_name(members: list[int]) -> str:
        first = cells[members[0]]
        return first.name or f"step{members[0]}"

    steps: dict[str, Step] = {}
    gname: dict[int, str] = {}
    for g, members in sorted(by_group.items()):
        members.sort()
        name = step_name(members)
        reads: set[str] = set()
        writes: set[str] = set()
        internal_writes: set[str] = set()
        for m in members:
            reads |= cells[m].reads - internal_writes
            internal_writes |= cells[m].writes
        # exports = names written here and read by other groups (or final)
        writes = set(internal_writes)
        steps[name] = Step(name=name, cells=[cells[m] for m in members],
                           reads=reads, writes=writes)
        gname[g] = name

    # --- group-crossing edges ---
    gedges: dict[tuple[str, str], set[str]] = {}
    for i, j, names in edges:
        gi, gj = find(i), find(j)
        if gi == gj:
            continue
        key = (gname[gi], gname[gj])
        gedges.setdefault(key, set()).update(names)

    # NOTE: steps export all their writes. Only the names on EDGES travel as
    # pipes between pods; the rest are recorded as (possibly final) workflow
    # outputs — statically we cannot tell a junk intermediate from a result
    # the scientist wants, so we keep them (storage is content-addressed and
    # dedup'd, the cost is negligible).

    ext = set().union(*[c.reads for c in cells] or [set()])
    produced = set().union(*[c.writes for c in cells] or [set()])
    graph = StepGraph(steps=steps, edges=gedges, external_inputs=ext - produced)
    return graph.validate()
