"""Liveness/readiness probes (paper §3.5), bus-record edition.

A pod's step loop publishes heartbeats on the ``health`` topic. The monitor
declares a pod:
  * not READY  — no heartbeat yet (still initializing / compiling),
  * LIVE       — last heartbeat within ``liveness_window``,
  * DEAD       — window exceeded -> the scheduler restarts it from the last
                 checkpoint.

Stronger than the paper's HTTP probes: a heartbeat is only written when the
step makes *forward progress* (e.g. every k train steps), so a livelocked
pod is detected too, not just a crashed one.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.bus import TopicBus

TOPIC = "health"


@dataclass
class PodHealth:
    pod: str
    last_ts: float
    last_progress: int
    ready: bool

    def state(self, now: float, window: float) -> str:
        if not self.ready:
            return "not_ready"
        return "live" if (now - self.last_ts) <= window else "dead"


class HeartbeatWriter:
    def __init__(self, bus: TopicBus, pod: str):
        self.bus, self.pod = bus, pod

    def ready(self):
        self.bus.publish(TOPIC, {"pod": self.pod, "kind": "ready"}, key=self.pod)

    def beat(self, progress: int = 0, **info):
        self.bus.publish(
            TOPIC,
            {"pod": self.pod, "kind": "beat", "progress": progress, **info},
            key=self.pod,
        )


class HealthMonitor:
    def __init__(self, bus: TopicBus, liveness_window_s: float = 10.0):
        self.bus = bus
        self.window = liveness_window_s
        self._state: dict[str, PodHealth] = {}
        self._cursor = 0

    def refresh(self):
        msgs = self.bus.read(TOPIC, start=self._cursor)
        for m in msgs:
            self._cursor = m.offset + 1
            v = m.value
            pod = v["pod"]
            h = self._state.get(pod) or PodHealth(pod, 0.0, 0, False)
            if v["kind"] == "ready":
                h.ready = True
            h.last_ts = m.ts
            h.last_progress = v.get("progress", h.last_progress)
            self._state[pod] = h

    def status(self, pod: str) -> str:
        self.refresh()
        h = self._state.get(pod)
        if h is None:
            return "unknown"
        return h.state(time.time(), self.window)

    def dead_pods(self) -> list[str]:
        self.refresh()
        now = time.time()
        return [p for p, h in self._state.items() if h.state(now, self.window) == "dead"]

    def progress(self, pod: str) -> int:
        self.refresh()
        h = self._state.get(pod)
        return h.last_progress if h else 0

    def heartbeat_times(self) -> dict[str, float]:
        self.refresh()
        return {p: h.last_ts for p, h in self._state.items()}
