"""Liveness/readiness probes (paper §3.5), bus-record edition.

A pod's step loop publishes heartbeats on the ``health`` topic. The monitor
declares a pod:
  * not READY   — no heartbeat yet (still initializing / compiling),
  * LIVE        — last heartbeat within ``liveness_window``,
  * LIVELOCKED  — heartbeats still arriving, the pod reports work in
                  flight (``busy``), but its ``progress`` counter has not
                  advanced for longer than ``livelock_window`` — the pod
                  is spinning, not serving (serving-fleet adaptation; off
                  unless a window is configured),
  * DEAD        — liveness window exceeded -> the supervisor restarts it
                  from its spec / the last checkpoint.

Stronger than the paper's HTTP probes: a heartbeat carries a *forward
progress* counter (train steps completed, serving tokens emitted), so a
livelocked pod is detected too, not just a crashed one — an HTTP 200 from
a wedged worker looks exactly like one from a healthy worker, but a flat
progress counter does not.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable

from repro.core.bus import TopicBus

TOPIC = "health"


@dataclass
class PodHealth:
    pod: str
    last_ts: float
    last_progress: int
    ready: bool
    progress_ts: float = 0.0   # when ``progress`` last ADVANCED
    busy: bool = False         # pod-reported: work in flight right now

    def state(self, now: float, window: float,
              livelock_window: float | None = None) -> str:
        if not self.ready:
            return "not_ready"
        if (now - self.last_ts) > window:
            return "dead"
        if (livelock_window is not None and self.busy
                and (now - self.progress_ts) > livelock_window):
            return "livelocked"
        return "live"


class HeartbeatWriter:
    def __init__(self, bus: TopicBus, pod: str):
        self.bus, self.pod = bus, pod

    def ready(self):
        self.bus.publish(TOPIC, {"pod": self.pod, "kind": "ready"}, key=self.pod)

    def beat(self, progress: int = 0, **info):
        """One liveness beat. ``progress`` is a monotonic forward-progress
        counter; serving workers additionally pass ``busy=True`` while
        requests are in flight so the monitor can tell "idle" (no progress
        expected) from "livelocked" (progress owed but not happening)."""
        self.bus.publish(
            TOPIC,
            {"pod": self.pod, "kind": "beat", "progress": progress, **info},
            key=self.pod,
        )


class HealthMonitor:
    """Replays the ``health`` topic into per-pod state.

    ``livelock_window_s=None`` (default) disables livelock detection —
    the train-era workflow scheduler only distinguishes live/dead.
    ``clock`` is injectable so hysteresis/window tests are deterministic.
    """

    def __init__(self, bus: TopicBus, liveness_window_s: float = 10.0,
                 livelock_window_s: float | None = None,
                 clock: Callable[[], float] = time.time):
        self.bus = bus
        self.window = liveness_window_s
        self.livelock_window = livelock_window_s
        self.clock = clock
        self._state: dict[str, PodHealth] = {}
        self._cursor = 0

    def refresh(self):
        msgs = self.bus.read(TOPIC, start=self._cursor)
        for m in msgs:
            self._cursor = m.offset + 1
            v = m.value
            pod = v["pod"]
            h = self._state.get(pod) or PodHealth(pod, 0.0, 0, False)
            if v["kind"] == "ready":
                h.ready = True
                h.progress_ts = m.ts
            progress = v.get("progress", h.last_progress)
            if progress != h.last_progress:
                h.progress_ts = m.ts
            h.last_ts = m.ts
            h.last_progress = progress
            h.busy = bool(v.get("busy", h.busy))
            self._state[pod] = h

    def status(self, pod: str) -> str:
        self.refresh()
        h = self._state.get(pod)
        if h is None:
            return "unknown"
        return h.state(self.clock(), self.window, self.livelock_window)

    def dead_pods(self) -> list[str]:
        self.refresh()
        now = self.clock()
        return [p for p, h in self._state.items()
                if h.state(now, self.window) == "dead"]

    def unhealthy_pods(self) -> list[tuple[str, str]]:
        """(pod, state) for every pod currently dead OR livelocked — the
        serving supervisor restarts both kinds."""
        self.refresh()
        now = self.clock()
        out = []
        for p, h in self._state.items():
            s = h.state(now, self.window, self.livelock_window)
            if s in ("dead", "livelocked"):
                out.append((p, s))
        return out

    def forget(self, pod: str) -> None:
        """Drop a pod from the view (it was retired/replaced); its stale
        heartbeats must not keep reporting it dead forever."""
        self._state.pop(pod, None)

    def progress(self, pod: str) -> int:
        self.refresh()
        h = self._state.get(pod)
        return h.last_progress if h else 0

    def heartbeat_times(self) -> dict[str, float]:
        self.refresh()
        return {p: h.last_ts for p, h in self._state.items()}
