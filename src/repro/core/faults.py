"""Chaos/fault injection used by the FT integration tests and examples.

Deterministic (seeded) pod-killing: the injector arms WorkerPods' kill
switches according to a schedule or a seeded random process — the test
harness for every paper-§3.5 claim (retries, probes, restart-from-ckpt).

Two kill models live here, one per pod family:

* :class:`KillRule` — the train-era model: a timer armed when a workflow
  pod *starts*, firing ``after_s`` seconds later. Wall-clock by design
  (it simulates a node dying underneath a long step).
* :class:`WorkerKillRule` — the serving-fleet model: the *worker itself*
  calls :meth:`FaultInjector.check_worker` once per engine step, and the
  rule fires deterministically on the worker's own progress counters
  (steps run / tokens emitted this attempt), never on wall clock — which
  is what lets the fleet chaos tests pin a crash mid-prefill or at an
  exact token index and stay reproducible under any thread scheduling.

All kill accounting is guarded by one lock: rules are consulted from the
scheduler thread, from every worker thread, and (for timer kills) from
timer threads, so the check-then-increment on ``_killed`` must be atomic —
without the lock a ``times=1`` rule can arm two kills when two pods start
concurrently (pinned by ``tests/test_faults.py``).
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field


@dataclass
class KillRule:
    step: str
    attempt: int | None = None     # None: any attempt
    after_s: float = 0.0           # kill this long after the pod starts
    times: int = 1                 # how many attempts to kill in total


@dataclass
class WorkerKillRule:
    """Deterministic kill condition for a serving engine worker.

    Fires when the worker named ``worker`` (``None``: any worker), on
    attempt ``attempt`` (``None``: any), reaches the given progress point:
    ``after_steps`` engine steps run this attempt, or ``after_tokens``
    tokens emitted this attempt (whichever is set; both set means both
    must be reached). ``times`` bounds how many attempts this rule kills
    in total, so a restarted worker is not killed forever.
    """

    worker: str | None = None
    attempt: int | None = None
    after_steps: int | None = None
    after_tokens: int | None = None
    times: int = 1


class FaultInjector:
    def __init__(self, rules: list[KillRule] | None = None, seed: int = 0,
                 random_kill_prob: float = 0.0,
                 worker_rules: list[WorkerKillRule] | None = None):
        self.rules = list(rules or [])
        self.worker_rules = list(worker_rules or [])
        self.rng = random.Random(seed)
        self.random_kill_prob = random_kill_prob
        # kills armed per rule key; mutated from scheduler/worker/timer
        # threads, so every check-then-increment holds _lock
        self._killed: dict[str, int] = {}
        self._timers: list[threading.Timer] = []
        self._armed = 0
        self._lock = threading.Lock()

    def on_pod_start(self, pod) -> bool:
        """Called by the scheduler for every launched WorkerPod. Returns
        True when a kill was armed for this pod."""
        step = pod.image.step.name
        for rule in self.rules:
            if rule.step != step:
                continue
            if rule.attempt is not None and rule.attempt != pod.attempt:
                continue
            with self._lock:
                if self._killed.get(step, 0) >= rule.times:
                    continue
                self._killed[step] = self._killed.get(step, 0) + 1
                self._armed += 1
                t = threading.Timer(
                    rule.after_s, pod.kill_switch.kill,
                    kwargs={"reason": f"chaos:{step}"},
                )
                t.daemon = True
                t.start()
                self._timers.append(t)
            return True
        if self.random_kill_prob and self.rng.random() < self.random_kill_prob:
            delay = self.rng.uniform(0.01, 0.2)
            t = threading.Timer(delay, pod.kill_switch.kill, kwargs={"reason": "chaos:random"})
            t.daemon = True
            with self._lock:
                self._timers.append(t)
                self._armed += 1
            t.start()
            return True
        return False

    # ------------------------------------------------------------------
    # serving-worker kills (progress-deterministic, no timers)
    # ------------------------------------------------------------------
    def check_worker(self, worker: str, attempt: int, *, steps: int,
                     tokens: int) -> str | None:
        """Consult the worker rules at one engine-step boundary. Returns a
        kill reason when a rule fires, else None. Called synchronously
        from the worker's own loop, so the kill lands at a deterministic
        point in that worker's progress regardless of thread scheduling."""
        for i, rule in enumerate(self.worker_rules):
            if rule.worker is not None and rule.worker != worker:
                continue
            if rule.attempt is not None and rule.attempt != attempt:
                continue
            if rule.after_steps is None and rule.after_tokens is None:
                continue
            if rule.after_steps is not None and steps < rule.after_steps:
                continue
            if rule.after_tokens is not None and tokens < rule.after_tokens:
                continue
            key = f"worker_rule:{i}"
            with self._lock:
                if self._killed.get(key, 0) >= rule.times:
                    continue
                # one rule kills one attempt once: a worker that survives
                # the kill point (already past it when armed) must not be
                # re-killed every subsequent step of the same attempt
                seen = f"{key}:{worker}:a{attempt}"
                if self._killed.get(seen):
                    continue
                self._killed[key] = self._killed.get(key, 0) + 1
                self._killed[seen] = 1
                self._armed += 1
            return (f"chaos:{worker}:a{attempt}:steps={steps}"
                    f":tokens={tokens}")
        return None

    def kills_armed(self) -> int:
        """Total kills armed so far (timer + worker rules)."""
        with self._lock:
            return self._armed

    def cancel_all(self):
        with self._lock:
            timers, self._timers = self._timers, []
        for t in timers:
            t.cancel()
