"""Chaos/fault injection used by the FT integration tests and examples.

Deterministic (seeded) pod-killing: the injector arms WorkerPods' kill
switches according to a schedule or a seeded random process — the test
harness for every paper-§3.5 claim (retries, probes, restart-from-ckpt).
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field


@dataclass
class KillRule:
    step: str
    attempt: int | None = None     # None: any attempt
    after_s: float = 0.0           # kill this long after the pod starts
    times: int = 1                 # how many attempts to kill in total


class FaultInjector:
    def __init__(self, rules: list[KillRule] | None = None, seed: int = 0,
                 random_kill_prob: float = 0.0):
        self.rules = list(rules or [])
        self.rng = random.Random(seed)
        self.random_kill_prob = random_kill_prob
        self._killed: dict[str, int] = {}
        self._timers: list[threading.Timer] = []

    def on_pod_start(self, pod) -> None:
        """Called by the scheduler for every launched WorkerPod."""
        step = pod.image.step.name
        for rule in self.rules:
            if rule.step != step:
                continue
            if rule.attempt is not None and rule.attempt != pod.attempt:
                continue
            if self._killed.get(step, 0) >= rule.times:
                continue
            self._killed[step] = self._killed.get(step, 0) + 1
            t = threading.Timer(
                rule.after_s, pod.kill_switch.kill, kwargs={"reason": f"chaos:{step}"}
            )
            t.daemon = True
            t.start()
            self._timers.append(t)
            return
        if self.random_kill_prob and self.rng.random() < self.random_kill_prob:
            delay = self.rng.uniform(0.01, 0.2)
            t = threading.Timer(delay, pod.kill_switch.kill, kwargs={"reason": "chaos:random"})
            t.daemon = True
            t.start()
            self._timers.append(t)

    def cancel_all(self):
        for t in self._timers:
            t.cancel()
