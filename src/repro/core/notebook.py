"""Notebook model + AST dataflow extraction (paper §3.1, first half).

A ``Notebook`` is an ordered list of ``Cell``s. Cells carry Python source
(as in .ipynb) or a Python callable (the programmatic API used by the ML
pipelines). For source cells we statically extract

  * ``reads``  — names loaded before being stored (free inputs),
  * ``writes`` — names stored at the top level (outputs),

which is exactly the information Jup2Kub needs to reconstruct the implicit
dataflow that the linear notebook hides.
"""

from __future__ import annotations

import ast
import builtins
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable


_BUILTINS = set(dir(builtins))


class _Usage(ast.NodeVisitor):
    """Collect top-level reads (free loads) and writes (stores)."""

    def __init__(self):
        self.reads: set[str] = set()
        self.writes: set[str] = set()
        self._local_scopes: list[set[str]] = []

    # --- name accounting ---
    def visit_Name(self, node: ast.Name):
        if isinstance(node.ctx, ast.Load):
            if node.id not in self.writes and node.id not in _BUILTINS:
                if not any(node.id in s for s in self._local_scopes):
                    self.reads.add(node.id)
        elif isinstance(node.ctx, (ast.Store, ast.Del)):
            if self._local_scopes:
                self._local_scopes[-1].add(node.id)
            else:
                self.writes.add(node.id)
        self.generic_visit(node)

    def visit_Import(self, node):
        for a in node.names:
            self.writes.add((a.asname or a.name).split(".")[0])

    def visit_ImportFrom(self, node):
        for a in node.names:
            self.writes.add(a.asname or a.name)

    def _visit_scoped(self, node, params: list[str]):
        # function/lambda bodies get a local scope seeded with parameters
        self._local_scopes.append(set(params))
        for child in ast.iter_child_nodes(node):
            self.visit(child)
        self._local_scopes.pop()

    def visit_FunctionDef(self, node):
        self.writes.add(node.name)
        params = [a.arg for a in node.args.args + node.args.kwonlyargs]
        if node.args.vararg:
            params.append(node.args.vararg.arg)
        if node.args.kwarg:
            params.append(node.args.kwarg.arg)
        self._visit_scoped(node, params)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        self._visit_scoped(node, [a.arg for a in node.args.args])

    # comprehensions have their own scope in py3 — targets are not
    # module-level writes, and element reads of targets are not free reads
    def _visit_comp(self, node):
        self._local_scopes.append(set())
        for gen in node.generators:
            self.visit(gen.iter)
            self.visit(gen.target)  # Store -> local scope (pushed above)
            for cond in gen.ifs:
                self.visit(cond)
        if isinstance(node, ast.DictComp):
            self.visit(node.key)
            self.visit(node.value)
        else:
            self.visit(node.elt)
        self._local_scopes.pop()

    visit_ListComp = _visit_comp
    visit_SetComp = _visit_comp
    visit_GeneratorExp = _visit_comp
    visit_DictComp = _visit_comp

    def visit_ClassDef(self, node):
        self.writes.add(node.name)
        self._visit_scoped(node, [])

    def visit_AugAssign(self, node):
        # x += 1 both reads and writes x
        if isinstance(node.target, ast.Name):
            if not any(node.target.id in s for s in self._local_scopes):
                self.reads.add(node.target.id)
        self.generic_visit(node)

    def visit_Assign(self, node):
        # evaluation order: RHS first — `total = total + row` READS total
        self.visit(node.value)
        for t in node.targets:
            self.visit(t)

    def visit_AnnAssign(self, node):
        if node.value is not None:
            self.visit(node.value)
        self.visit(node.target)


def extract_usage(source: str) -> tuple[set[str], set[str]]:
    tree = ast.parse(source)
    u = _Usage()
    u.visit(tree)
    return u.reads, u.writes


@dataclass
class Cell:
    """One notebook cell: source xor fn."""

    source: str | None = None
    fn: Callable[[dict], dict] | None = None
    name: str = ""
    reads: set[str] = field(default_factory=set)
    writes: set[str] = field(default_factory=set)
    tags: set[str] = field(default_factory=set)  # e.g. {"pipe"} forces a boundary

    def __post_init__(self):
        if self.source is not None and not (self.reads or self.writes):
            self.reads, self.writes = extract_usage(self.source)
        if self.source is not None:
            for line in self.source.splitlines():
                ls = line.strip()
                if ls.startswith("# %%") or ls.startswith("#%%"):
                    self.tags.update(
                        t for t in ls.replace("#", "").replace("%", "").split() if t
                    )
        if self.fn is not None:
            assert self.reads or self.writes or self.name, (
                "callable cells must declare reads/writes"
            )

    def run(self, env: dict) -> dict:
        """Execute against an environment dict; returns {written: value}."""
        if self.fn is not None:
            out = self.fn({k: env[k] for k in self.reads if k in env})
            assert set(out) >= self.writes, (self.name, set(out), self.writes)
            env.update(out)
            return out
        assert self.source is not None
        exec(compile(self.source, f"<cell:{self.name}>", "exec"), env)  # noqa: S102
        return {k: env[k] for k in self.writes if k in env}


@dataclass
class Notebook:
    cells: list[Cell]
    name: str = "notebook"

    @classmethod
    def from_ipynb(cls, path: str | Path) -> "Notebook":
        raw = json.loads(Path(path).read_text())
        cells = []
        for i, c in enumerate(raw.get("cells", [])):
            if c.get("cell_type") != "code":
                continue
            src = "".join(c.get("source", []))
            if src.strip():
                cells.append(Cell(source=src, name=f"cell{i}"))
        return cls(cells, name=Path(path).stem)

    @classmethod
    def from_sources(cls, sources: list[str], name: str = "notebook") -> "Notebook":
        return cls(
            [Cell(source=s, name=f"cell{i}") for i, s in enumerate(sources)], name=name
        )

    def run_linear(self, env: dict | None = None) -> dict:
        """Execute the notebook the classic way (single kernel, in order)."""
        env = dict(env or {})
        for c in self.cells:
            c.run(env)
        return env
