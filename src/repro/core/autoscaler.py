"""Horizontal autoscaler (paper §3.5 HPA), lag/throughput driven.

Watches a bus topic's consumer lag (serving) or heartbeat step-rate
(training) and computes a desired replica count in [min, max] with
hysteresis. For training, a scale decision is an *elastic rescale event*
(checkpoint -> reshard -> resume; see elastic.py) rather than naive pod
addition — DESIGN.md changed-assumption #3.

:class:`ServingAutoscaler` is the serving-fleet adaptation: consumer lag
alone undercounts demand once workers have *admitted* everything (lag 0,
every decode slot full, queues growing inside the engines), so it also
consults the fleet's slot-occupancy/page-utilization gauges (the ones
``serving/metrics.py`` already records) via an injected ``gauges``
callable — saturated workers with pending lag trigger a scale-up even
when the lag/replica ratio alone would not.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

from repro.core.bus import TopicBus
from repro.core.events import EventLog


@dataclass
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 8
    target_lag_per_replica: float = 8.0
    scale_down_grace_s: float = 1.0  # hysteresis: don't thrash downward
    # serving adaptation: scale up when mean slot occupancy exceeds this
    # while lag is nonzero (None disables the gauge term)
    target_occupancy: float | None = None


@dataclass
class Autoscaler:
    bus: TopicBus
    topic: str
    group: str
    cfg: AutoscalerConfig = field(default_factory=AutoscalerConfig)
    events: EventLog | None = None
    current: int = 1
    clock: Callable[[], float] = time.time
    _last_scale_down_ok: float | None = None

    def __post_init__(self):
        if self._last_scale_down_ok is None:
            self._last_scale_down_ok = self.clock()

    def desired_replicas(self) -> int:
        lag = self.bus.lag(self.topic, self.group)
        want = max(1, -(-lag // int(self.cfg.target_lag_per_replica)))  # ceil
        return max(self.cfg.min_replicas, min(self.cfg.max_replicas, want))

    def observe(self) -> tuple[int, bool]:
        """Returns (desired, changed). Applies hysteresis on scale-down:
        a lower desired count is only adopted once it has been wanted for
        ``scale_down_grace_s`` continuously, so an oscillating load never
        thrashes replicas down and immediately back up."""
        desired = self.desired_replicas()
        now = self.clock()
        if desired > self.current:
            changed = True
        elif desired < self.current:
            if now - self._last_scale_down_ok < self.cfg.scale_down_grace_s:
                return self.current, False
            changed = True
        else:
            self._last_scale_down_ok = now
            return self.current, False
        old = self.current
        self.current = desired
        self._last_scale_down_ok = now
        if self.events is not None:
            self.events.emit(
                "autoscale", step=self.topic, attempt=-1,
                old=old, new=desired, lag=self.bus.lag(self.topic, self.group),
            )
        return desired, changed


@dataclass
class ServingAutoscaler(Autoscaler):
    """Lag + engine-gauge driven replica count for the serving fleet.

    ``gauges`` returns the fleet's current aggregate gauges, at least
    ``{"slot_occupancy_mean": float in [0, 1]}`` (see
    :meth:`repro.serving.fleet.FleetSupervisor.gauges`). When mean
    occupancy exceeds ``cfg.target_occupancy`` and there is still lag on
    the work topic, one more replica is requested than the lag ratio
    alone — the workers are slot-bound, so splitting the queue across
    another engine is the only way lag can drain faster. Scale-down keeps
    the base class hysteresis.
    """

    gauges: Callable[[], dict] | None = None

    def desired_replicas(self) -> int:
        want = super().desired_replicas()
        if self.gauges is not None and self.cfg.target_occupancy is not None:
            g = self.gauges() or {}
            occ = g.get("slot_occupancy_mean", 0.0)
            if (occ >= self.cfg.target_occupancy
                    and self.bus.lag(self.topic, self.group) > 0):
                want = max(want, self.current + 1)
        return max(self.cfg.min_replicas, min(self.cfg.max_replicas, want))
