"""Horizontal autoscaler (paper §3.5 HPA), lag/throughput driven.

Watches a bus topic's consumer lag (serving) or heartbeat step-rate
(training) and computes a desired replica count in [min, max] with
hysteresis. For training, a scale decision is an *elastic rescale event*
(checkpoint -> reshard -> resume; see elastic.py) rather than naive pod
addition — DESIGN.md changed-assumption #3.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.bus import TopicBus
from repro.core.events import EventLog


@dataclass
class AutoscalerConfig:
    min_replicas: int = 1
    max_replicas: int = 8
    target_lag_per_replica: float = 8.0
    scale_down_grace_s: float = 1.0  # hysteresis: don't thrash downward


@dataclass
class Autoscaler:
    bus: TopicBus
    topic: str
    group: str
    cfg: AutoscalerConfig = field(default_factory=AutoscalerConfig)
    events: EventLog | None = None
    current: int = 1
    _last_scale_down_ok: float = field(default_factory=time.time)

    def desired_replicas(self) -> int:
        lag = self.bus.lag(self.topic, self.group)
        want = max(1, -(-lag // int(self.cfg.target_lag_per_replica)))  # ceil
        return max(self.cfg.min_replicas, min(self.cfg.max_replicas, want))

    def observe(self) -> tuple[int, bool]:
        """Returns (desired, changed). Applies hysteresis on scale-down."""
        desired = self.desired_replicas()
        now = time.time()
        if desired > self.current:
            changed = True
        elif desired < self.current:
            if now - self._last_scale_down_ok < self.cfg.scale_down_grace_s:
                return self.current, False
            changed = True
        else:
            self._last_scale_down_ok = now
            return self.current, False
        old = self.current
        self.current = desired
        self._last_scale_down_ok = now
        if self.events is not None:
            self.events.emit(
                "autoscale", step=self.topic, attempt=-1,
                old=old, new=desired, lag=self.bus.lag(self.topic, self.group),
            )
        return desired, changed
