"""Capsule: ReproZip-style dependency capture, TPU/JAX edition (paper §2.1, §3.1).

ReproZip traces syscalls to capture everything an experiment needs. A JAX
pipeline step has a much cleaner closure, which we capture *exactly*:

  * code      — source (or disassembly-stable qualname) of every cell/fn,
  * config    — the step's resolved configuration (dataclasses -> dict),
  * packages  — versions of every imported top-level package,
  * platform  — python/jax versions, device kind, mesh shape,
  * data      — content hashes of consumed artifacts,
  * seeds     — RNG seeds.

``capsule_id`` is the sha256 over the canonical JSON — two steps with the
same id are bit-reproducible modulo hardware nondeterminism. ``seal_step``
turns (step, config) into a ``StepImage`` — the Docker-image analogue: a
frozen fn + capsule that the deployer ships to pods.
"""

from __future__ import annotations

import dataclasses
import hashlib
import importlib
import inspect
import json
import platform
import sys
from dataclasses import dataclass, field
from typing import Any, Callable

import jax


def _canon(obj: Any) -> Any:
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {"__dataclass__": type(obj).__name__, **{
            f.name: _canon(getattr(obj, f.name)) for f in dataclasses.fields(obj)
        }}
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in sorted(obj.items(), key=lambda kv: str(kv[0]))}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, (set, frozenset)):
        return sorted(str(v) for v in obj)
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return repr(obj)


def _source_of(fn: Callable) -> str:
    try:
        return inspect.getsource(fn)
    except (OSError, TypeError):
        return getattr(fn, "__qualname__", repr(fn))


def package_versions(names: set[str]) -> dict[str, str]:
    out = {}
    for name in sorted(names):
        try:
            mod = importlib.import_module(name)
            out[name] = str(getattr(mod, "__version__", "unversioned"))
        except ImportError:
            out[name] = "missing"
    return out


@dataclass(frozen=True)
class Capsule:
    code: dict[str, str]
    config: dict
    packages: dict[str, str]
    platform: dict[str, str]
    data_hashes: dict[str, str] = field(default_factory=dict)
    seeds: dict[str, int] = field(default_factory=dict)

    @property
    def capsule_id(self) -> str:
        blob = json.dumps(_canon(dataclasses.asdict(self)), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["capsule_id"] = self.capsule_id
        return json.dumps(_canon(d), indent=1)

    @classmethod
    def from_json(cls, blob: str) -> "Capsule":
        d = json.loads(blob)
        d.pop("capsule_id", None)
        return cls(**d)


def capture(
    step,
    config: Any = None,
    data_hashes: dict[str, str] | None = None,
    seeds: dict[str, int] | None = None,
    extra_packages: set[str] | None = None,
) -> Capsule:
    """Capture a Step's full closure (the ReproZip `config.yml` analogue)."""
    code: dict[str, str] = {}
    if step.fn is not None:
        code[step.name] = _source_of(step.fn)
    for c in step.cells:
        code[c.name or "cell"] = c.source or _source_of(c.fn)
    pkgs = {"jax", "jaxlib", "numpy"} | (extra_packages or set())
    plat = {
        "python": sys.version.split()[0],
        "machine": platform.machine(),
        "jax_backend": jax.default_backend(),
        "device_count": str(jax.device_count()),
    }
    return Capsule(
        code=code,
        config=_canon(config) if config is not None else {},
        packages=package_versions(pkgs),
        platform=plat,
        data_hashes=dict(data_hashes or {}),
        seeds=dict(seeds or {}),
    )


@dataclass
class StepImage:
    """The 'Docker image' of a step: sealed fn + capsule."""

    step: Any
    capsule: Capsule

    @property
    def tag(self) -> str:
        return f"{self.step.name}:{self.capsule.capsule_id[:12]}"

    def verify_against(self, other: "Capsule") -> list[str]:
        """Environment-drift report (paper: 'keeps working as tools change')."""
        drift = []
        for pkg, ver in self.capsule.packages.items():
            cur = other.packages.get(pkg)
            if cur != ver:
                drift.append(f"package {pkg}: captured {ver} vs current {cur}")
        for k, v in self.capsule.platform.items():
            cur = other.platform.get(k)
            if cur != v:
                drift.append(f"platform {k}: captured {v} vs current {cur}")
        return drift


def seal_step(step, config: Any = None, **kw) -> StepImage:
    return StepImage(step=step, capsule=capture(step, config, **kw))
