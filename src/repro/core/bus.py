"""TopicBus: the Kafka analogue (paper §3.4), file-backed and broker-less.

Semantics kept from Kafka (what the scheduler/monitors rely on):
  * topics are append-only ordered logs; messages get monotonic offsets;
  * producers append (atomic O_APPEND line writes — multi-process safe);
  * consumer groups track committed offsets; delivery is at-least-once
    (commit AFTER processing), so consumers must be idempotent — step
    attempts carry idempotency keys for exactly this reason;
  * replay: a new group (or ``seek(0)``) re-reads history — this is how a
    restarted monitor rebuilds its view of the workflow.

Large payloads do NOT travel on the bus: steps exchange ArtifactStore refs
(the Kafka + object-store pattern). On a real TPU cluster this bus is the
host-side control plane; device tensors move over ICI collectives.
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Iterator


@dataclass(frozen=True)
class Message:
    topic: str
    offset: int
    ts: float
    key: str
    value: Any


class TopicBus:
    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _log(self, topic: str) -> Path:
        d = self.root / topic
        d.mkdir(parents=True, exist_ok=True)
        return d / "log.jsonl"

    def _offsets_dir(self, topic: str) -> Path:
        d = self.root / topic / "offsets"
        d.mkdir(parents=True, exist_ok=True)
        return d

    def topics(self) -> list[str]:
        return sorted(
            str(p.parent.relative_to(self.root))
            for p in self.root.glob("**/log.jsonl")
        )

    # ------------------------------------------------------------------
    def publish(self, topic: str, value: Any, key: str = "") -> int:
        """Append one message; returns its offset."""
        line = None
        with self._lock:
            log = self._log(topic)
            offset = self._end_offset(topic)
            rec = {"o": offset, "t": time.time(), "k": key, "v": value}
            line = json.dumps(rec, separators=(",", ":"), default=str) + "\n"
            with open(log, "a", buffering=1) as f:
                f.write(line)
        return offset

    def _end_offset(self, topic: str) -> int:
        log = self._log(topic)
        if not log.exists():
            return 0
        with open(log, "rb") as f:
            return sum(1 for _ in f)

    def end_offset(self, topic: str) -> int:
        with self._lock:
            return self._end_offset(topic)

    # ------------------------------------------------------------------
    def read(self, topic: str, start: int = 0, limit: int | None = None) -> list[Message]:
        log = self._log(topic)
        if not log.exists():
            return []
        out: list[Message] = []
        with open(log) as f:
            for i, line in enumerate(f):
                if i < start:
                    continue
                if limit is not None and len(out) >= limit:
                    break
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue  # torn tail write from a crashed producer
                out.append(Message(topic, rec["o"], rec["t"], rec["k"], rec["v"]))
        return out

    # ------------------------------------------------------------------
    def committed(self, topic: str, group: str) -> int:
        f = self._offsets_dir(topic) / group
        if not f.exists():
            return 0
        try:
            return int(f.read_text().strip() or 0)
        except ValueError:
            return 0

    def commit(self, topic: str, group: str, offset: int):
        f = self._offsets_dir(topic) / group
        # unique tmp per writer: concurrent committers must not rename each
        # other's tmp away (last rename wins, which at-least-once tolerates)
        tmp = f.with_suffix(f".{os.getpid()}.{threading.get_ident()}.tmp")
        tmp.write_text(str(offset))
        tmp.rename(f)  # atomic

    def consume(self, topic: str, group: str, limit: int | None = None) -> list[Message]:
        """Fetch messages after the group's committed offset (no auto-commit)."""
        start = self.committed(topic, group)
        return self.read(topic, start=start, limit=limit)

    def lag(self, topic: str, group: str) -> int:
        return self.end_offset(topic) - self.committed(topic, group)


class Consumer:
    """Convenience looping consumer with at-least-once processing."""

    def __init__(self, bus: TopicBus, topic: str, group: str):
        self.bus, self.topic, self.group = bus, topic, group

    def poll(self, handler: Callable[[Message], None], max_msgs: int = 100) -> int:
        msgs = self.bus.consume(self.topic, self.group, limit=max_msgs)
        n = 0
        for m in msgs:
            handler(m)  # may raise -> nothing committed -> redelivery
            n += 1
            self.bus.commit(self.topic, self.group, m.offset + 1)
        return n
