# Jup2Kub core: the paper's contribution as a composable runtime.
#
#   notebook -> dag -> splitter        C1: piped-section splitting
#   capsule                            C2: ReproZip-style dependency capture
#   podspec -> deployer                C3: dynamic pod deployment (+ real k8s YAML)
#   storage                            C4: PV/PVC two-tier artifact store
#   bus, registry                      C5: Kafka-style topics + service discovery
#   scheduler, executor, probes,       C6: ReplicaSets, liveness/readiness,
#   autoscaler, elastic, faults            rolling updates, HPA, retries

from repro.core.notebook import Cell, Notebook
from repro.core.dag import StepGraph, Step
from repro.core.splitter import split_pipeline
from repro.core.capsule import Capsule, seal_step
from repro.core.bus import TopicBus
from repro.core.storage import ArtifactStore, VolumeClaim
from repro.core.podspec import PodSpec, ResourceLimits, render_k8s_yaml
from repro.core.deployer import PodManager, DynamicPodDeployer
from repro.core.scheduler import RetryPolicy, WorkflowScheduler

__all__ = [
    "Cell", "Notebook", "StepGraph", "Step", "split_pipeline",
    "Capsule", "seal_step", "TopicBus", "ArtifactStore", "VolumeClaim",
    "PodSpec", "ResourceLimits", "render_k8s_yaml",
    "PodManager", "DynamicPodDeployer", "RetryPolicy", "WorkflowScheduler",
]
