"""ArtifactStore: the PV/PVC analogue (paper §3.3), two-tier and content-addressed.

Tiers (the paper's local-PV vs EBS/EFS split):
  * ``node``   — per-node fast storage (node-affine; a pod claiming a node
    tier is pinned to that node, exactly like PV nodeAffinity);
  * ``shared`` — cluster-wide storage (EFS analogue) for inter-pod pipes
    and checkpoints.

Objects are content-addressed (``sha256``) so pipes are immutable, dedup'd
and integrity-checkable; refs look like ``shared://ab12cd.../tensor`` and are
what actually travels on the TopicBus. ``VolumeClaim`` reserves a named
directory with a capacity (enforced on put) — the PVC analogue, used by the
CheckpointManager as its backing volume.
"""

from __future__ import annotations

import hashlib
import io
import json
import pickle
import shutil
from dataclasses import dataclass
from pathlib import Path
from typing import Any

import numpy as np

TIERS = ("node", "shared")


@dataclass(frozen=True)
class VolumeClaim:
    name: str
    tier: str
    capacity_bytes: int
    path: Path

    def used_bytes(self) -> int:
        return sum(f.stat().st_size for f in self.path.rglob("*") if f.is_file())


class ArtifactStore:
    def __init__(self, root: str | Path, node_id: str = "node0"):
        self.root = Path(root)
        self.node_id = node_id
        (self.root / "shared" / "objects").mkdir(parents=True, exist_ok=True)
        (self.root / "node" / node_id / "objects").mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------------
    def _objects(self, tier: str) -> Path:
        if tier == "shared":
            return self.root / "shared" / "objects"
        if tier == "node":
            return self.root / "node" / self.node_id / "objects"
        raise ValueError(f"unknown tier {tier!r}; want one of {TIERS}")

    @staticmethod
    def _encode(obj: Any) -> tuple[bytes, str]:
        if isinstance(obj, bytes):
            return obj, "bytes"
        if isinstance(obj, np.ndarray):
            buf = io.BytesIO()
            np.save(buf, obj)
            return buf.getvalue(), "ndarray"
        try:
            return json.dumps(obj).encode(), "json"
        except (TypeError, ValueError):
            return pickle.dumps(obj), "pickle"

    @staticmethod
    def _decode(blob: bytes, kind: str) -> Any:
        if kind == "bytes":
            return blob
        if kind == "ndarray":
            return np.load(io.BytesIO(blob))
        if kind == "json":
            return json.loads(blob)
        return pickle.loads(blob)  # noqa: S301 — same-trust-domain pipes

    # ------------------------------------------------------------------
    def put(self, obj: Any, tier: str = "shared", name: str = "obj") -> str:
        blob, kind = self._encode(obj)
        digest = hashlib.sha256(blob).hexdigest()
        d = self._objects(tier) / digest
        d.mkdir(exist_ok=True)
        f = d / "data"
        if not f.exists():  # content-addressed: idempotent
            tmp = d / ".tmp"
            tmp.write_bytes(blob)
            tmp.rename(f)
            (d / "meta.json").write_text(json.dumps({"kind": kind, "name": name}))
        return f"{tier}://{digest}/{name}"

    def get(self, ref: str) -> Any:
        tier, rest = ref.split("://", 1)
        digest = rest.split("/", 1)[0]
        d = self._objects(tier) / digest
        blob = (d / "data").read_bytes()
        if hashlib.sha256(blob).hexdigest() != digest:
            raise IOError(f"integrity failure for {ref}")
        kind = json.loads((d / "meta.json").read_text())["kind"]
        return self._decode(blob, kind)

    def exists(self, ref: str) -> bool:
        tier, rest = ref.split("://", 1)
        digest = rest.split("/", 1)[0]
        return (self._objects(tier) / digest / "data").exists()

    def put_tree(self, tree: Any, tier: str = "shared", name: str = "tree") -> str:
        """Store a pytree (jax/np arrays + containers) as one artifact."""
        import jax

        leaves, treedef = jax.tree.flatten(tree)
        refs = [self.put(np.asarray(v), tier=tier, name=f"{name}.{i}") for i, v in enumerate(leaves)]
        meta = {"treedef": str(treedef), "leaves": refs}
        return self.put(meta, tier=tier, name=name)

    # ------------------------------------------------------------------
    def claim(self, name: str, tier: str = "shared", capacity_bytes: int = 1 << 34) -> VolumeClaim:
        base = self.root / tier if tier == "shared" else self.root / tier / self.node_id
        path = base / "claims" / name
        path.mkdir(parents=True, exist_ok=True)
        return VolumeClaim(name=name, tier=tier, capacity_bytes=capacity_bytes, path=path)

    def release(self, claim: VolumeClaim):
        shutil.rmtree(claim.path, ignore_errors=True)
