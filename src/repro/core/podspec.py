"""PodSpec + the paper's Listing-1 Kubernetes Deployment template.

Two render targets from one spec:
  * runtime objects for our in-process scheduler (a pod = mesh-slice lease
    + host worker), resources in chips/HBM instead of cpu/mem;
  * REAL Kubernetes YAML faithful to the paper's Listing 1 (ReplicaSet=3,
    RollingUpdate maxSurge/maxUnavailable=1, liveness/readiness probes,
    KAFKA_BROKER env, EFS PVC mount) — written by examples/notebook demo so
    the translation to an actual cluster is inspectable.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ResourceLimits:
    # runtime (TPU) resources
    chips: int = 0
    hbm_gb: float = 0.0
    # paper (k8s) resources, kept for YAML fidelity
    cpu_limit: str = "1"
    mem_limit: str = "1Gi"
    cpu_request: str = "500m"
    mem_request: str = "500Mi"


@dataclass
class PodSpec:
    name: str
    image: str                      # StepImage.tag
    role: str = "consumer"          # producer | consumer | both (paper §3.2.1)
    in_topics: list[str] = field(default_factory=list)
    out_topics: list[str] = field(default_factory=list)
    replicas: int = 3               # paper §3.5 ReplicaSet default
    max_surge: int = 1
    max_unavailable: int = 1
    resources: ResourceLimits = field(default_factory=ResourceLimits)
    env: dict = field(default_factory=dict)
    claim_name: str = ""
    liveness_interval_s: float = 5.0
    readiness_timeout_s: float = 30.0
    node_affinity: str | None = None  # set when a node-tier volume is claimed


def serving_worker_spec(name: str, *, replicas: int = 2,
                        liveness_interval_s: float = 2.0,
                        readiness_timeout_s: float = 60.0,
                        env: dict | None = None) -> PodSpec:
    """PodSpec for one serving-engine worker deployment.

    The serving fleet's workers are consumers of the supervisor's
    ``fleet.work`` topic and producers on ``fleet.events``; readiness is
    dominated by model load + XLA compile, so its timeout is much longer
    than the liveness interval. The same spec drives both the in-process
    :class:`repro.serving.fleet.FleetSupervisor` (restart parameters,
    probe windows) and :func:`render_k8s_yaml` for the paper's Listing-1
    Deployment."""
    return PodSpec(
        name=name,
        image=f"{name}:latest",
        role="both",
        in_topics=["fleet.work", "fleet.control"],
        out_topics=["fleet.events", "health"],
        replicas=replicas,
        resources=ResourceLimits(chips=1, hbm_gb=16.0,
                                 cpu_limit="4", mem_limit="16Gi"),
        env=dict(env or {}),
        liveness_interval_s=liveness_interval_s,
        readiness_timeout_s=readiness_timeout_s,
    )


def render_k8s_yaml(spec: PodSpec, kafka_broker: str = "my-broker-address",
                    tag: str = "latest") -> str:
    """The paper's Listing 1, filled in (indentation bugs of the paper fixed)."""
    image_name, _, img_tag = spec.image.partition(":")
    env_lines = "".join(
        f"        - name: {k}\n          value: \"{v}\"\n" for k, v in spec.env.items()
    )
    claim = spec.claim_name or f"{spec.name}-efs-pvc"
    return f"""apiVersion: apps/v1
kind: Deployment
metadata:
  name: {spec.name}-deployment
spec:
  replicas: {spec.replicas}
  strategy:
    type: RollingUpdate
    rollingUpdate:
      maxUnavailable: {spec.max_unavailable}
      maxSurge: {spec.max_surge}
  selector:
    matchLabels:
      app: {spec.name}
  template:
    metadata:
      labels:
        app: {spec.name}
    spec:
      containers:
      - name: {spec.name}-container
        image: {image_name}:{img_tag or tag}
        env:
        - name: KAFKA_BROKER
          value: "{kafka_broker}"
        - name: POD_ROLE
          value: "{spec.role}"
        - name: IN_TOPICS
          value: "{','.join(spec.in_topics)}"
        - name: OUT_TOPICS
          value: "{','.join(spec.out_topics)}"
{env_lines}        resources:
          limits:
            cpu: "{spec.resources.cpu_limit}"
            memory: "{spec.resources.mem_limit}"
          requests:
            cpu: "{spec.resources.cpu_request}"
            memory: "{spec.resources.mem_request}"
        livenessProbe:
          httpGet:
            path: /healthz
            port: 8080
        readinessProbe:
          httpGet:
            path: /readiness
            port: 8080
        volumeMounts:
        - name: efs-volume
          mountPath: /mnt/efs
      volumes:
      - name: efs-volume
        persistentVolumeClaim:
          claimName: {claim}
"""


def render_pv_pvc_yaml(name: str, tier: str, capacity: str = "10Gi",
                       node: str | None = None) -> str:
    """PV + PVC pair (paper §3.3): local (node-affine) or EFS-style shared."""
    if tier == "node":
        affinity = f"""
  nodeAffinity:
    required:
      nodeSelectorTerms:
      - matchExpressions:
        - key: kubernetes.io/hostname
          operator: In
          values: ["{node or 'node0'}"]"""
        source = f"  local:\n    path: /mnt/local/{name}"
        sc = "local-storage"
    else:
        affinity = ""
        source = f"  csi:\n    driver: efs.csi.aws.com\n    volumeHandle: fs-{name}"
        sc = "efs-sc"
    return f"""apiVersion: v1
kind: PersistentVolume
metadata:
  name: {name}-pv
spec:
  capacity:
    storage: {capacity}
  accessModes: ["ReadWriteMany"]
  storageClassName: {sc}
{source}{affinity}
---
apiVersion: v1
kind: PersistentVolumeClaim
metadata:
  name: {name}-efs-pvc
spec:
  accessModes: ["ReadWriteMany"]
  storageClassName: {sc}
  resources:
    requests:
      storage: {capacity}
"""
