"""PodManager + DynamicPodDeployer (paper §3.2), both runtime and YAML targets.

``PodManager`` derives per-step pod details (producer/consumer role, topics)
from the StepGraph — exactly the paper's §3.2.1 responsibility. The deployer
"applies" them: for the in-process runtime it wires a WorkflowScheduler; for
a real cluster it renders the Deployment/PV/PVC manifests into a directory
(`kubectl apply -f` ready).
"""

from __future__ import annotations

import logging
from dataclasses import dataclass, field
from pathlib import Path

from repro.core.dag import StepGraph
from repro.core.podspec import PodSpec, ResourceLimits, render_k8s_yaml, render_pv_pvc_yaml

log = logging.getLogger("jup2kub.deploy")


class PodManager:
    """Holds pod details: role (producer/consumer) and topics per step."""

    def __init__(self, graph: StepGraph):
        self.graph = graph

    def role_of(self, name: str) -> str:
        has_in = bool(self.graph.deps(name))
        has_out = bool(self.graph.consumers(name))
        if has_in and has_out:
            return "both"
        return "consumer" if has_in else "producer"

    def topics_of(self, name: str) -> tuple[list[str], list[str]]:
        in_topics = sorted(f"pipe.{d}.{name}" for d in self.graph.deps(name))
        out_topics = sorted(f"pipe.{name}.{c}" for c in self.graph.consumers(name))
        return in_topics, out_topics

    def pod_specs(
        self,
        default_replicas: int = 3,
        resources: dict[str, ResourceLimits] | None = None,
    ) -> list[PodSpec]:
        specs = []
        for name, step in self.graph.steps.items():
            in_t, out_t = self.topics_of(name)
            res = (resources or {}).get(name, ResourceLimits())
            specs.append(
                PodSpec(
                    name=name,
                    image=f"jup2kub/{name}:latest",
                    role=self.role_of(name),
                    in_topics=in_t,
                    out_topics=out_t,
                    replicas=1 if step.long_running else max(step.replicas, default_replicas),
                    resources=res,
                    env={"STEP_NAME": name},
                    claim_name=f"{name}-efs-pvc",
                )
            )
        return specs


@dataclass
class DynamicPodDeployer:
    """Renders + 'applies' pod deployments (paper §3.2.3)."""

    manager: PodManager
    out_dir: Path | None = None
    kafka_broker: str = "my-broker-address"
    applied: list[PodSpec] = field(default_factory=list)

    def load_kube_config(self) -> dict:
        """config.load_kube_config() analogue: resolve the runtime context."""
        import jax

        return {
            "context": "jup2kub-sim",
            "devices": jax.device_count(),
            "backend": jax.default_backend(),
        }

    def deploy_all(self, resources: dict[str, ResourceLimits] | None = None) -> list[PodSpec]:
        cfg = self.load_kube_config()
        log.info("deploying with context %s", cfg)
        specs = self.manager.pod_specs(resources=resources)
        for spec in specs:
            try:
                self._apply(spec)
                self.applied.append(spec)
                log.info("deployed %s role=%s replicas=%d", spec.name, spec.role, spec.replicas)
            except Exception:
                log.exception("failed to deploy %s", spec.name)
                raise
        return specs

    def _apply(self, spec: PodSpec):
        if self.out_dir is None:
            return
        self.out_dir.mkdir(parents=True, exist_ok=True)
        (self.out_dir / f"{spec.name}-deployment.yaml").write_text(
            render_k8s_yaml(spec, kafka_broker=self.kafka_broker)
        )
        (self.out_dir / f"{spec.name}-storage.yaml").write_text(
            render_pv_pvc_yaml(spec.name, tier="shared")
        )
