"""Generation engines: lockstep micro-batching and continuous batching.

``GenerationEngine`` is the original synchronous batcher kept as the serving
baseline (and for model families without a paged decode path): every request
in a micro-batch is padded to the longest prompt and the whole batch decodes
until the slowest request finishes.

``ContinuousBatchingEngine`` is the hot-path replacement: a paged KV cache
(`kv_cache.PagedKVCache`) shares one fixed-width decode batch between
sequences of different lengths, new requests are admitted into free slots as
others finish, and the jitted decode step sees one static shape — continuous
admission never retriggers compilation. Requests can be admitted straight
from a ``core.bus`` topic (:meth:`ContinuousBatchingEngine.admit_from_bus`).

Two serving features layer on top of the paged cache:

* **Chunked prefill** (``prefill_chunk=N``, the default): prompts are split
  into fixed-size chunks and at most ONE chunk runs per engine step,
  interleaved with the decode step — a long prompt never stalls in-flight
  decodes for more than one chunk's latency. One jitted chunk function
  (static chunk shape) covers every prompt length; there is no per-bucket
  compile. ``prefill_chunk=None`` restores the PR-1 whole-prompt bucketed
  prefill (and is the automatic path for vlm prompts, whose vision embeds
  don't chunk).
* **Prefix sharing** (``prefix_sharing=True``, chunked mode only): prompts
  are matched against the cache's prefix index at admission; full pages
  holding an identical prefix are mapped copy-on-write instead of
  recomputed, and the request skips straight to its first novel chunk.

Per-request latency is recorded on each :class:`Result` — ``ttft`` (enqueue
to first token) and ``itl`` (successive decode-token gaps) — so callers can
report p50/p90/p99 without instrumenting the engine.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.serving.kv_cache import NULL_PAGE, PagedKVCache, cdiv, write_prefill_pages


@dataclass
class Request:
    uid: str
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    # optional caller-supplied arrival time for TTFT; when None the engine
    # stamps enqueue time itself (engine-side, the Request is not mutated)
    arrival_t: float | None = None


@dataclass
class Result:
    uid: str
    tokens: list[int] = field(default_factory=list)
    ttft: float | None = None      # seconds, enqueue -> first token
    itl: list[float] = field(default_factory=list)  # inter-token gaps (s)


class GenerationEngine:
    def __init__(self, cfg, params, *, max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_len = max_len
        self._key = jax.random.key(seed)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.max_len)
        )
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))

    def _sample(self, logits: jax.Array, temps: np.ndarray) -> jax.Array:
        """Per-request temperatures: row i is sampled with temps[i]."""
        if (temps <= 0.0).all():
            return jnp.argmax(
                logits[..., : self.cfg.vocab_size], axis=-1
            ).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return _sample_rows(
            logits, jnp.asarray(temps, jnp.float32), sub, self.cfg.vocab_size
        )

    def generate(self, requests: list[Request]) -> list[Result]:
        """Serve one micro-batch of requests synchronously."""
        if not requests:
            return []
        b = len(requests)
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (b, self.cfg.num_frontend_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        if self.cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (b, plen, self.cfg.d_model), jnp.dtype(self.cfg.dtype)
            )

        cache, logits = self._prefill(self.params, batch)
        results = [Result(r.uid) for r in requests]
        max_new = max(r.max_new_tokens for r in requests)
        temps = np.array([r.temperature for r in requests], np.float32)
        tok = self._sample(logits, temps)
        for i, r in enumerate(results):
            r.tokens.append(int(tok[i]))
        for _ in range(max_new - 1):
            cache, logits = self._decode(self.params, cache, tok[:, None])
            tok = self._sample(logits, temps)
            for i, r in enumerate(results):
                if len(r.tokens) < requests[i].max_new_tokens:
                    r.tokens.append(int(tok[i]))
        return results


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


@dataclass
class _Seq:
    request: Request
    tokens: list[int]
    order: int = 0      # admission sequence number (preemption picks youngest)
    phase: str = "decode"   # "prefill" until the whole prompt is cached
    prefill_pos: int = 0    # prompt positions already resident in pages
    ttft: float | None = None
    itl: list[float] = field(default_factory=list)
    last_t: float = 0.0     # wall time of the previous emitted token


def _sample_rows(
    logits: jax.Array,  # (B, Vp) f32
    temps: jax.Array,   # (B,) f32; <= 0 means greedy
    key: jax.Array,
    vocab: int,
) -> jax.Array:
    lg = logits[..., :vocab]
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
    sampled = jax.random.categorical(
        key, lg / jnp.maximum(temps, 1e-6)[:, None], axis=-1
    ).astype(jnp.int32)
    return jnp.where(temps > 0.0, sampled, greedy)


class ContinuousBatchingEngine:
    """Paged-KV continuous batcher for decoder-only attention families.

    * Prompts prefill in fixed-size chunks (one jitted dispatch per chunk,
      static shape), at most one chunk per step, interleaved with decode —
      see the module docstring. ``prefill_chunk=None`` restores the PR-1
      whole-prompt bucketed prefill.
    * Admission consults the prefix index: requests sharing a cached prefix
      map those full pages copy-on-write and skip to their first novel chunk.
    * Decode runs one jitted step over ``max_slots`` fixed-width slots; slots
      that are idle or still prefilling are masked (null block table, length
      0) and their attention output is discarded.
    * Sequences finish independently — their page refcounts drop (pages
      return to the pool at zero) and the slot is refilled from the waiting
      queue on the next step.
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        max_len: int = 256,
        max_slots: int = 8,
        page_size: int = 16,
        num_pages: int | None = None,
        seed: int = 0,
        attn_impl: str | None = None,
        prefill_chunk: int | None = 64,
        prefix_sharing: bool = True,
    ):
        assert not cfg.is_encoder_decoder, "paged engine is decoder-only"
        assert cfg.family in ("dense", "moe", "vlm"), (
            f"continuous batching needs a paged KV path; family "
            f"{cfg.family!r} should use GenerationEngine"
        )
        self.cfg = cfg
        self.model = (
            build_model(cfg, attn_impl=attn_impl) if attn_impl else build_model(cfg)
        )
        self.params = params
        self.nf = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
        self.max_len = max_len
        self.max_slots = max_slots
        if prefill_chunk == 0:  # CLI convention: 0 disables chunking
            prefill_chunk = None
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        # vlm prompts carry vision embeds: no token chunking, no prefix trie
        self._chunked = prefill_chunk is not None and cfg.family in ("dense", "moe")
        self.prefill_chunk = prefill_chunk
        self.prefix_sharing = prefix_sharing and self._chunked
        self.cache = PagedKVCache(
            num_layers=cfg.num_layers,
            num_kv_heads=cfg.eff_kv_heads,
            head_dim=cfg.head_dim,
            dtype=jnp.dtype(cfg.dtype),
            max_slots=max_slots,
            max_context=max_len,
            page_size=page_size,
            num_pages=num_pages,
        )
        self._base_key = jax.random.key(seed)
        self._ticks = 0  # sampling-event counter, folded into the RNG key

        # ONE dispatch per decode step: model step + sampling fused, logits
        # never leave the device. Shapes are static, so this compiles once.
        # The sampled tokens and advanced lengths are returned device-side:
        # on steps with no admission/eviction they feed the next step
        # directly, so the steady-state loop transfers nothing to the device.
        def decode_and_sample(params, pages, bt, lens, active, tokens, temps,
                              tick):
            pages, logits = self.model.decode_step_paged(
                params, pages, bt, lens, tokens
            )
            key = jax.random.fold_in(self._base_key, tick)
            toks = _sample_rows(logits, temps, key, cfg.vocab_size)
            return pages, toks[:, None], lens + active

        self._decode = jax.jit(decode_and_sample, donate_argnums=(1,))
        self._prefill_fns: dict[int, object] = {}
        self._chunk_fn = None
        self.waiting: deque[Request] = deque()
        self._slots: dict[int, _Seq] = {}
        self._done: list[Result] = []
        self.rejections: list[tuple[str, str]] = []
        self.stats = {"decode_steps": 0, "prefills": 0, "prefill_chunks": 0,
                      "tokens": 0, "rejected": 0, "preemptions": 0}
        self._admit_counter = 0
        self._arrivals: dict[str, float] = {}  # uid -> enqueue time (TTFT)
        # device mirrors of the host tables; rebuilt only when stale
        self._dirty = True
        self._bt_dev = self._lens_dev = self._active_dev = None
        self._toks_dev = self._temps_dev = None

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def enqueue(self, req: Request) -> None:
        ctx = self.nf + len(req.prompt)
        if ctx + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: context {ctx}+{req.max_new_tokens} "
                f"exceeds engine max_len={self.max_len}"
            )
        worst = cdiv(ctx + req.max_new_tokens, self.cache.page_size)
        if worst > self.cache.num_pages - 1:
            raise ValueError(
                f"request {req.uid}: needs {worst} KV pages, pool has "
                f"{self.cache.num_pages - 1} — it could never be scheduled"
            )
        # arrival is tracked engine-side (keyed by uid, cleared on finish):
        # mutating the caller's Request would corrupt TTFT on resubmission
        self._arrivals.setdefault(
            req.uid,
            req.arrival_t if req.arrival_t is not None else time.perf_counter(),
        )
        self.waiting.append(req)

    def admit_from_bus(self, bus, topic: str, group: str, max_msgs: int = 32) -> int:
        """Pull pending requests from a ``core.bus`` topic into the waiting
        queue (at-least-once: each message is committed after enqueue).

        Malformed or unservable messages are rejected — recorded in
        ``self.rejections`` / ``stats['rejected']`` — and still committed,
        so one poison message never wedges the consumer group."""
        n = 0
        for m in bus.consume(topic, group, limit=max_msgs):
            v = m.value
            try:
                self.enqueue(Request(
                    v["uid"], list(v["prompt"]),
                    int(v.get("max_new_tokens", 16)),
                    float(v.get("temperature", 0.0)),
                ))
                n += 1
            except (ValueError, KeyError, TypeError) as e:
                uid = v.get("uid", "?") if isinstance(v, dict) else "?"
                self.rejections.append((str(uid), str(e)))
                self.stats["rejected"] += 1
            bus.commit(topic, group, m.offset + 1)
        return n

    def drain_rejections(self) -> list[tuple[str, str]]:
        out, self.rejections = self.rejections, []
        return out

    def _bucket(self, plen: int) -> int:
        b = 16
        while b < plen:
            b *= 2
        return min(b, max(self.max_len - self.nf, 1))

    def _prefill_fn(self, bucket: int):
        """Legacy whole-prompt path (``prefill_chunk=None`` / vlm): ONE
        dispatch per admission — prefill forward + page scatter + first
        token sample, jitted per prompt-length bucket."""
        if bucket not in self._prefill_fns:
            s_total = self.nf + bucket

            def fn(params, batch, idx, k_pages, v_pages, row, valid_len,
                   temp, tick):
                cache, logits = self.model.prefill(
                    params, batch, s_total, logits_index=idx
                )
                k_pages, v_pages = write_prefill_pages(
                    k_pages, v_pages, cache["k"][:, 0], cache["v"][:, 0],
                    row, valid_len,
                )
                key = jax.random.fold_in(self._base_key, tick)
                tok = _sample_rows(logits, temp[None], key, self.cfg.vocab_size)
                return k_pages, v_pages, tok[0]

            self._prefill_fns[bucket] = jax.jit(fn, donate_argnums=(3, 4))
        return self._prefill_fns[bucket]

    def _chunk_prefill_fn(self):
        """Chunked path: ONE jitted function (static chunk shape) covers
        every prompt length — chunk forward + page scatter + sample fused.
        The sampled token is only meaningful on a prompt's final chunk."""
        if self._chunk_fn is None:

            def fn(params, k_pages, v_pages, tokens, row, start, valid, temp,
                   tick):
                pages, logits = self.model.prefill_chunk(
                    params, {"k": k_pages, "v": v_pages}, row, tokens, start,
                    valid,
                )
                key = jax.random.fold_in(self._base_key, tick)
                tok = _sample_rows(logits[None], temp[None], key,
                                   self.cfg.vocab_size)
                return pages["k"], pages["v"], tok[0]

            self._chunk_fn = jax.jit(fn, donate_argnums=(1, 2))
        return self._chunk_fn

    def _finish(self, slot: int, seq: _Seq) -> Result:
        res = Result(seq.request.uid, seq.tokens, ttft=seq.ttft, itl=seq.itl)
        self.cache.release(slot)
        self._slots.pop(slot, None)
        self._arrivals.pop(res.uid, None)
        self._dirty = True
        return res

    def _first_token(self, slot: int, seq: _Seq, tok: int) -> None:
        """Prompt fully cached: record the sampled first token + TTFT."""
        now = time.perf_counter()
        seq.tokens.append(tok)
        seq.phase = "decode"
        seq.last_t = now
        arrival = self._arrivals.get(seq.request.uid)
        if arrival is not None:
            seq.ttft = now - arrival
        self.stats["tokens"] += 1
        self.stats["prefills"] += 1
        if seq.request.max_new_tokens <= 1:
            # lands in _done, harvested by THIS step (admit/prefill run
            # before the harvest) — not delayed to the next one
            self._done.append(self._finish(slot, seq))
        self._dirty = True

    def _pending_prefix_gain(self, tokens: list[int]) -> int:
        """Longest full-page prefix of ``tokens`` that an IN-FLIGHT prefill
        will publish to the prefix index but has not yet (its chunks haven't
        reached those pages). Admission waits for such a prefix instead of
        allocating private pages for content that is about to be shared —
        without this, a burst of same-prefix requests admitted in one step
        would get zero sharing."""
        ps = self.cache.page_size
        limit = self.cache._prefix_limit(tokens)
        best = 0
        for seq in self._slots.values():
            if seq.phase != "prefill":
                continue
            other = seq.request.prompt
            n = 0
            for i in range(min(limit, len(other) // ps)):
                if tokens[i * ps:(i + 1) * ps] != other[i * ps:(i + 1) * ps]:
                    break
                n += 1
            best = max(best, n * ps)
        return best

    def _admit(self) -> int:
        admitted = 0
        while self.waiting:
            req = self.waiting[0]
            plen = len(req.prompt)
            ctx = self.nf + plen
            tokens = req.prompt if self.prefix_sharing else None
            if tokens is not None:
                matched = self.cache.match_prefix(tokens)[1]
                if self._pending_prefix_gain(tokens) > matched:
                    break  # a longer shared prefix lands within a few chunks
            if not self.cache.can_admit(ctx, tokens):
                break
            self.waiting.popleft()
            slot, cached = self.cache.admit(ctx, tokens)
            self._admit_counter += 1

            if self._chunked:
                # pages claimed; chunks run one per step via _prefill_step,
                # starting at the first position not covered by the shared
                # prefix. The slot stays masked out of decode until then.
                self._slots[slot] = _Seq(
                    req, [], order=self._admit_counter, phase="prefill",
                    prefill_pos=cached,
                )
                self._dirty = True
                admitted += 1
                continue

            # legacy whole-prompt path (vlm / prefill_chunk=None)
            bucket = self._bucket(plen)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :plen] = req.prompt
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.family == "vlm":
                batch["vision_embeds"] = jnp.zeros(
                    (1, self.nf, self.cfg.d_model), jnp.dtype(self.cfg.dtype)
                )
            self._ticks += 1
            k_pages, v_pages, tok = self._prefill_fn(bucket)(
                self.params, batch, jnp.asarray(ctx - 1, jnp.int32),
                self.cache.k_pages, self.cache.v_pages,
                self.cache.device_row(slot),
                jnp.asarray(ctx, jnp.int32),
                jnp.asarray(req.temperature, jnp.float32),
                self._ticks,
            )
            self.cache.set_pages(k_pages, v_pages)
            seq = _Seq(req, [], order=self._admit_counter)
            self._slots[slot] = seq
            self._first_token(slot, seq, int(tok))
            admitted += 1
        return admitted

    def _prefill_step(self) -> bool:
        """Advance the OLDEST in-flight prefill by one fixed-size chunk.

        At most one chunk runs per engine step, so concurrent decodes stall
        for one chunk's latency at worst. Pages covered by the dispatched
        chunk are published to the prefix index afterwards — dispatch order
        is execution order, so a later admission can share them safely.
        """
        cands = [(q.order, s) for s, q in self._slots.items()
                 if q.phase == "prefill"]
        if not cands:
            return False
        _, slot = min(cands)
        seq = self._slots[slot]
        prompt = seq.request.prompt
        start = seq.prefill_pos
        c = self.prefill_chunk
        valid = min(c, len(prompt) - start)
        toks = np.zeros((c,), np.int32)
        toks[:valid] = prompt[start:start + valid]
        self._ticks += 1
        k_pages, v_pages, tok = self._chunk_prefill_fn()(
            self.params, self.cache.k_pages, self.cache.v_pages,
            jnp.asarray(toks), self.cache.device_row(slot),
            jnp.asarray(start, jnp.int32), jnp.asarray(valid, jnp.int32),
            jnp.asarray(seq.request.temperature, jnp.float32), self._ticks,
        )
        self.cache.set_pages(k_pages, v_pages)
        seq.prefill_pos = start + valid
        self.stats["prefill_chunks"] += 1
        if self.prefix_sharing:
            self.cache.register_prefix(slot, prompt, seq.prefill_pos)
        if seq.prefill_pos == len(prompt):
            self._first_token(slot, seq, int(tok))
        return True

    def _preempt(self, slot: int) -> None:
        """Evict a sequence and requeue its request (regenerated from
        scratch later) to free pages under pool pressure."""
        seq = self._slots.pop(slot)
        self.cache.release(slot)
        self.waiting.appendleft(seq.request)
        self.stats["preemptions"] += 1
        self._dirty = True

    def _ensure_capacity(self) -> None:
        """Give every DECODING slot a writable page for its next position —
        growing at page boundaries, copying a shared (refcount > 1) page
        anywhere else — preempting the youngest sequences if the pool runs
        dry. A lone sequence can always grow (enqueue rejects requests that
        exceed the whole pool), so this terminates with at least one slot
        making progress."""
        order = sorted(
            (s for s, q in self._slots.items() if q.phase == "decode"),
            key=lambda s: self._slots[s].order,
        )
        for slot in order:
            while slot in self._slots:
                try:
                    if self.cache.ensure_append_capacity(slot):
                        self._dirty = True
                    break
                except RuntimeError:
                    victim = max(self._slots, key=lambda s: self._slots[s].order)
                    self._preempt(victim)

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    @property
    def idle(self) -> bool:
        return not (self.waiting or self._slots or self._done)

    def step(self) -> list[Result]:
        """Admit, run (at most) one prefill chunk, run one decode step over
        all decoding slots, evict finished sequences. Returns the requests
        that completed."""
        self._admit()
        ran = self._prefill_step()
        # the one-chunk-per-step cap exists to bound decode stalls; with no
        # decode in flight there is nothing to stall, so drain chunks
        # back-to-back until a sequence becomes decodable (cold start,
        # post-burst refill)
        while ran and not any(
            q.phase == "decode" for q in self._slots.values()
        ):
            self._admit()
            ran = self._prefill_step()
        finished, self._done = self._done, []
        if not any(q.phase == "decode" for q in self._slots.values()):
            return finished

        self._ensure_capacity()
        if not any(q.phase == "decode" for q in self._slots.values()):
            return finished  # preemption can empty the decode set
        if self._dirty:  # admission/eviction/page-growth: refresh mirrors
            tokens = np.zeros((self.max_slots, 1), np.int32)
            temps = np.zeros((self.max_slots,), np.float32)
            active = np.zeros((self.max_slots,), np.int32)
            # fresh host copies: slots still prefilling are masked to the
            # null page / length 0 so the decode write lands in the sink
            # and their (discarded) attention output reads nothing
            bt = self.cache.block_tables.copy()
            lens = self.cache.lengths.copy()
            live = np.zeros((self.max_slots,), bool)
            for slot, seq in self._slots.items():
                if seq.phase != "decode":
                    continue
                live[slot] = True
                tokens[slot, 0] = seq.tokens[-1]
                temps[slot] = seq.request.temperature
                active[slot] = 1
            bt[~live] = NULL_PAGE
            lens[~live] = 0
            self._bt_dev = jnp.asarray(bt)
            self._lens_dev = jnp.asarray(lens)
            self._active_dev = jnp.asarray(active)
            self._toks_dev = jnp.asarray(tokens)
            self._temps_dev = jnp.asarray(temps)
            self._dirty = False
        pages = {"k": self.cache.k_pages, "v": self.cache.v_pages}
        self._ticks += 1
        pages, self._toks_dev, self._lens_dev = self._decode(
            self.params, pages, self._bt_dev, self._lens_dev,
            self._active_dev, self._toks_dev, self._temps_dev, self._ticks,
        )
        self.cache.set_pages(pages["k"], pages["v"])
        self.stats["decode_steps"] += 1
        toks = np.asarray(self._toks_dev)[:, 0]
        now = time.perf_counter()
        for slot in list(self._slots):
            seq = self._slots[slot]
            if seq.phase != "decode":
                continue
            self.cache.append(slot)
            seq.tokens.append(int(toks[slot]))
            seq.itl.append(now - seq.last_t)
            seq.last_t = now
            self.stats["tokens"] += 1
            if len(seq.tokens) >= seq.request.max_new_tokens:
                finished.append(self._finish(slot, seq))
        return finished

    def generate(self, requests: list[Request]) -> list[Result]:
        """Drain a request list through the continuous batcher; results come
        back in submission order."""
        for r in requests:
            self.enqueue(r)
        done: dict[str, Result] = {}
        while not self.idle:
            for res in self.step():
                done[res.uid] = res
        return [done[r.uid] for r in requests]
