"""Generation engines: lockstep micro-batching and continuous batching.

Both engines implement the :class:`repro.serving.api.EngineCore` protocol —
``submit() -> RequestHandle``, ``step() -> list[StreamEvent]``,
``cancel(uid)``, ``abort_all()`` — over the shared lifecycle machinery in
:class:`repro.serving.api.EngineBase`, so the bus worker, benchmarks and the
workflow scheduler drive them identically. Sampling (per-request temperature
/ top-k / top-p / seed) runs through ONE fused sample step
(``models.common.sample_tokens``) keyed off ``(seed, token_index)``, so a
request's token stream is independent of batch placement and survives
preemption byte-for-byte.

``GenerationEngine`` is the original synchronous batcher kept as the serving
baseline (and for model families without a paged decode path): it adapts the
protocol by chunking its micro-batches into steps — one ``step()`` call
forms a padded micro-batch and prefills it, each further call runs one
decode step over the whole batch, and the batch retires when every row has
finished (rows that stop early are masked, not evicted).

``ContinuousBatchingEngine`` is the hot path: a paged KV cache
(`kv_cache.PagedKVCache`) shares one fixed-width decode batch between
sequences of different lengths, new requests are admitted into free slots as
others finish, and the jitted decode step sees one static shape — continuous
admission never retriggers compilation.

Two serving features layer on top of the paged cache:

* **Chunked prefill** (``prefill_chunk=N``, the default): prompts are split
  into fixed-size chunks and at most ONE chunk runs per engine step,
  interleaved with the decode step — a long prompt never stalls in-flight
  decodes for more than one chunk's latency. ``prefill_chunk=None`` restores
  the whole-prompt bucketed prefill (and is the automatic path for vlm
  prompts, whose vision embeds don't chunk).
* **Prefix sharing** (``prefix_sharing=True``, chunked mode only): prompts
  are matched against the cache's prefix index at admission; full pages
  holding an identical prefix are mapped copy-on-write instead of
  recomputed, and the request skips straight to its first novel chunk.

Admission order is pluggable (``admission=`` takes any
:class:`repro.serving.api.AdmissionPolicy`; FIFO by default). Preemption
under page-pool pressure requeues the youngest sequences transparently —
their already-streamed deltas are never re-emitted — unless
``max_preemptions`` is exceeded, in which case the request finishes with
``FinishReason.PREEMPTED``.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.common import sample_tokens
from repro.serving.api import (
    AdmissionPolicy,
    EngineBase,
    FinishReason,
    Request,
    RequestHandle,
    Result,
    StreamEvent,
    validate_request,
)
from repro.serving.kv_cache import NULL_PAGE, PagedKVCache, cdiv, write_prefill_pages

__all__ = [
    "ContinuousBatchingEngine",
    "GenerationEngine",
    "Request",
    "Result",
]


@dataclass
class _Row:
    """One row of a lockstep micro-batch."""

    request: Request
    handle: RequestHandle
    done: bool = False


class GenerationEngine(EngineBase):
    """Lockstep micro-batching engine (protocol adapter over padded batches).

    ``step()`` semantics: with no batch in flight, pull up to ``max_batch``
    requests from the admission queue, left-pad to the longest prompt,
    prefill and sample each row's first token. Every further ``step()`` runs
    one decode step over the whole batch. Rows finish independently (length
    / stop / cancel) and are masked until the slowest row retires the batch
    — the classic lockstep cost the continuous batcher removes.
    """

    def __init__(self, cfg, params, *, max_len: int = 256, seed: int = 0,
                 max_batch: int = 8,
                 admission: AdmissionPolicy | None = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_len = max_len
        self.max_batch = max_batch
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.max_len)
        )
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        # jitted (per batch width): eager vmap would re-trace the sampler's
        # per-row body on every decode step; greedy_only is static so
        # all-greedy batches pay a plain argmax (same trick as the paged
        # engine's fused decode step)
        def _sample_fn(lg, temps, tks, tps, seeds, idx, greedy_only):
            if greedy_only:
                return jnp.argmax(
                    lg[..., :cfg.vocab_size], axis=-1
                ).astype(jnp.int32)
            return sample_tokens(lg, temps, tks, tps, seeds, idx,
                                 cfg.vocab_size)

        self._sample = jax.jit(_sample_fn, static_argnums=(6,))
        self._init_api(admission=admission, seed=seed)
        self._batch: list[_Row] | None = None
        self._bstate: dict | None = None

    # -- EngineBase hooks ----------------------------------------------
    def _validate(self, request: Request) -> None:
        validate_request(request, max_len=self.max_len)

    def _cancel_active(self, uid: str) -> bool:
        if self._batch is None:
            return False
        for row in self._batch:
            if row.handle.uid == uid and not row.done:
                row.done = True
                self._finish_handle(row.handle, FinishReason.CANCELLED)
                self._retire_if_done()
                return True
        return False

    def _retire_if_done(self) -> None:
        if self._batch is not None and all(r.done for r in self._batch):
            self._batch = None
            self._bstate = None

    # -- protocol -------------------------------------------------------
    @property
    def idle(self) -> bool:
        return not (len(self.admission) or self._batch or self._events)

    def capacity(self) -> int:
        if self._batch is not None:
            return 0
        return max(0, self.max_batch - len(self.admission))

    def step(self) -> list[StreamEvent]:
        now = time.perf_counter()
        self._expire_queue(now)
        if self._batch is None:
            # batch bound: rows are left-padded to the longest prompt and
            # decode until the slowest row finishes, so the batch occupies
            # max(plen) + max(max_new) cache positions — admit only while
            # that fits max_len (a lone request always does: validated)
            reqs: list[Request] = []
            plen = new = 0
            while len(reqs) < self.max_batch:
                cand = self.admission.peek(now)
                if cand is None:
                    break
                c_plen = max(plen, len(cand.prompt))
                c_new = max(new, cand.sampling.max_new_tokens)
                if reqs and c_plen + c_new > self.max_len:
                    break
                plen, new = c_plen, c_new
                reqs.append(self.admission.pop(now))
            if reqs:
                self._start_batch(reqs)
        else:
            st = self._bstate
            st["cache"], logits = self._decode(
                self.params, st["cache"], st["tok"][:, None]
            )
            st["step"] += 1
            st["tok"] = self._sample(
                logits, st["temps"], st["tks"], st["tps"], st["seeds"],
                jnp.full((len(self._batch),), st["step"], jnp.int32),
                st["greedy_only"],
            )
            self._harvest(np.asarray(st["tok"]))
        self._retire_if_done()
        return self._drain_events()

    # -- internals ------------------------------------------------------
    def _start_batch(self, reqs: list[Request]) -> None:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (b, self.cfg.num_frontend_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        if self.cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (b, plen, self.cfg.d_model), jnp.dtype(self.cfg.dtype)
            )
        cache, logits = self._prefill(self.params, batch)
        rows = [_Row(r, self._handles[r.uid]) for r in reqs]
        sp = [r.sampling for r in reqs]
        st = {
            "cache": cache,
            "step": 0,
            "greedy_only": all(s.temperature <= 0.0 for s in sp),
            "temps": jnp.asarray([s.temperature for s in sp], jnp.float32),
            "tks": jnp.asarray([s.top_k for s in sp], jnp.int32),
            "tps": jnp.asarray([s.top_p for s in sp], jnp.float32),
            "seeds": jnp.asarray([row.handle.seed for row in rows], jnp.int32),
        }
        st["tok"] = self._sample(
            logits, st["temps"], st["tks"], st["tps"], st["seeds"],
            jnp.zeros((b,), jnp.int32), st["greedy_only"],
        )
        self._batch, self._bstate = rows, st
        self._harvest(np.asarray(st["tok"]))

    def _harvest(self, toks: np.ndarray) -> None:
        now = time.perf_counter()
        idx = self._bstate["step"]
        for i, row in enumerate(self._batch):
            if row.done:
                continue
            if self._deliver(row.handle, int(toks[i]), idx, now):
                row.done = True


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


@dataclass
class _Seq:
    request: Request
    handle: RequestHandle
    tokens: list[int]   # this ATTEMPT's tokens (feed decode; the handle owns
                        # the emitted stream, which survives preemption)
    order: int = 0      # admission sequence number (preemption picks youngest)
    phase: str = "decode"   # "prefill" until the whole prompt is cached
    prefill_pos: int = 0    # prompt positions already resident in pages


class ContinuousBatchingEngine(EngineBase):
    """Paged-KV continuous batcher for decoder-only attention families.

    * Prompts prefill in fixed-size chunks (one jitted dispatch per chunk,
      static shape), at most one chunk per step, interleaved with decode —
      see the module docstring. ``prefill_chunk=None`` restores the
      whole-prompt bucketed prefill.
    * Admission consults the prefix index: requests sharing a cached prefix
      map those full pages copy-on-write and skip to their first novel chunk.
    * Decode runs one jitted step over ``max_slots`` fixed-width slots; slots
      that are idle or still prefilling are masked (null block table, length
      0) and their attention output is discarded.
    * Sequences finish independently — their page refcounts drop (pages
      return to the pool at zero) and the slot is refilled from the waiting
      queue on the next step.
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        max_len: int = 256,
        max_slots: int = 8,
        page_size: int = 16,
        num_pages: int | None = None,
        seed: int = 0,
        attn_impl: str | None = None,
        prefill_chunk: int | None = 64,
        prefix_sharing: bool = True,
        admission: AdmissionPolicy | None = None,
        max_preemptions: int | None = None,
    ):
        assert not cfg.is_encoder_decoder, "paged engine is decoder-only"
        assert cfg.family in ("dense", "moe", "vlm"), (
            f"continuous batching needs a paged KV path; family "
            f"{cfg.family!r} should use GenerationEngine"
        )
        self.cfg = cfg
        self.model = (
            build_model(cfg, attn_impl=attn_impl) if attn_impl else build_model(cfg)
        )
        self.params = params
        self.nf = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
        self.max_len = max_len
        self.max_slots = max_slots
        self.max_preemptions = max_preemptions
        if prefill_chunk == 0:  # CLI convention: 0 disables chunking
            prefill_chunk = None
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        # vlm prompts carry vision embeds: no token chunking, no prefix trie
        self._chunked = prefill_chunk is not None and cfg.family in ("dense", "moe")
        self.prefill_chunk = prefill_chunk
        self.prefix_sharing = prefix_sharing and self._chunked
        self.cache = PagedKVCache(
            num_layers=cfg.num_layers,
            num_kv_heads=cfg.eff_kv_heads,
            head_dim=cfg.head_dim,
            dtype=jnp.dtype(cfg.dtype),
            max_slots=max_slots,
            max_context=max_len,
            page_size=page_size,
            num_pages=num_pages,
        )
        self._init_api(admission=admission, seed=seed)
        self.stats.update({"decode_steps": 0, "prefills": 0,
                           "prefill_chunks": 0, "preemptions": 0})

        # ONE dispatch per decode step: model step + sampling fused, logits
        # never leave the device. Shapes are static, so this compiles once
        # per value of ``greedy_only`` — a host-known flag (recomputed with
        # the device mirrors) that lets all-greedy batches skip the per-row
        # top-k/top-p/seeded sampler entirely; the filters only cost when a
        # sampled request is actually in flight. The sampled tokens,
        # advanced lengths and advanced sample indices are returned
        # device-side: on steps with no admission/eviction they feed the
        # next step directly, so the steady-state loop transfers nothing to
        # the device.
        def decode_and_sample(params, pages, bt, lens, active, tokens, temps,
                              tks, tps, seeds, idx, greedy_only):
            pages, logits = self.model.decode_step_paged(
                params, pages, bt, lens, tokens
            )
            if greedy_only:
                toks = jnp.argmax(
                    logits[..., :cfg.vocab_size], axis=-1
                ).astype(jnp.int32)
            else:
                toks = sample_tokens(logits, temps, tks, tps, seeds, idx,
                                     cfg.vocab_size)
            return pages, toks[:, None], lens + active, idx + active

        self._decode = jax.jit(decode_and_sample, donate_argnums=(1,),
                               static_argnums=(11,))
        self._prefill_fns: dict[int, object] = {}
        self._chunk_fn = None
        self._slots: dict[int, _Seq] = {}
        self._admit_counter = 0
        # device mirrors of the host tables; rebuilt only when stale
        self._dirty = True
        self._greedy_only = True
        self._bt_dev = self._lens_dev = self._active_dev = None
        self._toks_dev = self._temps_dev = None
        self._tks_dev = self._tps_dev = self._seeds_dev = self._idx_dev = None

    # ------------------------------------------------------------------
    # EngineBase hooks
    # ------------------------------------------------------------------
    def _validate(self, request: Request) -> None:
        validate_request(request, max_len=self.max_len, extra_ctx=self.nf)
        ctx = self.nf + len(request.prompt)
        worst = cdiv(ctx + request.sampling.max_new_tokens,
                     self.cache.page_size)
        if worst > self.cache.num_pages - 1:
            raise ValueError(
                f"request {request.uid}: needs {worst} KV pages, pool has "
                f"{self.cache.num_pages - 1} — it could never be scheduled"
            )

    def _cancel_active(self, uid: str) -> bool:
        for slot, seq in list(self._slots.items()):
            if seq.request.uid == uid:
                self._finish_handle(seq.handle, FinishReason.CANCELLED)
                self._finish_slot(slot)
                return True
        return False

    # ------------------------------------------------------------------
    # protocol surface
    # ------------------------------------------------------------------
    @property
    def idle(self) -> bool:
        return not (len(self.admission) or self._slots or self._events)

    def capacity(self) -> int:
        return max(0, self.cache.free_slot_count - len(self.admission))

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        b = 16
        while b < plen:
            b *= 2
        return min(b, max(self.max_len - self.nf, 1))

    def _prefill_fn(self, bucket: int):
        """Legacy whole-prompt path (``prefill_chunk=None`` / vlm): ONE
        dispatch per admission — prefill forward + page scatter + first
        token sample, jitted per prompt-length bucket."""
        if bucket not in self._prefill_fns:
            s_total = self.nf + bucket

            def fn(params, batch, idx, k_pages, v_pages, row, valid_len,
                   temp, tk, tp, rseed):
                cache, logits = self.model.prefill(
                    params, batch, s_total, logits_index=idx
                )
                k_pages, v_pages = write_prefill_pages(
                    k_pages, v_pages, cache["k"][:, 0], cache["v"][:, 0],
                    row, valid_len,
                )
                tok = sample_tokens(
                    logits, temp[None], tk[None], tp[None], rseed[None],
                    jnp.zeros((1,), jnp.int32), self.cfg.vocab_size,
                )
                return k_pages, v_pages, tok[0]

            self._prefill_fns[bucket] = jax.jit(fn, donate_argnums=(3, 4))
        return self._prefill_fns[bucket]

    def _chunk_prefill_fn(self):
        """Chunked path: ONE jitted function (static chunk shape) covers
        every prompt length — chunk forward + page scatter + sample fused.
        The sampled token is only meaningful on a prompt's final chunk."""
        if self._chunk_fn is None:

            def fn(params, k_pages, v_pages, tokens, row, start, valid,
                   temp, tk, tp, rseed):
                pages, logits = self.model.prefill_chunk(
                    params, {"k": k_pages, "v": v_pages}, row, tokens, start,
                    valid,
                )
                tok = sample_tokens(
                    logits[None], temp[None], tk[None], tp[None],
                    rseed[None], jnp.zeros((1,), jnp.int32),
                    self.cfg.vocab_size,
                )
                return pages["k"], pages["v"], tok[0]

            self._chunk_fn = jax.jit(fn, donate_argnums=(1, 2))
        return self._chunk_fn

    def _finish_slot(self, slot: int) -> None:
        """Release a finished/cancelled sequence's slot and pages."""
        self.cache.release(slot)
        self._slots.pop(slot, None)
        self._dirty = True

    def _first_token(self, slot: int, seq: _Seq, tok: int) -> None:
        """Prompt fully cached: deliver the sampled first token (attempt
        index 0 — after a preemption the handle de-duplicates it)."""
        now = time.perf_counter()
        seq.tokens.append(tok)
        seq.phase = "decode"
        self.stats["prefills"] += 1
        if self._deliver(seq.handle, tok, 0, now):
            # finish event lands in THIS step's batch (admit/prefill run
            # before the decode harvest) — not delayed to the next one
            self._finish_slot(slot)
        self._dirty = True

    def _pending_prefix_gain(self, tokens: list[int]) -> int:
        """Longest full-page prefix of ``tokens`` that an IN-FLIGHT prefill
        will publish to the prefix index but has not yet (its chunks haven't
        reached those pages). Admission waits for such a prefix instead of
        allocating private pages for content that is about to be shared —
        without this, a burst of same-prefix requests admitted in one step
        would get zero sharing."""
        ps = self.cache.page_size
        limit = self.cache._prefix_limit(tokens)
        best = 0
        for seq in self._slots.values():
            if seq.phase != "prefill":
                continue
            other = seq.request.prompt
            n = 0
            for i in range(min(limit, len(other) // ps)):
                if tokens[i * ps:(i + 1) * ps] != other[i * ps:(i + 1) * ps]:
                    break
                n += 1
            best = max(best, n * ps)
        return best

    def _admit(self) -> int:
        now = time.perf_counter()
        self._expire_queue(now)
        admitted = 0
        while True:
            req = self.admission.peek(now)
            if req is None:
                break
            plen = len(req.prompt)
            ctx = self.nf + plen
            tokens = req.prompt if self.prefix_sharing else None
            if tokens is not None:
                matched = self.cache.match_prefix(tokens)[1]
                if self._pending_prefix_gain(tokens) > matched:
                    break  # a longer shared prefix lands within a few chunks
            if not self.cache.can_admit(ctx, tokens):
                break
            self.admission.pop(now)
            handle = self._handles[req.uid]
            slot, cached = self.cache.admit(ctx, tokens)
            self._admit_counter += 1

            if self._chunked:
                # pages claimed; chunks run one per step via _prefill_step,
                # starting at the first position not covered by the shared
                # prefix. The slot stays masked out of decode until then.
                self._slots[slot] = _Seq(
                    req, handle, [], order=self._admit_counter,
                    phase="prefill", prefill_pos=cached,
                )
                self._dirty = True
                admitted += 1
                continue

            # legacy whole-prompt path (vlm / prefill_chunk=None)
            bucket = self._bucket(plen)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :plen] = req.prompt
            batch = {"tokens": jnp.asarray(toks)}
            if self.cfg.family == "vlm":
                batch["vision_embeds"] = jnp.zeros(
                    (1, self.nf, self.cfg.d_model), jnp.dtype(self.cfg.dtype)
                )
            sp = req.sampling
            k_pages, v_pages, tok = self._prefill_fn(bucket)(
                self.params, batch, jnp.asarray(ctx - 1, jnp.int32),
                self.cache.k_pages, self.cache.v_pages,
                self.cache.device_row(slot),
                jnp.asarray(ctx, jnp.int32),
                jnp.asarray(sp.temperature, jnp.float32),
                jnp.asarray(sp.top_k, jnp.int32),
                jnp.asarray(sp.top_p, jnp.float32),
                jnp.asarray(handle.seed, jnp.int32),
            )
            self.cache.set_pages(k_pages, v_pages)
            seq = _Seq(req, handle, [], order=self._admit_counter)
            self._slots[slot] = seq
            self._first_token(slot, seq, int(tok))
            admitted += 1
        return admitted

    def _prefill_step(self) -> bool:
        """Advance the OLDEST in-flight prefill by one fixed-size chunk.

        At most one chunk runs per engine step, so concurrent decodes stall
        for one chunk's latency at worst. Pages covered by the dispatched
        chunk are published to the prefix index afterwards — dispatch order
        is execution order, so a later admission can share them safely.
        """
        cands = [(q.order, s) for s, q in self._slots.items()
                 if q.phase == "prefill"]
        if not cands:
            return False
        _, slot = min(cands)
        seq = self._slots[slot]
        prompt = seq.request.prompt
        start = seq.prefill_pos
        c = self.prefill_chunk
        valid = min(c, len(prompt) - start)
        toks = np.zeros((c,), np.int32)
        toks[:valid] = prompt[start:start + valid]
        sp = seq.request.sampling
        k_pages, v_pages, tok = self._chunk_prefill_fn()(
            self.params, self.cache.k_pages, self.cache.v_pages,
            jnp.asarray(toks), self.cache.device_row(slot),
            jnp.asarray(start, jnp.int32), jnp.asarray(valid, jnp.int32),
            jnp.asarray(sp.temperature, jnp.float32),
            jnp.asarray(sp.top_k, jnp.int32),
            jnp.asarray(sp.top_p, jnp.float32),
            jnp.asarray(seq.handle.seed, jnp.int32),
        )
        self.cache.set_pages(k_pages, v_pages)
        seq.prefill_pos = start + valid
        self.stats["prefill_chunks"] += 1
        if self.prefix_sharing:
            self.cache.register_prefix(slot, prompt, seq.prefill_pos)
        if seq.prefill_pos == len(prompt):
            self._first_token(slot, seq, int(tok))
        return True

    def _preempt(self, slot: int) -> None:
        """Evict a sequence to free pages under pool pressure. The request
        requeues and regenerates from scratch — already-streamed deltas are
        de-duplicated, so consumers never see a token twice — unless it has
        exceeded ``max_preemptions``, in which case it finishes
        ``FinishReason.PREEMPTED``."""
        seq = self._slots.pop(slot)
        self.cache.release(slot)
        self.stats["preemptions"] += 1
        self._dirty = True
        h = seq.handle
        h.preemptions += 1
        if (self.max_preemptions is not None
                and h.preemptions > self.max_preemptions):
            self._finish_handle(
                h, FinishReason.PREEMPTED,
                error=f"request {h.uid}: preempted {h.preemptions} times "
                      f"(max_preemptions={self.max_preemptions})",
            )
        else:
            self._events.append(
                StreamEvent(h.uid, "preempted", t=time.perf_counter())
            )
            self.admission.requeue(seq.request, h.arrival)

    def _ensure_capacity(self) -> None:
        """Give every DECODING slot a writable page for its next position —
        growing at page boundaries, copying a shared (refcount > 1) page
        anywhere else — preempting the youngest sequences if the pool runs
        dry. A lone sequence can always grow (submit rejects requests that
        exceed the whole pool), so this terminates with at least one slot
        making progress."""
        order = sorted(
            (s for s, q in self._slots.items() if q.phase == "decode"),
            key=lambda s: self._slots[s].order,
        )
        for slot in order:
            while slot in self._slots:
                try:
                    if self.cache.ensure_append_capacity(slot):
                        self._dirty = True
                    break
                except RuntimeError:
                    victim = max(self._slots, key=lambda s: self._slots[s].order)
                    self._preempt(victim)

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def step(self) -> list[StreamEvent]:
        """Admit, run (at most) one prefill chunk, run one decode step over
        all decoding slots, evict finished sequences. Returns the lifecycle
        events produced (token deltas, finishes, preemptions)."""
        self._admit()
        ran = self._prefill_step()
        # the one-chunk-per-step cap exists to bound decode stalls; with no
        # decode in flight there is nothing to stall, so drain chunks
        # back-to-back until a sequence becomes decodable (cold start,
        # post-burst refill)
        while ran and not any(
            q.phase == "decode" for q in self._slots.values()
        ):
            self._admit()
            ran = self._prefill_step()
        if not any(q.phase == "decode" for q in self._slots.values()):
            return self._drain_events()

        self._ensure_capacity()
        if not any(q.phase == "decode" for q in self._slots.values()):
            return self._drain_events()  # preemption can empty the decode set
        if self._dirty:  # admission/eviction/page-growth: refresh mirrors
            self._greedy_only = all(
                q.request.sampling.temperature <= 0.0
                for q in self._slots.values() if q.phase == "decode"
            )
            tokens = np.zeros((self.max_slots, 1), np.int32)
            temps = np.zeros((self.max_slots,), np.float32)
            tks = np.zeros((self.max_slots,), np.int32)
            tps = np.ones((self.max_slots,), np.float32)
            seeds = np.zeros((self.max_slots,), np.int32)
            idx = np.zeros((self.max_slots,), np.int32)
            active = np.zeros((self.max_slots,), np.int32)
            # fresh host copies: slots still prefilling are masked to the
            # null page / length 0 so the decode write lands in the sink
            # and their (discarded) attention output reads nothing
            bt = self.cache.block_tables.copy()
            lens = self.cache.lengths.copy()
            live = np.zeros((self.max_slots,), bool)
            for slot, seq in self._slots.items():
                if seq.phase != "decode":
                    continue
                live[slot] = True
                tokens[slot, 0] = seq.tokens[-1]
                sp = seq.request.sampling
                temps[slot] = sp.temperature
                tks[slot] = sp.top_k
                tps[slot] = sp.top_p
                seeds[slot] = seq.handle.seed
                idx[slot] = len(seq.tokens)
                active[slot] = 1
            bt[~live] = NULL_PAGE
            lens[~live] = 0
            self._bt_dev = jnp.asarray(bt)
            self._lens_dev = jnp.asarray(lens)
            self._active_dev = jnp.asarray(active)
            self._toks_dev = jnp.asarray(tokens)
            self._temps_dev = jnp.asarray(temps)
            self._tks_dev = jnp.asarray(tks)
            self._tps_dev = jnp.asarray(tps)
            self._seeds_dev = jnp.asarray(seeds)
            self._idx_dev = jnp.asarray(idx)
            self._dirty = False
        pages = {"k": self.cache.k_pages, "v": self.cache.v_pages}
        pages, self._toks_dev, self._lens_dev, self._idx_dev = self._decode(
            self.params, pages, self._bt_dev, self._lens_dev,
            self._active_dev, self._toks_dev, self._temps_dev,
            self._tks_dev, self._tps_dev, self._seeds_dev, self._idx_dev,
            self._greedy_only,
        )
        self.cache.set_pages(pages["k"], pages["v"])
        self.stats["decode_steps"] += 1
        toks = np.asarray(self._toks_dev)[:, 0]
        now = time.perf_counter()
        for slot in list(self._slots):
            seq = self._slots[slot]
            if seq.phase != "decode":
                continue
            self.cache.append(slot)
            tok = int(toks[slot])
            seq.tokens.append(tok)
            if self._deliver(seq.handle, tok, len(seq.tokens) - 1, now):
                self._finish_slot(slot)
        return self._drain_events()
