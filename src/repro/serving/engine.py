"""Batched generation engine: request queue -> prefill -> decode loop.

The engine is a Jup2Kub pipeline *step* in the serving example: requests
arrive on a bus topic, are micro-batched up to ``max_batch``, prefilled
together (padded to a shared length), then decoded token-by-token with a
jitted step. Greedy or temperature sampling.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


@dataclass
class Request:
    uid: str
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0


@dataclass
class Result:
    uid: str
    tokens: list[int] = field(default_factory=list)


class GenerationEngine:
    def __init__(self, cfg, params, *, max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_len = max_len
        self._key = jax.random.key(seed)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.max_len)
        )
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))

    def _sample(self, logits: jax.Array, temperature: float) -> jax.Array:
        logits = logits[..., : self.cfg.vocab_size]
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(sub, logits / temperature, axis=-1)

    def generate(self, requests: list[Request]) -> list[Result]:
        """Serve one micro-batch of requests synchronously."""
        if not requests:
            return []
        b = len(requests)
        plen = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(requests):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (b, self.cfg.num_frontend_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        if self.cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (b, plen, self.cfg.d_model), jnp.dtype(self.cfg.dtype)
            )

        cache, logits = self._prefill(self.params, batch)
        results = [Result(r.uid) for r in requests]
        max_new = max(r.max_new_tokens for r in requests)
        temp = max(r.temperature for r in requests)
        tok = self._sample(logits, temp).astype(jnp.int32)
        for i, r in enumerate(results):
            r.tokens.append(int(tok[i]))
        for _ in range(max_new - 1):
            cache, logits = self._decode(self.params, cache, tok[:, None])
            tok = self._sample(logits, temp).astype(jnp.int32)
            for i, r in enumerate(results):
                if len(r.tokens) < requests[i].max_new_tokens:
                    r.tokens.append(int(tok[i]))
        return results
