"""Generation engines: lockstep micro-batching and continuous batching.

Both engines implement the :class:`repro.serving.api.EngineCore` protocol —
``submit() -> RequestHandle``, ``step() -> list[StreamEvent]``,
``cancel(uid)``, ``abort_all()`` — over the shared lifecycle machinery in
:class:`repro.serving.api.EngineBase`, so the bus worker, benchmarks and the
workflow scheduler drive them identically. Sampling (per-request temperature
/ top-k / top-p / seed) runs through ONE fused sample step
(``models.common.sample_tokens``) keyed off ``(seed, token_index)``, so a
request's token stream is independent of batch placement and survives
preemption byte-for-byte.

``GenerationEngine`` is the original synchronous batcher kept as the serving
baseline (and for model families without a paged decode path): it adapts the
protocol by chunking its micro-batches into steps — one ``step()`` call
forms a padded micro-batch and prefills it, each further call runs one
decode step over the whole batch, and the batch retires when every row has
finished (rows that stop early are masked, not evicted).

``ContinuousBatchingEngine`` is the hot path, built from two layers
(see ``docs/serving.md`` for the full design):

* a host-side :class:`repro.serving.scheduler.Scheduler` — admission order,
  chunked-prefill interleaving, prefix-sharing deferral, preemption victim
  selection, page accounting and decode-batch assembly, all plain
  Python/numpy with no device dispatch;
* a device-side :class:`repro.serving.executor.ModelExecutor` — the jitted
  fused prefill/decode+sample steps, run under ``shard_map`` on a
  ``("model",)`` mesh with attention heads tensor-parallel and the KV page
  pool sharded along the head dimension (a 1-device mesh runs the same
  code path unsharded).

The engine itself is the thin protocol adapter wiring the two: it
translates scheduler decisions into lifecycle events and executor calls.
Admission order is pluggable (``admission=`` takes any
:class:`repro.serving.api.AdmissionPolicy`; FIFO by default). Preemption
under page-pool pressure requeues the youngest sequences transparently —
their already-streamed deltas are never re-emitted — unless
``max_preemptions`` is exceeded, in which case the request finishes with
``FinishReason.PREEMPTED``. Chunked prefill (``prefill_chunk=N``, the
default; ``None``/0 restores whole-prompt bucketed prefill, automatic for
vlm prompts) and copy-on-write prefix sharing (``prefix_sharing=True``)
behave exactly as before the split.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.common import sample_tokens
from repro.serving.api import (
    AdmissionPolicy,
    EngineBase,
    FinishReason,
    Request,
    RequestHandle,
    Result,
    StreamEvent,
    validate_request,
)
from repro.core.storage import ArtifactStore
from repro.serving.executor import ModelExecutor
from repro.serving.kv_cache import PagedKVCache, cdiv
from repro.serving.kv_tiers import KVTierManager
from repro.serving.metrics import UtilizationMetrics
from repro.serving.scheduler import Scheduler, Sequence
from repro.serving.speculative import SPEC_MODES, build_proposer

__all__ = [
    "ContinuousBatchingEngine",
    "GenerationEngine",
    "Request",
    "Result",
]


@dataclass
class _Row:
    """One row of a lockstep micro-batch."""

    request: Request
    handle: RequestHandle
    done: bool = False


class GenerationEngine(EngineBase):
    """Lockstep micro-batching engine (protocol adapter over padded batches).

    ``step()`` semantics: with no batch in flight, pull up to ``max_batch``
    requests from the admission queue, left-pad to the longest prompt,
    prefill and sample each row's first token. Every further ``step()`` runs
    one decode step over the whole batch. Rows finish independently (length
    / stop / cancel) and are masked until the slowest row retires the batch
    — the classic lockstep cost the continuous batcher removes.
    """

    def __init__(self, cfg, params, *, max_len: int = 256, seed: int = 0,
                 max_batch: int = 8,
                 admission: AdmissionPolicy | None = None):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_len = max_len
        self.max_batch = max_batch
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, self.max_len)
        )
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        # jitted (per batch width): eager vmap would re-trace the sampler's
        # per-row body on every decode step; greedy_only is static so
        # all-greedy batches pay a plain argmax (same trick as the paged
        # engine's fused decode step)
        def _sample_fn(lg, temps, tks, tps, seeds, idx, greedy_only):
            if greedy_only:
                return jnp.argmax(
                    lg[..., :cfg.vocab_size], axis=-1
                ).astype(jnp.int32)
            return sample_tokens(lg, temps, tks, tps, seeds, idx,
                                 cfg.vocab_size)

        self._sample = jax.jit(_sample_fn, static_argnums=(6,))
        self._init_api(admission=admission, seed=seed)
        self.utilization = UtilizationMetrics()
        self._batch: list[_Row] | None = None
        self._bstate: dict | None = None

    # -- EngineBase hooks ----------------------------------------------
    def _validate(self, request: Request) -> None:
        validate_request(request, max_len=self.max_len)

    def _cancel_active(self, uid: str) -> bool:
        if self._batch is None:
            return False
        for row in self._batch:
            if row.handle.uid == uid and not row.done:
                row.done = True
                self._finish_handle(row.handle, FinishReason.CANCELLED)
                self._retire_if_done()
                return True
        return False

    def _retire_if_done(self) -> None:
        if self._batch is not None and all(r.done for r in self._batch):
            self._batch = None
            self._bstate = None

    # -- protocol -------------------------------------------------------
    @property
    def idle(self) -> bool:
        return not (len(self.admission) or self._batch or self._events)

    def capacity(self) -> int:
        if self._batch is not None:
            return 0
        return max(0, self.max_batch - len(self.admission))

    def step(self) -> list[StreamEvent]:
        now = time.perf_counter()
        self._expire_queue(now)
        if self._batch is None:
            # batch bound: rows are left-padded to the longest prompt and
            # decode until the slowest row finishes, so the batch occupies
            # max(plen) + max(max_new) cache positions — admit only while
            # that fits max_len (a lone request always does: validated)
            reqs: list[Request] = []
            plen = new = 0
            while len(reqs) < self.max_batch:
                cand = self.admission.peek(now)
                if cand is None:
                    break
                c_plen = max(plen, len(cand.prompt))
                c_new = max(new, cand.sampling.max_new_tokens)
                if reqs and c_plen + c_new > self.max_len:
                    break
                plen, new = c_plen, c_new
                reqs.append(self.admission.pop(now))
            if reqs:
                self._start_batch(reqs)
        else:
            st = self._bstate
            self.utilization.record(
                active=sum(not r.done for r in self._batch),
                slots=self.max_batch,
            )
            st["cache"], logits = self._decode(
                self.params, st["cache"], st["tok"][:, None]
            )
            st["step"] += 1
            st["tok"] = self._sample(
                logits, st["temps"], st["tks"], st["tps"], st["seeds"],
                jnp.full((len(self._batch),), st["step"], jnp.int32),
                st["greedy_only"],
            )
            self._harvest(np.asarray(st["tok"]))
        self._retire_if_done()
        return self._drain_events()

    # -- internals ------------------------------------------------------
    def _start_batch(self, reqs: list[Request]) -> None:
        b = len(reqs)
        plen = max(len(r.prompt) for r in reqs)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(reqs):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (b, self.cfg.num_frontend_tokens, self.cfg.d_model),
                jnp.dtype(self.cfg.dtype),
            )
        if self.cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (b, plen, self.cfg.d_model), jnp.dtype(self.cfg.dtype)
            )
        cache, logits = self._prefill(self.params, batch)
        rows = [_Row(r, self._handles[r.uid]) for r in reqs]
        sp = [r.sampling for r in reqs]
        st = {
            "cache": cache,
            "step": 0,
            "greedy_only": all(s.temperature <= 0.0 for s in sp),
            "temps": jnp.asarray([s.temperature for s in sp], jnp.float32),
            "tks": jnp.asarray([s.top_k for s in sp], jnp.int32),
            "tps": jnp.asarray([s.top_p for s in sp], jnp.float32),
            "seeds": jnp.asarray([row.handle.seed for row in rows], jnp.int32),
        }
        st["tok"] = self._sample(
            logits, st["temps"], st["tks"], st["tps"], st["seeds"],
            jnp.zeros((b,), jnp.int32), st["greedy_only"],
        )
        self._batch, self._bstate = rows, st
        self._harvest(np.asarray(st["tok"]))

    def _harvest(self, toks: np.ndarray) -> None:
        now = time.perf_counter()
        idx = self._bstate["step"]
        for i, row in enumerate(self._batch):
            if row.done:
                continue
            if self._deliver(row.handle, int(toks[i]), idx, now):
                row.done = True


# ---------------------------------------------------------------------------
# continuous batching (scheduler/executor split)
# ---------------------------------------------------------------------------


class ContinuousBatchingEngine(EngineBase):
    """Paged-KV continuous batcher for decoder-only attention families.

    Protocol adapter over the scheduler/executor split:

    * the :class:`Scheduler` decides (host-only) — admission against the
      prefix index and the page pool, one prefill chunk per step
      interleaved with decode, youngest-first preemption under pool
      pressure, decode-batch assembly;
    * the :class:`ModelExecutor` computes (device-only) — one jitted
      sharded dispatch per chunk / per decode step over ``max_slots``
      fixed-width slots; idle or prefilling slots are masked (null block
      table, length 0) and their attention output discarded;
    * this class translates between them and the
      :class:`~repro.serving.api.EngineCore` lifecycle: handles, stream
      events, typed finishes, preemption-transparent requeueing.

    Sequences finish independently — their page refcounts drop (pages
    return to the pool at zero) and the slot is refilled from the waiting
    queue on the next step.

    With prefix sharing on, a :class:`~repro.serving.kv_tiers.KVTierManager`
    (``kv_tiers``; default follows ``prefix_sharing``) parks released
    prefix pages instead of freeing them, reclaiming them lazily under pool
    pressure; ``host_pages``/``persist_dir`` add host-RAM and
    ArtifactStore-backed spill tiers with async prefetch on prefix hits.
    ``kv_quant="int8"`` stores KV pages quantized per page per head, with
    dequantization fused into the paged attention kernels — roughly halving
    page bytes at equal pool capacity.
    """

    def __init__(
        self,
        cfg,
        params,
        *,
        max_len: int = 256,
        max_slots: int = 8,
        page_size: int = 16,
        num_pages: int | None = None,
        seed: int = 0,
        attn_impl: str | None = None,
        prefill_chunk: int | None = 64,
        prefix_sharing: bool = True,
        admission: AdmissionPolicy | None = None,
        max_preemptions: int | None = None,
        step_mode: str = "fused",
        token_budget: int | None = None,
        kv_quant: str = "none",
        kv_tiers: bool | None = None,
        host_pages: int = 0,
        persist_dir: str | None = None,
        speculative: str = "off",
        spec_k: int = 4,
        draft_config=None,
        draft_params=None,
    ):
        assert not cfg.is_encoder_decoder, "paged engine is decoder-only"
        assert cfg.family in ("dense", "moe", "vlm"), (
            f"continuous batching needs a paged KV path; family "
            f"{cfg.family!r} should use GenerationEngine"
        )
        self.cfg = cfg
        self.nf = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
        self.max_len = max_len
        self.max_slots = max_slots
        self.max_preemptions = max_preemptions
        if prefill_chunk == 0:  # CLI convention: 0 disables chunking
            prefill_chunk = None
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        # vlm prompts carry vision embeds: no token chunking, no prefix trie
        self._chunked = prefill_chunk is not None and cfg.family in ("dense", "moe")
        self.prefill_chunk = prefill_chunk
        self.prefix_sharing = prefix_sharing and self._chunked
        if step_mode not in ("fused", "interleaved"):
            raise ValueError(
                f"step_mode must be 'fused' or 'interleaved', got {step_mode!r}"
            )
        if token_budget is not None and token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, got {token_budget}")
        self.step_mode = step_mode
        self.token_budget = token_budget
        if kv_quant not in ("none", "int8"):
            raise ValueError(
                f"kv_quant must be 'none' or 'int8', got {kv_quant!r}"
            )
        # tiers default to on whenever the prefix index exists to park into
        # (kv_tiers=False forces them off for A/B runs; host/persist tiers
        # only engage when host_pages / persist_dir are set)
        if kv_tiers is None:
            kv_tiers = self.prefix_sharing
        self.tiers = (
            KVTierManager(
                host_pages=host_pages,
                store=(ArtifactStore(persist_dir)
                       if persist_dir is not None else None),
            )
            if kv_tiers and self.prefix_sharing else None
        )
        self.cache = PagedKVCache(
            num_layers=cfg.num_layers,
            num_kv_heads=cfg.eff_kv_heads,
            head_dim=cfg.head_dim,
            dtype=jnp.dtype(cfg.dtype),
            max_slots=max_slots,
            max_context=max_len,
            page_size=page_size,
            num_pages=num_pages,
            quant=kv_quant,
            tiers=self.tiers,
        )
        self.scheduler = Scheduler(
            self.cache,
            prefill_chunk=prefill_chunk,
            chunked=self._chunked,
            prefix_sharing=self.prefix_sharing,
            extra_ctx=self.nf,
            token_budget=token_budget,
        )
        self.executor = ModelExecutor(
            cfg, params, self.cache, max_len=max_len, attn_impl=attn_impl
        )
        self.model = self.executor.model
        self.params = self.executor.params
        # speculative decoding: a proposer drafts spec_k tokens per
        # decoding slot; the executor verifies each bundle in one fused
        # dispatch; rejected tails roll back by rewinding sequence length
        if speculative not in SPEC_MODES:
            raise ValueError(
                f"speculative must be one of {SPEC_MODES}, got {speculative!r}"
            )
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        self.spec_mode = speculative
        self.spec_k = spec_k
        self.spec = None
        if speculative != "off":
            if cfg.family not in ("dense", "moe"):
                raise ValueError(
                    f"speculative decoding needs the paged chunk path; "
                    f"family {cfg.family!r} has none"
                )
            if step_mode != "fused":
                raise ValueError(
                    "speculative decoding requires step_mode='fused'"
                )
            self.spec = build_proposer(
                speculative, draft_config=draft_config,
                draft_params=draft_params, max_slots=max_slots,
                max_len=max_len, page_size=page_size, seed=seed,
                attn_impl=attn_impl,
            )
        self._init_api(admission=admission, seed=seed)
        self.utilization = UtilizationMetrics()
        self.stats.update({"decode_steps": 0, "prefills": 0,
                           "prefill_chunks": 0, "preemptions": 0,
                           "spec_bundles": 0})

    # ------------------------------------------------------------------
    # EngineBase hooks
    # ------------------------------------------------------------------
    def _validate(self, request: Request) -> None:
        validate_request(request, max_len=self.max_len, extra_ctx=self.nf)
        ctx = self.nf + len(request.prompt)
        worst = cdiv(ctx + request.sampling.max_new_tokens,
                     self.cache.page_size)
        if worst > self.cache.num_pages - 1:
            raise ValueError(
                f"request {request.uid}: needs {worst} KV pages, pool has "
                f"{self.cache.num_pages - 1} — it could never be scheduled"
            )

    def _release_slot(self, slot: int) -> Sequence:
        """Release a slot and retire any proposer state for its uid —
        every engine-side release funnels through here so a finished or
        cancelled request can never leak a draft-cache slot."""
        seq = self.scheduler.release(slot)
        if self.spec is not None:
            self.spec.retire(seq.request.uid)
        return seq

    def _cancel_active(self, uid: str) -> bool:
        slot = self.scheduler.find(uid)
        if slot is None:
            return False
        seq = self._release_slot(slot)
        self._finish_handle(seq.handle, FinishReason.CANCELLED)
        return True

    # ------------------------------------------------------------------
    # protocol surface
    # ------------------------------------------------------------------
    @property
    def idle(self) -> bool:
        return not (len(self.admission) or self.scheduler.slots
                    or self._events)

    def capacity(self) -> int:
        return max(0, self.cache.free_slot_count - len(self.admission))

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _first_token(self, slot: int, seq: Sequence, tok: int) -> None:
        """Prompt fully cached: deliver the sampled first token (attempt
        index 0 — after a preemption the handle de-duplicates it)."""
        now = time.perf_counter()
        seq.tokens.append(tok)
        self.scheduler.begin_decode(slot)
        self.stats["prefills"] += 1
        if self._deliver(seq.handle, tok, 0, now):
            # finish event lands in THIS step's batch (admit/prefill run
            # before the decode harvest) — not delayed to the next one
            self._release_slot(slot)

    def _admit(self) -> int:
        now = time.perf_counter()
        self._expire_queue(now)
        admitted = 0
        while True:
            req = self.admission.peek(now)
            if req is None or not self.scheduler.can_place(req):
                break
            self.admission.pop(now)
            handle = self._handles[req.uid]
            slot, seq, _ = self.scheduler.place(req, handle)
            admitted += 1
            if not self._chunked:
                # legacy whole-prompt path (vlm / prefill_chunk=None):
                # one executor dispatch per admission
                tok = self.executor.prefill_whole(req, handle.seed, slot)
                self._first_token(slot, seq, tok)
        return admitted

    def _prefill_step(self) -> bool:
        """Advance the oldest in-flight prefill by one fixed-size chunk
        (scheduler picks, executor dispatches)."""
        work = self.scheduler.next_prefill()
        if work is None:
            return False
        tok = self.executor.prefill_chunk(work)
        self.stats["prefill_chunks"] += 1
        if self.scheduler.complete_chunk(work):
            self._first_token(work.slot, work.seq, tok)
        return True

    def _handle_preempted(self, seq: Sequence) -> None:
        """Bookkeeping for a sequence the scheduler evicted under pool
        pressure: requeue transparently (already-streamed deltas are never
        re-emitted) or finish ``preempted`` past ``max_preemptions``."""
        self.stats["preemptions"] += 1
        if self.spec is not None:
            self.spec.retire(seq.request.uid)
        h = seq.handle
        h.preemptions += 1
        if (self.max_preemptions is not None
                and h.preemptions > self.max_preemptions):
            self._finish_handle(
                h, FinishReason.PREEMPTED,
                error=f"request {h.uid}: preempted {h.preemptions} times "
                      f"(max_preemptions={self.max_preemptions})",
            )
        else:
            self._events.append(
                StreamEvent(h.uid, "preempted", t=time.perf_counter())
            )
            self.admission.requeue(seq.request, h.arrival)

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def step(self) -> list[StreamEvent]:
        """Run one engine step and return the lifecycle events produced
        (token deltas, finishes, preemptions).

        ``step_mode="fused"`` (default): admit, build ONE token-budgeted
        :class:`~repro.serving.scheduler.StepPlan` and dispatch it — every
        decode slot and (at most) one prefill chunk in a single
        Pallas-backed executor call. ``step_mode="interleaved"`` keeps the
        pre-fusion behavior (one chunk dispatch, then one decode dispatch)
        for A/B comparison; both modes produce byte-identical streams."""
        if self.step_mode == "interleaved":
            return self._step_interleaved()
        return self._step_fused()

    def _record_batch(self, decode_rows: int, prefill_live: int,
                      rows: int, fused: bool) -> None:
        self.utilization.record_batch(
            decode_rows=decode_rows, prefill_rows=prefill_live,
            padded_rows=rows - decode_rows - prefill_live, fused=fused,
        )

    def _dispatch_plan(self, plan) -> np.ndarray | None:
        """Run one plan through the executor and do the chunk bookkeeping
        (cursor advance, prefix publication, first-token delivery). Returns
        the decode tokens for the engine harvest (None: no decode rows)."""
        chunk, n_dec = plan.chunk, len(plan.decode_slots)
        rows = n_dec and self.max_slots
        if chunk is not None:
            rows += len(chunk.tokens)
        self._record_batch(n_dec, chunk.valid if chunk else 0, rows,
                           fused=bool(chunk is not None and n_dec))
        toks, ctok = self.executor.step(plan)
        if chunk is not None:
            self.stats["prefill_chunks"] += 1
            if self.scheduler.complete_chunk(chunk):
                self._first_token(chunk.slot, chunk.seq, ctok)
        return toks

    def _record_tiers(self) -> None:
        if self.tiers is not None:
            t = self.tiers
            self.utilization.record_tiers(
                parked=t.parked_count, host=t.host_count,
                persisted=t.persisted_count, counters=t.counters,
            )

    # ------------------------------------------------------------------
    # speculative decoding
    # ------------------------------------------------------------------
    def _propose_bundles(self) -> dict[int, list[int]]:
        """Ask the proposer for drafts for every decoding slot. ``k`` is
        capped so a fully-accepted bundle can neither overshoot the
        request's validated worst-case page budget (context + k + 1 must
        stay within max_pages_per_seq) nor draft past max_new_tokens
        (tokens beyond the finish are pure waste)."""
        out: dict[int, list[int]] = {}
        cache = self.cache
        ctx_cap = cache.max_pages_per_seq * cache.page_size
        for slot, seq in self.scheduler.decoding():
            sp = seq.request.sampling
            if not sp.speculative:
                continue
            k = min(self.spec_k,
                    sp.max_new_tokens - len(seq.tokens) - 1,
                    ctx_cap - int(cache.lengths[slot]) - 1)
            if k < 1:
                continue
            history = list(seq.request.prompt) + seq.tokens
            drafts = self.spec.propose(seq.request.uid, history, k)
            if drafts:
                out[slot] = drafts[:k]
        return out

    def _harvest_bundle(self, bundle, now: float) -> None:
        """Dispatch one verify bundle and commit its outcome.

        The verify step sampled a token for every bundle row under the
        same ``(seed, token_index)`` key sequential decode would have
        used, so acceptance is a pure host-side comparison: ``a`` = length
        of the leading run where the sampled token equals the draft. Rows
        0..a hold KV for tokens the sampler itself produced — commit
        advances the cached length to ``start + a + 1`` and the rejected
        tail is rewound by never advancing past it (append-only pages:
        nothing to free, nothing published — ``register_prefix`` only runs
        during prefill). ``sampled[a]`` is the bonus/correction token; its
        KV is not cached yet, exactly like a plain decode step's newest
        token. Emission goes through the same ``_deliver`` path as plain
        decode, so stop/length finishes mid-bundle release the slot and
        drop the unemitted remainder."""
        sched = self.scheduler
        toks = self.executor.verify(bundle)
        k = len(bundle.drafts)
        a = 0
        while a < k and int(toks[a]) == bundle.drafts[a]:
            a += 1
        sched.commit_speculation(bundle.slot, bundle.start + a + 1)
        self.stats["spec_bundles"] += 1
        self.utilization.record_spec(proposed=k, accepted=a,
                                     rollbacks=k - a)
        seq = bundle.seq
        for j in range(a + 1):
            tok = int(toks[j])
            sched.append_speculated(bundle.slot, tok)
            if self._deliver(seq.handle, tok, len(seq.tokens) - 1, now):
                self._release_slot(bundle.slot)
                break

    def _step_fused(self) -> list[StreamEvent]:
        sched = self.scheduler
        # publish last step's prefetched pages BEFORE admission matches
        # against the prefix index (pending pages stay invisible one step)
        self.cache.tick_tiers()
        self._admit()
        # with no decode in flight there is no stall to bound, so drain
        # chunk-only plans back-to-back until a sequence becomes decodable
        # (cold start, post-burst refill)
        while not sched.has_decodable():
            plan = sched.build_step_plan()
            if plan.chunk is None:
                return self._drain_events()
            self._dispatch_plan(plan)
            self._admit()

        # every decode row needs a writable page BEFORE the plan captures
        # block tables (growth/COW dirties them; eviction can also claim
        # the slot a chunk would have targeted). Speculating slots pre-grow
        # k extra positions so the verify dispatch never hits a page fault.
        proposals = (self._propose_bundles()
                     if self.spec is not None else {})
        extra = ({s: len(d) for s, d in proposals.items()}
                 if proposals else None)
        for seq in sched.ensure_decode_capacity(extra=extra):
            self._handle_preempted(seq)
        if not sched.has_decodable():
            return self._drain_events()  # preemption can empty the decode set

        decoding, slots = sched.occupancy()
        used, total = sched.page_utilization()
        self.utilization.record(active=decoding, slots=slots,
                                pages_used=used, pages_total=total)
        self._record_tiers()
        # eviction may have dropped a proposal's sequence — bundle only
        # slots that still hold the decoding sequence we drafted for
        bundles = [
            sched.build_spec_bundle(s, d, self.spec_k + 1)
            for s, d in sorted(proposals.items())
            if sched.slots.get(s) is not None
            and sched.slots[s].phase == "decode"
        ]
        plan = sched.build_step_plan(spec=bundles)
        toks = None
        if plan.decode_slots or plan.chunk is not None:
            toks = self._dispatch_plan(plan)
        self.stats["decode_steps"] += 1
        now = time.perf_counter()
        # harvest exactly the slots the plan dispatched — the chunk slot
        # may have become decodable mid-step and is NOT in this batch
        for slot in plan.decode_slots:
            seq = sched.slots[slot]
            tok = int(toks[slot])
            sched.append_decoded(slot, tok)
            if self._deliver(seq.handle, tok, len(seq.tokens) - 1, now):
                self._release_slot(slot)
        # bundled slots step through their verify dispatch instead
        for bundle in plan.spec or ():
            self._harvest_bundle(bundle, now)
        self._record_tiers()  # post-release: captures end-of-life parking
        return self._drain_events()

    def _step_interleaved(self) -> list[StreamEvent]:
        """Pre-fusion step: one chunk dispatch interleaved with one decode
        dispatch (kept for A/B against the fused step)."""
        sched = self.scheduler
        self.cache.tick_tiers()
        self._admit()
        ran = self._prefill_step()
        # the one-chunk-per-step cap exists to bound decode stalls; with no
        # decode in flight there is nothing to stall, so drain chunks
        # back-to-back until a sequence becomes decodable (cold start,
        # post-burst refill)
        while ran and not sched.has_decodable():
            self._admit()
            ran = self._prefill_step()
        if not sched.has_decodable():
            return self._drain_events()

        for seq in sched.ensure_decode_capacity():
            self._handle_preempted(seq)
        if not sched.has_decodable():
            return self._drain_events()  # preemption can empty the decode set

        decoding, slots = sched.occupancy()
        used, total = sched.page_utilization()
        self.utilization.record(active=decoding, slots=slots,
                                pages_used=used, pages_total=total)
        self._record_tiers()
        self._record_batch(decoding, 0, self.max_slots, fused=False)
        inputs = sched.build_decode_inputs() if sched.dirty else None
        toks = self.executor.decode(inputs)
        self.stats["decode_steps"] += 1
        now = time.perf_counter()
        for slot, seq in sched.decoding():
            tok = int(toks[slot])
            sched.append_decoded(slot, tok)
            if self._deliver(seq.handle, tok, len(seq.tokens) - 1, now):
                self._release_slot(slot)
        self._record_tiers()  # post-release: captures end-of-life parking
        return self._drain_events()
