"""Cache-tier manager behind :class:`~repro.serving.kv_cache.PagedKVCache`.

The page-pool capacity wall (ROADMAP item 3) is an *admission* problem: every
engine queues behind HBM-resident KV pages, yet the dominant workload —
notebook pipelines rerun repeatedly for reproduction — re-presents the same
prompt prefixes over and over with idle gaps in between. This module keeps
those prefixes alive across releases and lets them overflow HBM entirely.

Page state machine (one page moves strictly through these states)::

        alloc            release (last ref,        reclaim under
          |               page in prefix index)     pressure
          v                      |                     |
        LIVE  ----------------> PARKED  ------------> HOST  ----> PERSISTED
     (refcount>0)          (refcount 0, still      (numpy copy,   (ArtifactStore,
          ^                 device-resident,        device page    content-addressed,
          |   prefix hit    in the prefix index,    freed)         survives restart)
          +---- revive -----    reclaim-under-           |              |
          |                     pressure LRU)            +-- prefetch --+
          +-------------- async prefetch ----------------+   (on prefix-index hit)

* **PARKED** — a zero-refcount page whose prefix-index entry survives; it
  costs nothing until the pool runs dry, at which point
  ``PagedKVCache.reclaim_parked`` (called from ``can_admit`` /
  ``ensure_append_capacity`` *before* admission fails or preemption fires)
  spills the LRU parked pages and returns them to the free list.
* **HOST** — spilled page contents as numpy buffers keyed by *content key*
  (a sha256 chain over (parent content key, token chunk) — the content
  analogue of the device prefix index's (parent page id, chunk) key, stable
  across physical page reuse and process restarts). Capped at
  ``host_pages`` entries, LRU-evicted.
* **PERSISTED** — optional write-through of every spill into a
  ``core.storage.ArtifactStore`` (the repo's PV analogue); the content-key →
  ref index lives next to the objects as ``kv_prefix_index.json`` so a fresh
  process re-attaches to yesterday's prefixes.

Prefetch is *asynchronous at the dispatch level*: on a prefix-index walk
that runs past device residency, ``PagedKVCache.match_prefix(prefetch=True)``
allocates device pages, enqueues the host→device copies (jax dispatch is
async — the transfer overlaps host work) and registers the pages as parked
**pending**. Pending pages are treated as a miss until the engine's next
step calls ``tick()``, so the admission that triggered the prefetch waits
one step without ever blocking the step itself.

This class is deliberately device-free: it owns policy (LRU order, the
pending set, tier capacities) and host/persisted bytes. All device work —
page reads/writes, allocation, refcounts — stays in ``PagedKVCache``.
"""

from __future__ import annotations

import hashlib
import json
from collections import OrderedDict

import numpy as np

from repro.core.storage import ArtifactStore

_INDEX_NAME = "kv_prefix_index.json"


def chain_key(parent: bytes, chunk) -> bytes:
    """Content key of one full page: sha256 over (parent key, token chunk).

    Root pages chain from ``b""``. Unlike the device prefix index's
    (parent *page id*, chunk) key, this names the prefix by content only,
    so it survives physical page reuse, spill/reload and process restarts.
    """
    h = hashlib.sha256(parent)
    h.update(np.asarray(tuple(chunk), np.int64).tobytes())
    return h.digest()


class KVTierManager:
    """Parked-LRU + host-RAM + persisted tiers for prefix KV pages.

    ``parked`` maps device page id -> content key in LRU order (oldest
    first); ``host`` maps content key -> per-array numpy page blocks;
    ``persist_index`` maps hex content key -> per-array ArtifactStore refs.
    ``pending`` holds device page ids whose host→device prefetch was
    dispatched this step; :meth:`tick` publishes them.

    ``counters`` is purely additive (ints/floats only) so metrics trackers
    can snapshot/delta/merge it without knowing the key set.
    """

    def __init__(
        self,
        *,
        host_pages: int = 0,
        store: ArtifactStore | None = None,
        persist_tier: str = "node",
    ):
        self.host_pages = int(host_pages)
        self.store = store
        self.persist_tier = persist_tier
        self.parked: OrderedDict[int, bytes] = OrderedDict()
        self.pending: set[int] = set()
        self.host: OrderedDict[bytes, dict[str, np.ndarray]] = OrderedDict()
        self.persist_index: dict[str, dict[str, str]] = {}
        if store is not None:
            idx = store.root / _INDEX_NAME
            if idx.exists():
                self.persist_index = json.loads(idx.read_text())
        self.counters: dict[str, float] = {
            "prefix_queries": 0,
            "device_hits": 0,      # parked pages revived in place
            "host_hits": 0,        # pages prefetched back from host RAM
            "persist_hits": 0,     # pages prefetched back from the store
            "prefetched_pages": 0,
            "prefetch_bytes": 0,
            "prefetch_s": 0.0,
            "spilled_pages": 0,
            "spill_bytes": 0,
            "spill_s": 0.0,
            "reclaimed_pages": 0,  # parked pages returned to the free list
        }

    # ------------------------------------------------------------------
    # parked tier (device-resident, refcount 0)
    # ------------------------------------------------------------------
    def park(self, page: int, ck: bytes) -> None:
        assert page not in self.parked, page
        self.parked[page] = ck

    def unpark(self, page: int) -> bytes:
        self.pending.discard(page)
        return self.parked.pop(page)

    def touch(self, page: int) -> None:
        """Move a matched parked page to the MRU end (protects a prefix that
        is being re-queried from reclaim racing its own admission)."""
        if page in self.parked:
            self.parked.move_to_end(page)

    def pop_lru(self, skip: set[int]) -> tuple[int, bytes] | None:
        """Oldest parked page not in ``skip`` (and not prefetch-pending)."""
        for page, ck in self.parked.items():
            if page not in skip and page not in self.pending:
                del self.parked[page]
                return page, ck
        return None

    def tick(self) -> None:
        """Publish prefetched pages: the engine calls this once per step, so
        every transfer dispatched during the previous step's admission pass
        has a full dispatch round to land before anyone can match it."""
        self.pending.clear()

    # ------------------------------------------------------------------
    # host + persisted tiers (content-key addressed)
    # ------------------------------------------------------------------
    def spill(self, ck: bytes, arrays: dict[str, np.ndarray]) -> None:
        """Demote one page's contents out of HBM: write-through to the store
        (when configured) and into the host LRU (when capacity allows)."""
        nbytes = sum(a.nbytes for a in arrays.values())
        self.counters["spilled_pages"] += 1
        self.counters["spill_bytes"] += nbytes
        if self.store is not None:
            hx = ck.hex()
            if hx not in self.persist_index:
                self.persist_index[hx] = {
                    key: self.store.put(a, tier=self.persist_tier, name=f"kv.{key}")
                    for key, a in arrays.items()
                }
                self._save_index()
        if self.host_pages > 0:
            self.host[ck] = arrays
            self.host.move_to_end(ck)
            while len(self.host) > self.host_pages:
                # write-through above means evicted entries are already
                # persisted (or deliberately droppable): just forget them
                self.host.popitem(last=False)

    def lookup(self, ck: bytes) -> dict[str, np.ndarray] | None:
        """Fetch one page's contents from host RAM, else the store.

        A host hit *promotes*: the entry moves back to device (the caller
        uploads it), so it leaves the host LRU. Persisted entries are
        immutable and stay."""
        arrays = self.host.pop(ck, None)
        if arrays is not None:
            self.counters["host_hits"] += 1
            return arrays
        if self.store is not None:
            refs = self.persist_index.get(ck.hex())
            if refs is not None:
                self.counters["persist_hits"] += 1
                return {key: self.store.get(ref) for key, ref in refs.items()}
        return None

    def _save_index(self) -> None:
        (self.store.root / _INDEX_NAME).write_text(
            json.dumps(self.persist_index)
        )

    # ------------------------------------------------------------------
    @property
    def wants_spill(self) -> bool:
        """False when reclaimed contents have nowhere to go (device-parking
        only): the caller skips the device read entirely."""
        return self.host_pages > 0 or self.store is not None

    @property
    def parked_count(self) -> int:
        return len(self.parked)

    @property
    def host_count(self) -> int:
        return len(self.host)

    @property
    def persisted_count(self) -> int:
        return len(self.persist_index)
