"""Fault-tolerant serving fleet: supervised engine workers over the bus.

This is the PR that fuses the Jup2Kub orchestration layer (paper §3.5:
supervised pods, liveness/readiness probes, restart, HPA) with the serving
arc. A :class:`FleetSupervisor` runs N :class:`EngineWorker` pods — in this
repo's pod model a pod is a host thread with a kill switch, exactly like
``core/executor.WorkerPod`` (one XLA-compiled engine per OS process would
put a multi-minute compile inside every tier-1 restart; the thread model
keeps the *protocol* identical while the bus, the only coupling between
supervisor and worker, stays process-shape-agnostic — ``launch/serve.py
--role worker`` runs the same loop as a real separate process against a
shared bus directory).

Topics (all on one ``core.bus.TopicBus``):

* ``requests``      — client ingress (same schema as ``launch/serve.py``).
* ``fleet.work``    — supervisor -> workers. Workers share one consumer
  group; claims are serialized by a claim lock, and each claim publishes
  an ``accept`` *before* committing, so a worker that dies mid-claim
  either leaves the message uncommitted (redelivered) or leaves an accept
  on the log (the supervisor knows the owner and resubmits). At-least-once
  either way; duplicates are harmless because delivery de-duplicates.
* ``fleet.events``  — workers -> supervisor: ``accept`` / ``delta`` /
  ``finish``. The supervisor relays deltas to ``responses``.
* ``fleet.control`` — cancel broadcast; every worker attempt replays the
  full history, so cancels outlive the worker that first received them.
* ``health``        — heartbeats (``core/probes.py``); a beat carries the
  worker's token counter as forward progress plus ``busy``, so a
  livelocked worker (beating, busy, zero progress) is detected, not just
  a dead one.
* ``responses``     — supervisor -> clients, same delta/finish schema as
  ``launch/serve.py``, but **exactly-once per token index**.

The recovery algorithm (the point of this module): the supervisor tracks
per-request delivery state — every token it has relayed, keyed by index.
When a worker dies, each of its in-flight requests is resubmitted to the
work topic with the *same seed*; because sampling is keyed off
``(seed, token_index)`` and placement-independent (PR 3), the replacement
worker regenerates a byte-identical stream, and the supervisor forwards
only the first occurrence of each index — the client stream resumes at
exactly the next undelivered token, with no token re-emitted or skipped
across the crash boundary. A duplicate delta whose token differs from the
recorded one would falsify that contract; the supervisor counts it
(``FleetMetrics.mismatched_deltas``) and the chaos tests pin it at zero.

Requests that were cancelled and then orphaned by a crash are finished
``cancelled`` directly by the supervisor instead of being resubmitted —
a cancel must never resurrect work, and must never hang.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from statistics import fmean

from repro.core.autoscaler import AutoscalerConfig, ServingAutoscaler
from repro.core.bus import TopicBus
from repro.core.events import EventLog
from repro.core.executor import PodKilled
from repro.core.faults import FaultInjector
from repro.core.podspec import PodSpec
from repro.core.probes import HealthMonitor, HeartbeatWriter
from repro.serving.api import request_from_message
from repro.serving.metrics import FleetMetrics

WORK_TOPIC = "fleet.work"
EVENTS_TOPIC = "fleet.events"
CONTROL_TOPIC = "fleet.control"
REQUESTS_TOPIC = "requests"
RESPONSES_TOPIC = "responses"
SUPERVISOR_GROUP = "fleet-supervisor"
WORKER_GROUP = "fleet-workers"


def fleet_seed(seed_base: int, n: int) -> int:
    """Seed stamped on the n-th ingressed request when the client left
    ``seed`` unset. Same derivation as ``EngineBase.submit`` so a seeded
    single-engine oracle replay of the trace reproduces the fleet's
    streams byte-for-byte."""
    return (seed_base * 1_000_003 + n) & 0x7FFFFFFF


@dataclass
class FleetConfig:
    workers: int = 2                   # initial replica count
    min_replicas: int = 1
    max_replicas: int = 4
    target_lag_per_replica: float = 4.0
    target_occupancy: float | None = 0.85
    scale_down_grace_s: float = 0.5
    autoscale: bool = True
    liveness_window_s: float = 5.0     # heartbeat gap -> dead
    livelock_window_s: float | None = None  # busy w/o progress -> restart
    beat_interval_s: float = 0.02      # min spacing between heartbeats
    seed_base: int = 1234              # for stamping unseeded requests
    max_restarts: int = 5              # attempts per worker name
    idle_sleep_s: float = 0.002

    @classmethod
    def from_spec(cls, spec: PodSpec, **overrides) -> "FleetConfig":
        """Derive the runtime supervision parameters from a Listing-1
        :class:`PodSpec` (``core/podspec.serving_worker_spec``): replica
        count and probe cadence come from the spec, the rest from
        defaults/overrides — the same object that renders the k8s YAML
        drives the in-process fleet."""
        kw = dict(
            workers=spec.replicas,
            beat_interval_s=spec.liveness_interval_s / 2.0,
            liveness_window_s=spec.liveness_interval_s * 2.5,
        )
        kw.update(overrides)
        return cls(**kw)


@dataclass
class RequestState:
    """Supervisor-side delivery ledger for one request: the payload it can
    resubmit verbatim (seed included), every token relayed so far (the
    dedupe reference), and crash-recovery bookkeeping."""

    uid: str
    payload: dict
    tokens: list[int] = field(default_factory=list)
    finish_reason: str | None = None
    error: str | None = None
    owner: str | None = None           # pod id of the accepting worker
    cancel_requested: bool = False
    resubmits: int = 0
    resume_from: int = 0               # next undelivered index at crash
    t_crash: float | None = None       # pending recovery-latency stopwatch
    recovery_s: float | None = None


class EngineWorker:
    """One supervised serving pod: a thread running the ``launch/serve.py``
    worker loop (claim -> submit -> step -> publish) over a fresh engine,
    with heartbeats and a deterministic chaos hook.

    The chaos hook is :meth:`FaultInjector.check_worker`, consulted
    synchronously at every loop top on this attempt's own progress
    counters — a kill therefore lands at an exact (steps, tokens) point in
    this worker's execution regardless of thread scheduling, which is what
    keeps the fleet chaos tests reproducible. Death is silent by design:
    a killed worker publishes nothing further (no finish, no goodbye
    beat), exactly like a SIGKILLed pod.
    """

    def __init__(self, name: str, attempt: int, bus: TopicBus, engine_factory,
                 claim_lock: threading.Lock, cfg: FleetConfig,
                 injector: FaultInjector | None = None):
        self.name = name
        self.attempt = attempt
        self.pod_id = f"{name}-a{attempt}"
        self.bus = bus
        self.engine_factory = engine_factory
        self.claim_lock = claim_lock
        self.cfg = cfg
        self.injector = injector
        self.stop = threading.Event()       # supervisor-initiated shutdown
        self.draining = threading.Event()   # stop claiming, finish in-flight
        self.stopped_cleanly = False
        self.handled = False                # supervisor bookkeeping
        self.kill_reason: str | None = None
        self.error: str | None = None
        self.steps_run = 0                  # this attempt
        self.tokens_emitted = 0             # this attempt
        self.inflight: set[str] = set()
        self.gauge: dict = {}               # last-step occupancy snapshot
        self.thread = threading.Thread(
            target=self._run, name=self.pod_id, daemon=True)

    def start(self) -> None:
        self.thread.start()

    def retire(self) -> None:
        """Graceful scale-down: claim nothing more, drain, then exit."""
        self.draining.set()

    # ------------------------------------------------------------------
    def _run(self) -> None:
        hb = HeartbeatWriter(self.bus, self.pod_id)
        try:
            engine = self.engine_factory()
            hb.ready()
            self._loop(engine, hb)
            self.stopped_cleanly = True
        except PodKilled:
            pass  # crash: silence — the supervisor must *detect* this
        except BaseException as e:  # noqa: BLE001 — a pod death is a pod death
            self.error = repr(e)

    def _loop(self, engine, hb: HeartbeatWriter) -> None:
        accepted: set[str] = set()
        cancelled: set[str] = set()
        handles: dict[str, object] = {}
        ctl_cursor = 0
        last_beat = 0.0
        while not self.stop.is_set():
            if self.injector is not None:
                reason = self.injector.check_worker(
                    self.name, self.attempt,
                    steps=self.steps_run, tokens=self.tokens_emitted)
                if reason is not None:
                    self.kill_reason = reason
                    raise PodKilled(reason)
            # cancels: replay from the start of the topic each attempt, so
            # a cancel issued before this worker existed still applies
            for m in self.bus.read(CONTROL_TOPIC, start=ctl_cursor):
                ctl_cursor = m.offset + 1
                if m.value.get("kind") == "cancel":
                    uid = str(m.value["uid"])
                    cancelled.add(uid)
                    engine.cancel(uid)
            if not self.draining.is_set():
                self._claim(engine, accepted, cancelled, handles)
            now = time.monotonic()
            if now - last_beat >= self.cfg.beat_interval_s:
                last_beat = now
                hb.beat(progress=self.tokens_emitted, busy=not engine.idle)
            if engine.idle:
                if self.draining.is_set():
                    return
                time.sleep(self.cfg.idle_sleep_s)
                continue
            for ev in engine.step():
                if ev.kind == "token":
                    self.tokens_emitted += 1
                    self.bus.publish(EVENTS_TOPIC, {
                        "kind": "delta", "uid": ev.uid, "token": ev.token,
                        "index": ev.index, "worker": self.pod_id,
                    })
                elif ev.kind == "finish":
                    self.inflight.discard(ev.uid)
                    h = handles.pop(ev.uid, None)
                    self.bus.publish(EVENTS_TOPIC, {
                        "kind": "finish", "uid": ev.uid,
                        "finish_reason": ev.finish_reason.value,
                        "error": getattr(h, "error", None),
                        "worker": self.pod_id,
                    })
            self.steps_run += 1
            u = engine.utilization
            self.gauge = {
                "slot_occupancy": u.slot_samples[-1] if u.slot_samples else 0.0,
                "page_util": u.page_samples[-1] if u.page_samples else None,
            }

    def _claim(self, engine, accepted: set[str], cancelled: set[str],
               handles: dict) -> None:
        cap = engine.capacity()
        if cap <= 0:
            return
        with self.claim_lock:
            for m in self.bus.consume(WORK_TOPIC, WORKER_GROUP, limit=cap):
                v = m.value
                uid = str(v.get("uid", "?")) if isinstance(v, dict) else "?"
                if uid in accepted:  # at-least-once redelivery
                    self.bus.commit(WORK_TOPIC, WORKER_GROUP, m.offset + 1)
                    continue
                try:
                    req = request_from_message(v)
                except (ValueError, KeyError, TypeError) as e:
                    self.bus.publish(EVENTS_TOPIC, {
                        "kind": "finish", "uid": uid,
                        "finish_reason": "rejected", "error": str(e),
                        "worker": self.pod_id,
                    })
                    self.bus.commit(WORK_TOPIC, WORKER_GROUP, m.offset + 1)
                    continue
                h = engine.submit(req)
                accepted.add(uid)
                if h.done:  # rejected at the API boundary
                    self.bus.publish(EVENTS_TOPIC, {
                        "kind": "finish", "uid": uid,
                        "finish_reason": h.finish_reason.value,
                        "error": h.error, "worker": self.pod_id,
                    })
                else:
                    # accept BEFORE commit: die between the two and the
                    # supervisor still learns who owned this uid
                    self.bus.publish(EVENTS_TOPIC, {
                        "kind": "accept", "uid": uid, "worker": self.pod_id,
                    })
                    self.inflight.add(uid)
                    handles[uid] = h
                    if uid in cancelled:
                        engine.cancel(uid)
                self.bus.commit(WORK_TOPIC, WORKER_GROUP, m.offset + 1)


class FleetSupervisor:
    """Supervises N engine workers: ingress, delta relay with exactly-once
    per-index delivery, crash detection + resubmit recovery, livelock
    restart, and lag/occupancy-driven autoscaling.

    Drive it with :meth:`poll` (one supervision round, synchronous — the
    chaos tests interleave assertions between rounds) or :meth:`run`
    (poll until every expected request is terminal). Workers are real
    threads; everything the supervisor knows arrives via the bus or
    ``Thread.is_alive()``, so the supervisor itself is single-threaded
    and deterministic given the bus logs.
    """

    def __init__(self, bus: TopicBus, engine_factory,
                 cfg: FleetConfig | None = None,
                 injector: FaultInjector | None = None,
                 events: EventLog | None = None):
        self.bus = bus
        self.engine_factory = engine_factory
        self.cfg = cfg or FleetConfig()
        self.injector = injector
        self.events = events or EventLog(bus, workflow="serving-fleet")
        self.metrics = FleetMetrics()
        self.monitor = HealthMonitor(
            bus, liveness_window_s=self.cfg.liveness_window_s,
            livelock_window_s=self.cfg.livelock_window_s)
        self.scaler: ServingAutoscaler | None = None
        if self.cfg.autoscale:
            self.scaler = ServingAutoscaler(
                bus, WORK_TOPIC, WORKER_GROUP,
                AutoscalerConfig(
                    min_replicas=self.cfg.min_replicas,
                    max_replicas=self.cfg.max_replicas,
                    target_lag_per_replica=self.cfg.target_lag_per_replica,
                    scale_down_grace_s=self.cfg.scale_down_grace_s,
                    target_occupancy=self.cfg.target_occupancy,
                ),
                events=self.events, current=self.cfg.workers,
                gauges=self.gauges)
        self.states: dict[str, RequestState] = {}
        self.workers: dict[str, EngineWorker] = {}
        self._claim_lock = threading.Lock()
        self._spawned = 0
        self._ingressed = 0
        self._started = False

    # -- lifecycle ------------------------------------------------------
    def start(self) -> None:
        if self._started:
            return
        self._started = True
        for _ in range(self.cfg.workers):
            self._spawn()

    def shutdown(self) -> None:
        for w in self.workers.values():
            w.stop.set()
        for w in self.workers.values():
            w.thread.join(timeout=10)

    def _spawn(self, name: str | None = None, attempt: int = 0) -> EngineWorker:
        if name is None:
            name = f"w{self._spawned}"
            self._spawned += 1
        w = EngineWorker(name, attempt, self.bus, self.engine_factory,
                         self._claim_lock, self.cfg, injector=self.injector)
        self.workers[name] = w
        w.start()
        return w

    # -- client surface -------------------------------------------------
    def submit(self, payload: dict) -> None:
        """Client ingress helper: publish one request payload (the
        ``launch/serve.py`` schema) onto the requests topic."""
        self.bus.publish(REQUESTS_TOPIC, payload)

    def cancel(self, uid: str) -> bool:
        """Broadcast a cancel. Terminal state is guaranteed: a live owner
        cancels through its engine; an owner that dies first is caught by
        the failure handler, which finishes orphaned cancels directly."""
        st = self.states.get(uid)
        if st is None or st.finish_reason is not None:
            return False
        st.cancel_requested = True
        self.bus.publish(CONTROL_TOPIC, {"kind": "cancel", "uid": uid})
        return True

    def results(self) -> dict[str, RequestState]:
        return dict(self.states)

    def gauges(self) -> dict:
        """Aggregate last-step engine gauges over live workers — the
        occupancy signal the autoscaler folds in on top of consumer lag."""
        occ, pages = [], []
        for w in self.workers.values():
            g = w.gauge
            if not g or not w.thread.is_alive():
                continue
            occ.append(g.get("slot_occupancy", 0.0))
            if g.get("page_util") is not None:
                pages.append(g["page_util"])
        return {
            "slot_occupancy_mean": fmean(occ) if occ else 0.0,
            "page_util_mean": fmean(pages) if pages else 0.0,
        }

    # -- supervision ----------------------------------------------------
    def poll(self) -> None:
        """One supervision round: ingress -> relay -> detect failures ->
        reconcile replica count."""
        self.start()
        self._ingress()
        self._drain_events()
        self._detect_failures()
        self._reconcile()

    def run(self, expected: list[str] | None = None, timeout_s: float = 120.0,
            poll_s: float = 0.002) -> bool:
        """Poll until every expected uid (default: every ingressed request)
        is terminal. Returns False on timeout — callers assert on it."""
        deadline = time.monotonic() + timeout_s
        while time.monotonic() < deadline:
            self.poll()
            if self._complete(expected):
                return True
            time.sleep(poll_s)
        return False

    def _complete(self, expected: list[str] | None) -> bool:
        if self.bus.lag(REQUESTS_TOPIC, SUPERVISOR_GROUP) > 0:
            return False
        if expected is not None:
            return all(
                u in self.states and self.states[u].finish_reason is not None
                for u in expected)
        return bool(self.states) and all(
            st.finish_reason is not None for st in self.states.values())

    # -- ingress --------------------------------------------------------
    def _ingress(self) -> None:
        for m in self.bus.consume(REQUESTS_TOPIC, SUPERVISOR_GROUP, limit=64):
            v = m.value
            if isinstance(v, dict) and "uid" in v:
                uid = str(v["uid"])
                payload = dict(v)
                if payload.get("seed") is None:
                    # stamp a deterministic seed NOW: recovery replays this
                    # exact payload, so the regenerated stream is identical
                    payload["seed"] = fleet_seed(self.cfg.seed_base,
                                                 self._ingressed)
                self._ingressed += 1
                if uid in self.states:
                    self.bus.publish(RESPONSES_TOPIC, {
                        "uid": uid, "event": "finish", "tokens": [],
                        "finish_reason": "rejected",
                        "error": f"request {uid}: uid already in flight",
                    })
                else:
                    self.states[uid] = RequestState(uid, payload)
                    self.bus.publish(WORK_TOPIC, payload)
            else:
                self.bus.publish(RESPONSES_TOPIC, {
                    "uid": "?", "event": "finish", "tokens": [],
                    "finish_reason": "rejected", "error": "malformed payload",
                })
            self.bus.commit(REQUESTS_TOPIC, SUPERVISOR_GROUP, m.offset + 1)

    # -- worker events --------------------------------------------------
    def _drain_events(self) -> None:
        for m in self.bus.consume(EVENTS_TOPIC, SUPERVISOR_GROUP, limit=512):
            v = m.value
            kind = v.get("kind")
            st = self.states.get(str(v.get("uid")))
            if st is not None:
                if kind == "accept":
                    # latest accept wins: on resubmit the new owner replaces
                    # the dead one
                    st.owner = v["worker"]
                elif kind == "delta":
                    self._on_delta(st, v)
                elif kind == "finish":
                    self._on_finish(st, v)
            self.bus.commit(EVENTS_TOPIC, SUPERVISOR_GROUP, m.offset + 1)

    def _on_delta(self, st: RequestState, v: dict) -> None:
        if st.finish_reason is not None:
            return  # late delta from a zombie attempt after cancel/finish
        idx, tok = int(v["index"]), int(v["token"])
        if idx < len(st.tokens):
            # regenerated prefix from a resubmit (or a zombie's duplicate):
            # drop it, but CHECK it — replay-identical recovery means the
            # regenerated token must equal what was already delivered
            self.metrics.duplicate_deltas += 1
            if st.tokens[idx] != tok:
                self.metrics.mismatched_deltas += 1
            return
        if idx > len(st.tokens):
            self.metrics.gapped_deltas += 1  # must never happen
            return
        st.tokens.append(tok)
        self.bus.publish(RESPONSES_TOPIC, {
            "uid": st.uid, "event": "delta", "token": tok, "index": idx,
        })
        if st.t_crash is not None and idx >= st.resume_from:
            st.recovery_s = time.monotonic() - st.t_crash
            self.metrics.record_recovery(st.recovery_s)
            st.t_crash = None

    def _on_finish(self, st: RequestState, v: dict) -> None:
        if st.finish_reason is not None:
            return  # first finish wins (zombie/redelivery duplicates)
        st.finish_reason = v["finish_reason"]
        st.error = v.get("error")
        self._publish_finish(st)

    def _publish_finish(self, st: RequestState) -> None:
        self.bus.publish(RESPONSES_TOPIC, {
            "uid": st.uid, "event": "finish", "tokens": list(st.tokens),
            "finish_reason": st.finish_reason, "error": st.error,
        })

    # -- failure detection + recovery ----------------------------------
    def _detect_failures(self) -> None:
        for name, w in list(self.workers.items()):
            if w.handled:
                continue
            if not w.thread.is_alive():
                if w.stopped_cleanly:
                    w.handled = True
                    self.monitor.forget(w.pod_id)
                    del self.workers[name]
                else:
                    self._handle_failure(name, w, w.kill_reason or w.error
                                         or "died")
        if self.cfg.livelock_window_s is not None:
            for pod, state in self.monitor.unhealthy_pods():
                if state != "livelocked":
                    continue
                for name, w in list(self.workers.items()):
                    if w.pod_id == pod and not w.handled:
                        w.stop.set()  # best effort; zombie output dedupes
                        self._handle_failure(name, w, "livelocked")

    def _handle_failure(self, name: str, w: EngineWorker, reason: str) -> None:
        w.handled = True
        # the worker is confirmed dead, so every delta it ever published is
        # already on the bus: drain once more so resume_from is the true
        # next-undelivered index (otherwise an undrained pre-crash tail
        # would stop the recovery stopwatch without any replay happening)
        self._drain_events()
        self.monitor.forget(w.pod_id)
        self.metrics.crashes += 1
        self.events.emit("worker_failed", step=name, attempt=w.attempt,
                         reason=reason)
        now = time.monotonic()
        for st in self.states.values():
            if st.owner != w.pod_id or st.finish_reason is not None:
                continue
            st.owner = None
            if st.cancel_requested:
                # cancelled then orphaned: never resubmit, never hang
                st.finish_reason = "cancelled"
                self.metrics.direct_cancels += 1
                self._publish_finish(st)
            else:
                st.t_crash = now
                st.resume_from = len(st.tokens)
                st.resubmits += 1
                self.metrics.resubmitted += 1
                self.bus.publish(WORK_TOPIC, st.payload)
        del self.workers[name]
        if w.attempt + 1 <= self.cfg.max_restarts:
            self.metrics.restarts += 1
            self.events.emit("worker_restarted", step=name,
                             attempt=w.attempt + 1, reason=reason)
            self._spawn(name, attempt=w.attempt + 1)

    # -- autoscaling ----------------------------------------------------
    def _reconcile(self) -> None:
        active = [w for w in self.workers.values()
                  if not w.handled and not w.draining.is_set()
                  and w.thread.is_alive()]
        desired = len(active)
        if self.scaler is not None:
            desired, _ = self.scaler.observe()
        for _ in range(max(0, desired - len(active))):
            active.append(self._spawn())
        extra = len(active) - desired
        if extra > 0:
            # retire the emptiest workers; draining finishes in-flight work
            for w in sorted(active, key=lambda w: len(w.inflight))[:extra]:
                w.retire()


__all__ = [
    "EngineWorker",
    "FleetConfig",
    "FleetSupervisor",
    "RequestState",
    "fleet_seed",
]
