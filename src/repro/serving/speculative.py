"""Speculative decoding over the paged engine: proposers + bundle policy.

Decode is one token per dispatch, so in the low-batch interactive regime
(the notebook-rerun workload the paper targets) per-step dispatch overhead
dominates and tokens/sec sits far below the roofline. Speculation breaks
the serial chain: a cheap *proposer* drafts ``k`` candidate tokens, the
target model scores all of them in ONE fused dispatch (``models/lm.py::
verify_step_paged`` — exactly a k+1-token prefill chunk over the slot's
own block table), and the engine keeps the longest prefix that agrees
with what the ``(seed, token_index)``-keyed sampler would have produced
one token at a time. Pages are append-only per sequence, so rejecting the
tail is a pure host-side length rewind — no page is freed, published, or
parked on the rejected range.

Two proposers live behind one duck-typed interface (``propose(uid,
history, k)`` / ``retire(uid)``):

* :class:`NgramProposer` — self-speculation with no second model: match
  the last n tokens of the request's own prompt+output history against
  the earlier history and propose the continuation of the most recent
  match. Free to run and surprisingly strong on the rerun workload, where
  outputs quote their own prompts and loops abound.
* :class:`DraftModelProposer` — a small draft model (e.g. smollm drafting
  for llama3-8b-reduced) decoding greedily ``k`` steps ahead. It owns a
  separate :class:`~repro.serving.kv_cache.PagedKVCache` with its own
  block tables, so the target pool's COW/refcounting is untouched; draft
  KV follows the same append-only/rewind discipline as the target
  (divergence rewinds to the common prefix, never copies).

The engine only ever asks "what comes next for this history" — proposers
never see pages, slots, or the scheduler, which is what keeps the
acceptance/rollback proof local to ``engine.py``.
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

__all__ = [
    "SpeculativeProposer",
    "NgramProposer",
    "DraftModelProposer",
    "build_proposer",
]

SPEC_MODES = ("off", "ngram", "draft")


@runtime_checkable
class SpeculativeProposer(Protocol):
    """What the engine needs from a proposer — nothing engine-shaped."""

    def propose(self, uid: str, history: list[int], k: int) -> list[int]:
        """Up to ``k`` drafted continuations of ``history`` (prompt +
        emitted tokens). An empty list means "no idea": the engine falls
        back to the plain decode row for that step."""
        ...

    def retire(self, uid: str) -> None:
        """The request finished/was evicted: drop any per-uid state."""
        ...


class NgramProposer:
    """Prompt/self-speculation: propose the continuation of the most
    recent earlier occurrence of the history's n-token suffix.

    Longest match wins (``n`` down to 1), most recent occurrence wins
    within a match length — recency tracks the local repetition structure
    (code loops, quoted prompts) better than first occurrence. Stateless
    across calls, so ``retire`` is a no-op and preemption/replay cannot
    desynchronize it."""

    def __init__(self, n: int = 3):
        assert n >= 1, n
        self.n = n

    def propose(self, uid: str, history: list[int], k: int) -> list[int]:
        # iterate the lookup on history+drafts: a match near the end of
        # history (short cycles — THE high-acceptance regime) yields a
        # short continuation, and re-matching extends it to the full k
        drafts: list[int] = []
        while len(drafts) < k:
            cont = self._match(history + drafts, k - len(drafts))
            if not cont:
                break
            drafts.extend(cont)
        return drafts

    def _match(self, h: list[int], k: int) -> list[int]:
        ln = len(h)
        if k <= 0 or ln < 2:
            return []
        for m in range(min(self.n, ln - 1), 0, -1):
            pat = h[ln - m:]
            for j in range(ln - m - 1, -1, -1):
                if h[j:j + m] == pat:
                    cont = h[j + m:j + m + k]
                    if cont:
                        return list(cont)
                    break  # suffix-adjacent match: shorter m may still hit
        return []

    def retire(self, uid: str) -> None:  # stateless
        return None


class DraftModelProposer:
    """Greedy k-step lookahead with a small draft model on its OWN paged
    cache.

    Per request the proposer keeps ``(slot, cached)``: a draft-cache slot
    and the token list whose KV that slot holds (position i caches
    ``cached[i]``). Each ``propose`` rewinds to the longest common prefix
    of ``cached`` and the true history (rejected drafts fall away for
    free — append-only pages, same rewind rule as the target), catches up
    on new history via chunked prefill, then decodes ``k`` tokens
    greedily. The draft pool is sized like the target's but entirely
    separate: different layer count/head shape anyway, and isolation is
    what keeps the target's COW/refcount proof untouched by speculation.
    """

    def __init__(self, cfg, params=None, *, max_slots: int = 8,
                 max_len: int = 256, page_size: int = 16, seed: int = 0,
                 chunk: int = 32, attn_impl: str | None = None):
        import jax

        from ..models import build_model
        from .kv_cache import PagedKVCache

        self.cfg = cfg
        self.model = build_model(
            cfg, **({"attn_impl": attn_impl} if attn_impl else {})
        )
        if params is None:
            params = self.model.init(jax.random.key(seed))
        self.params = params
        self.max_len = max_len
        self.chunk = chunk
        import jax.numpy as jnp

        self.cache = PagedKVCache(
            num_layers=cfg.num_layers,
            num_kv_heads=cfg.eff_kv_heads,
            head_dim=cfg.head_dim,
            dtype=jnp.dtype(cfg.dtype),
            max_slots=max_slots,
            max_context=max_len,
            page_size=page_size,
        )
        self._prefill = jax.jit(self.model.prefill_chunk, donate_argnums=(1,))
        self._decode = jax.jit(
            self.model.decode_step_paged, donate_argnums=(1,)
        )
        self._state: dict[str, dict] = {}  # uid -> {"slot", "cached"}

    # -- internals ----------------------------------------------------
    def _rewind(self, st: dict, history: list[int], target: int) -> int:
        """Rewind the slot to the longest common prefix of what its pages
        hold and what the history now demands (<= target positions)."""
        cached = st["cached"]
        cp = 0
        m = min(len(cached), target)
        while cp < m and cached[cp] == history[cp]:
            cp += 1
        del cached[cp:]
        self.cache.lengths[st["slot"]] = cp
        return cp

    def _catch_up(self, st: dict, history: list[int], target: int) -> None:
        """Chunk-prefill history[cp:target] into the slot's pages."""
        import jax.numpy as jnp

        slot, cached = st["slot"], st["cached"]
        pos = len(cached)
        if pos >= target:
            return
        self.cache.ensure_append_capacity(slot, target - pos)
        row = jnp.asarray(self.cache.block_tables[slot])
        while pos < target:
            step = min(self.chunk, target - pos)
            buf = np.zeros(self.chunk, np.int32)
            buf[:step] = history[pos:pos + step]
            new_pages, _ = self._prefill(
                self.params, dict(self.cache.pages), row,
                jnp.asarray(buf), jnp.int32(pos), jnp.int32(step),
            )
            self.cache.swap_pages(new_pages)
            pos += step
        cached.extend(history[len(cached):target])
        self.cache.lengths[slot] = target

    # -- proposer interface -------------------------------------------
    def propose(self, uid: str, history: list[int], k: int) -> list[int]:
        import jax.numpy as jnp

        target = len(history) - 1  # positions cached before drafting
        k = min(k, self.max_len - len(history))
        if k <= 0 or target < 1:
            return []
        st = self._state.get(uid)
        if st is None:
            if self.cache.free_slot_count == 0 and self._state:
                # engine retires uids on finish/evict; this is a backstop
                self.retire(next(iter(self._state)))
            if self.cache.free_slot_count == 0:
                return []
            try:
                slot, _ = self.cache.admit(target)
            except RuntimeError:
                return []
            self.cache.lengths[slot] = 0  # admit reserves; nothing cached
            st = self._state[uid] = {"slot": slot, "cached": []}
        slot = st["slot"]
        self._rewind(st, history, target)
        try:
            self._catch_up(st, history, target)
            self.cache.ensure_append_capacity(slot, k)
        except RuntimeError:
            return []  # draft pool full: skip speculation this step
        bt = jnp.asarray(self.cache.block_tables[slot:slot + 1])
        drafts: list[int] = []
        last = history[-1]
        cur = target
        for _ in range(k):
            new_pages, logits = self._decode(
                self.params, dict(self.cache.pages), bt,
                jnp.asarray([cur], jnp.int32),
                jnp.asarray([[last]], jnp.int32),
            )
            self.cache.swap_pages(new_pages)
            st["cached"].append(last)
            cur += 1
            last = int(np.argmax(
                np.asarray(logits[0, :self.cfg.vocab_size])
            ))
            drafts.append(last)
        self.cache.lengths[slot] = cur
        return drafts

    def retire(self, uid: str) -> None:
        st = self._state.pop(uid, None)
        if st is not None:
            self.cache.release(st["slot"])


def build_proposer(mode: str, *, draft_config=None, draft_params=None,
                   ngram_n: int = 3, max_slots: int = 8, max_len: int = 256,
                   page_size: int = 16, seed: int = 0,
                   attn_impl: str | None = None):
    """Resolve an engine ``speculative=`` kwarg into a proposer instance.

    ``draft_config`` may be a ModelConfig or an arch name (resolved via
    ``repro.configs.get_arch``, ``-reduced`` suffix honored); fresh
    seed-derived params are initialized when ``draft_params`` is None —
    fine for benchmarks, real deployments pass trained draft weights."""
    if mode == "ngram":
        return NgramProposer(n=ngram_n)
    if mode == "draft":
        if draft_config is None:
            raise ValueError("speculative='draft' needs draft_config")
        if isinstance(draft_config, str):
            from ..configs import get_arch

            draft_config = get_arch(draft_config)
        return DraftModelProposer(
            draft_config, draft_params, max_slots=max_slots,
            max_len=max_len, page_size=page_size, seed=seed,
            attn_impl=attn_impl,
        )
    raise ValueError(
        f"speculative must be one of {SPEC_MODES}, got {mode!r}"
    )
