"""Block-table KV cache: fixed-size pages allocated from a shared pool.

The device side is two arrays per model — ``k_pages``/``v_pages`` of shape
(L, P, page_size, KVH, Dh) — plus per-step int32 inputs (block tables and
lengths), so the jitted decode step sees ONE static shape no matter how many
sequences are in flight or how long each one is. The host side is a free-list
allocator (:class:`PagePool`) and per-slot bookkeeping (:class:`PagedKVCache`)
that hands the engine ready-to-transfer block tables.

Page 0 is reserved as the **null page**: unused block-table entries and idle
decode slots point at it, so the kernel's gathers never go out of bounds and
idle-slot writes land in a sink nobody reads (reads are masked by length).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NULL_PAGE = 0


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


class PagePool:
    """LIFO free-list allocator over physical page ids [1, num_pages)."""

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least the null page + one real page"
        self.num_pages = num_pages
        # LIFO so recently-freed (cache-warm) pages are reused first
        self._free = list(range(num_pages - 1, 0, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        """Pop n pages; raises RuntimeError when the pool is exhausted."""
        assert n > 0, n  # n=0 would slice the whole free list without popping
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: want {n}, have {len(self._free)}"
            )
        taken = self._free[-n:][::-1]
        del self._free[len(self._free) - n:]
        return taken

    def free(self, pages: list[int]) -> None:
        for p in pages:
            assert p != NULL_PAGE, "cannot free the null page"
            self._free.append(p)


class PagedKVCache:
    """Device page pool + host block tables for up to ``max_slots`` sequences.

    The engine owns the jitted functions; this class owns allocation state
    and the current device arrays (which the engine swaps after each donated
    decode/prefill-write call via :meth:`set_pages`).
    """

    def __init__(
        self,
        *,
        num_layers: int,
        num_kv_heads: int,
        head_dim: int,
        dtype,
        max_slots: int,
        max_context: int,
        page_size: int = 16,
        num_pages: int | None = None,
    ):
        self.page_size = page_size
        self.max_slots = max_slots
        self.max_pages_per_seq = cdiv(max_context, page_size)
        if num_pages is None:  # worst case: every slot at max context, + null
            num_pages = max_slots * self.max_pages_per_seq + 1
        self.num_pages = num_pages
        shape = (num_layers, num_pages, page_size, num_kv_heads, head_dim)
        self.k_pages = jnp.zeros(shape, dtype)
        self.v_pages = jnp.zeros(shape, dtype)

        self.pool = PagePool(num_pages)
        self.block_tables = np.full(
            (max_slots, self.max_pages_per_seq), NULL_PAGE, np.int32
        )
        self.lengths = np.zeros((max_slots,), np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(max_slots)]
        self._free_slots = list(range(max_slots - 1, -1, -1))

    # ------------------------------------------------------------------
    # slots
    # ------------------------------------------------------------------
    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    def can_admit(self, context_len: int) -> bool:
        return (
            bool(self._free_slots)
            and self.pool.available >= cdiv(max(context_len, 1), self.page_size)
        )

    def admit(self, context_len: int) -> int:
        """Claim a slot and pages for an initial context of ``context_len``."""
        assert context_len <= self.max_pages_per_seq * self.page_size, (
            context_len, self.max_pages_per_seq * self.page_size)
        slot = self._free_slots.pop()
        pages = self.pool.alloc(cdiv(max(context_len, 1), self.page_size))
        self._slot_pages[slot] = pages
        self.block_tables[slot] = NULL_PAGE
        self.block_tables[slot, : len(pages)] = pages
        self.lengths[slot] = context_len
        return slot

    def ensure_append_capacity(self, slot: int) -> bool:
        """Make sure position ``lengths[slot]`` has a page before a decode
        step writes there (on-demand growth at page boundaries). Returns
        True when a page was allocated (the block table changed); raises
        RuntimeError when the pool is exhausted (callers may preempt)."""
        need = int(self.lengths[slot]) // self.page_size
        pages = self._slot_pages[slot]
        if need == len(pages):
            (new,) = self.pool.alloc(1)
            pages.append(new)
            self.block_tables[slot, need] = new
            return True
        return False

    def append(self, slot: int) -> None:
        """Record that the decode step wrote one token for this slot."""
        self.lengths[slot] += 1

    def release(self, slot: int) -> None:
        self.pool.free(self._slot_pages[slot])
        self._slot_pages[slot] = []
        self.block_tables[slot] = NULL_PAGE
        self.lengths[slot] = 0
        self._free_slots.append(slot)

    # ------------------------------------------------------------------
    # device views
    # ------------------------------------------------------------------
    def device_tables(self) -> tuple[jax.Array, jax.Array]:
        """Device copies of (block_tables, lengths).

        MUST copy: ``jnp.asarray`` may alias (or lazily transfer) the host
        numpy buffer, and these arrays are mutated in place between decode
        steps — an aliased buffer races with async device reads and shows up
        as stale block tables / lengths (observed on the CPU backend as
        dropped KV writes and off-by-one attention masks).
        """
        return jnp.asarray(self.block_tables.copy()), jnp.asarray(self.lengths.copy())

    def device_row(self, slot: int) -> jax.Array:
        """Device copy of one slot's block-table row (same aliasing rule)."""
        return jnp.asarray(self.block_tables[slot].copy())

    def set_pages(self, k_pages: jax.Array, v_pages: jax.Array) -> None:
        self.k_pages, self.v_pages = k_pages, v_pages

    def gather_dense(self, slot: int) -> tuple[np.ndarray, np.ndarray]:
        """Reassemble a slot's K/V as dense (L, len, KVH, Dh) — tests only."""
        k = np.asarray(self.k_pages)
        v = np.asarray(self.v_pages)
        n = int(self.lengths[slot])
        pages = self._slot_pages[slot]
        out_k = np.concatenate([k[:, p] for p in pages], axis=1)[:, :n]
        out_v = np.concatenate([v[:, p] for p in pages], axis=1)[:, :n]
        return out_k, out_v


def write_prefill_pages(
    k_pages: jax.Array,   # (L, P, page, KVH, Dh) — donated by the caller's jit
    v_pages: jax.Array,
    k_new: jax.Array,     # (L, S, KVH, Dh) dense prefill K (S may be padded)
    v_new: jax.Array,
    table_row: jax.Array,  # (MP,) int32 physical page per logical page
    valid_len: jax.Array,  # scalar int32: positions < valid_len are real
) -> tuple[jax.Array, jax.Array]:
    """Scatter one sequence's dense prefill K/V into its pages.

    Padded positions (>= valid_len) are routed out of bounds and dropped —
    bucketed prompt padding never lands anywhere, and every surviving
    scatter index is unique (duplicate-index scatter order is undefined).
    """
    num_pages, page = k_pages.shape[1:3]
    s = k_new.shape[1]
    pos = jnp.arange(s)
    phys = jnp.where(pos < valid_len, table_row[pos // page], num_pages)
    off = pos % page
    k_pages = k_pages.at[:, phys, off].set(k_new, mode="drop")
    v_pages = v_pages.at[:, phys, off].set(v_new, mode="drop")
    return k_pages, v_pages
