"""Block-table KV cache: refcounted pages with prefix sharing + copy-on-write.

The device side is a dict of page-pool arrays per model — ``pages["k"]`` /
``pages["v"]`` of shape (L, P, page_size, KVH, Dh), plus per-(position, head)
``pages["k_scale"]`` / ``pages["v_scale"]`` of shape (L, P, page_size, KVH)
when the pool is int8-quantized — plus per-step int32 inputs (block tables
and lengths), so the jitted decode step sees ONE static shape no matter how
many sequences are in flight or how long each one is. The host side is a
refcounted free-list allocator (:class:`PagePool`) and per-slot bookkeeping
(:class:`PagedKVCache`) that hands the engine ready-to-transfer block tables.

Sharded serving (the scheduler/executor split) places the pool on a
``("model",)`` mesh sharded along the **kv-head** dim only
(:meth:`PagedKVCache._reshard`): every shard then holds the same physical
pages for its slice of heads, so page ids, block tables, refcounts and the
prefix index below are shard-invariant and stay SINGLE host-side
structures — nothing in this module knows how many devices exist. The
copy-on-write page copy (:func:`_copy_page`) slices along the page dim,
which keeps the head sharding intact.

Page 0 is reserved as the **null page**: unused block-table entries and idle
decode slots point at it, so the kernel's gathers never go out of bounds and
idle-slot writes land in a sink nobody reads (reads are masked by length).

Sharing model:

* Every page carries a **refcount**. A page is physically freed (returned to
  the free list) only when its refcount reaches zero, so two sequences can
  map the same physical page and release independently.
* A **prefix index** maps the token content of a chain of full pages to the
  physical page holding its K/V. Keys are hash-chained — (parent physical
  page, this page's token chunk), root = the null page — so lookup and
  registration are O(1) per page, and a page is only reused when the
  ENTIRE prefix matches (the parent id names the whole chain), not just
  that page's tokens.
  :meth:`PagedKVCache.admit` consults it to map shared full pages read-only;
  matches are capped below the prompt's last token (the engine always needs
  at least one position's logits, and recomputing it must never write into
  a shared page).
* **Copy-on-write**: :meth:`ensure_append_capacity` copies a page (device
  page-granular copy, donated buffers so XLA updates in place) before a
  sequence writes into a page whose refcount is > 1. With admission-time
  sharing restricted to full pages this only triggers after :meth:`fork`,
  which maps *all* of a sequence's pages — including the partial tail —
  into a second slot.
* **Tiers** (:mod:`repro.serving.kv_tiers`, optional): with a
  :class:`~repro.serving.kv_tiers.KVTierManager` attached, a prefix-index
  page whose last reference drops is **parked** (refcount 0, device-resident,
  still matchable) instead of freed, and :meth:`reclaim_parked` — invoked
  from :meth:`can_admit` / the allocation path before admission fails or
  preemption fires — spills the LRU parked pages to host RAM / an
  ``ArtifactStore`` and returns them to the free list. A prefix-index walk
  past device residency asynchronously prefetches spilled pages back
  (:meth:`match_prefix` with ``prefetch=True``); the engine publishes the
  transfers one step later via :meth:`tick_tiers`. See the state-machine
  diagram in ``kv_tiers.py``.

Pages are registered into the prefix index by the engine *after* the prefill
chunk that fills them has been dispatched (dispatch order = execution order
on one device stream), so a concurrent admission can never read a shared
page before its contents exist.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import dequantize_pages, quantize_kv
from repro.serving.kv_tiers import KVTierManager, chain_key

NULL_PAGE = 0


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


class PagePool:
    """Refcounted LIFO free-list allocator over physical page ids [1, num_pages).

    ``alloc`` hands out pages with refcount 1; ``incref`` adds a sharer;
    ``decref`` returns the page to the free list when the count hits zero.

    Tiered caches add a third state between live and free: ``park`` drops a
    page to refcount 0 WITHOUT returning it to the free list (the page stays
    device-resident and matchable), ``revive`` claims a parked page back to
    refcount 1, and ``reclaim`` finally free-lists a parked page. The owner
    (:class:`PagedKVCache`) tracks WHICH pages are parked; the pool only
    enforces the refcount transitions.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least the null page + one real page"
        self.num_pages = num_pages
        # LIFO so recently-freed (cache-warm) pages are reused first
        self._free = list(range(num_pages - 1, 0, -1))
        self.refcounts = np.zeros((num_pages,), np.int32)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        """Pop n pages (each refcount 1); RuntimeError when exhausted."""
        assert n > 0, n  # n=0 would slice the whole free list without popping
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: want {n}, have {len(self._free)}"
            )
        taken = self._free[-n:][::-1]
        del self._free[len(self._free) - n:]
        for p in taken:
            self.refcounts[p] = 1
        return taken

    def incref(self, page: int) -> None:
        assert page != NULL_PAGE and self.refcounts[page] > 0, page
        self.refcounts[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        assert page != NULL_PAGE, "cannot free the null page"
        assert self.refcounts[page] > 0, f"decref of free page {page}"
        self.refcounts[page] -= 1
        if self.refcounts[page] == 0:
            self._free.append(page)
            return True
        return False

    def free(self, pages: list[int]) -> None:
        for p in pages:
            self.decref(p)

    # -- parked-tier transitions (refcount 0, NOT on the free list) --------
    def park(self, page: int) -> None:
        assert page != NULL_PAGE and self.refcounts[page] == 1, page
        self.refcounts[page] = 0

    def revive(self, page: int) -> None:
        assert page != NULL_PAGE and self.refcounts[page] == 0, page
        self.refcounts[page] = 1

    def reclaim(self, page: int) -> None:
        assert self.refcounts[page] == 0, page
        self._free.append(page)


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_page(pages, src, dst):
    """Copy one physical page (all layers, every pool array — K, V and any
    quantization scales) src -> dst, in place (donated)."""
    def cp(arr):
        blk = jax.lax.dynamic_slice_in_dim(arr, src, 1, axis=1)
        return jax.lax.dynamic_update_slice_in_dim(arr, blk, dst, axis=1)
    return {key: cp(arr) for key, arr in pages.items()}


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_page(arr, page, data):
    """Write one physical page (all layers) from a host block, in place.

    The page dim (axis 1) is never sharded, so the update preserves the
    kv-head sharding; dispatch is async, which is what makes tier prefetch
    overlap the step that triggered it."""
    return jax.lax.dynamic_update_slice_in_dim(arr, data, page, axis=1)


class PagedKVCache:
    """Device page pool + host block tables for up to ``max_slots`` sequences.

    The engine owns the jitted functions; this class owns allocation state
    (slots, refcounts, the prefix index) and the current device arrays
    (which the engine swaps after each donated decode/prefill-write call via
    :meth:`swap_pages`).

    ``quant="int8"`` stores K/V as int8 with one f32 scale per
    (page, position, kv head) in ``pages["k_scale"]``/``pages["v_scale"]``
    — ~4x more pages per HBM byte; the paged kernels fuse the dequant.
    ``tiers`` attaches a :class:`~repro.serving.kv_tiers.KVTierManager`
    (see module docstring).
    """

    def __init__(
        self,
        *,
        num_layers: int,
        num_kv_heads: int,
        head_dim: int,
        dtype,
        max_slots: int,
        max_context: int,
        page_size: int = 16,
        num_pages: int | None = None,
        quant: str = "none",
        tiers: KVTierManager | None = None,
    ):
        assert quant in ("none", "int8"), quant
        self.page_size = page_size
        self.max_slots = max_slots
        self.max_pages_per_seq = cdiv(max_context, page_size)
        if num_pages is None:  # worst case: every slot at max context, + null
            num_pages = max_slots * self.max_pages_per_seq + 1
        self.num_pages = num_pages
        self.quant = quant
        self.tiers = tiers
        shape = (num_layers, num_pages, page_size, num_kv_heads, head_dim)
        store_dtype = jnp.int8 if quant == "int8" else dtype
        self.pages: dict[str, jax.Array] = {
            "k": jnp.zeros(shape, store_dtype),
            "v": jnp.zeros(shape, store_dtype),
        }
        if quant == "int8":
            self.pages["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            self.pages["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)

        self.pool = PagePool(num_pages)
        self.block_tables = np.full(
            (max_slots, self.max_pages_per_seq), NULL_PAGE, np.int32
        )
        self.lengths = np.zeros((max_slots,), np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(max_slots)]
        self._free_slots = list(range(max_slots - 1, -1, -1))
        # prefix index: (parent physical page, token chunk) -> physical page
        self._prefix_index: dict[tuple, int] = {}
        self._page_key: dict[int, tuple] = {}  # reverse map for dereg on free
        # content key per indexed page (kv_tiers.chain_key): names the prefix
        # by token content, so it survives spill/reload and page-id reuse
        self._page_ck: dict[int, bytes] = {}
        self.stats = {"prefix_hits": 0, "prefix_tokens_reused": 0,
                      "cow_copies": 0}

    # ------------------------------------------------------------------
    # prefix index
    # ------------------------------------------------------------------
    def _prefix_limit(self, tokens) -> int:
        """Number of full pages eligible for sharing: capped strictly below
        the last token, so recomputing the sampling position never writes
        into a shared page (see module docstring)."""
        return max(0, (len(tokens) - 1) // self.page_size)

    def match_prefix(self, tokens, prefetch: bool = False) -> tuple[list[int], int]:
        """Longest chain of registered full pages matching ``tokens``.

        Keys are hash-chained, (parent physical page, this page's token
        chunk) — O(1) per level instead of rehashing the whole prefix —
        with NULL_PAGE as the chain root. A parent page id uniquely names
        its prefix because every sharer of a child page also holds the
        parent (prefix structure), so a parent entry can never be freed
        (and its id recycled) while a child entry survives.

        Tier semantics: prefetch-PENDING pages (host→device copy dispatched
        this step, published next step by :meth:`tick_tiers`) count as a
        miss, so an admission never maps a page whose transfer it cannot
        know has landed. With ``prefetch=True`` (the :meth:`can_admit`
        path only), a walk that runs past device residency looks the next
        chunks up by content key in the host/persisted tiers and dispatches
        their uploads — the triggering request then waits a step (deferred
        admission) without blocking anyone else.

        Returns (pages, matched_token_count). Aside from prefetch, read
        only: the caller (:meth:`admit`) takes the references.
        """
        ps = self.page_size
        tiers = self.tiers
        pages: list[int] = []
        parent = NULL_PAGE
        limit = self._prefix_limit(tokens)
        for i in range(limit):
            page = self._prefix_index.get(
                (parent, tuple(tokens[i * ps:(i + 1) * ps]))
            )
            if page is None or (tiers is not None and page in tiers.pending):
                break
            pages.append(page)
            parent = page
        if tiers is not None:
            for p in pages:  # matched parked pages move to the MRU end
                tiers.touch(p)
            if prefetch:
                self._prefetch_chain(pages, tokens, limit)
        return pages, len(pages) * ps

    def _prefetch_chain(self, matched: list[int], tokens, limit: int) -> None:
        """Extend a device-resident prefix from the host/persisted tiers.

        Each hit allocates a device page, dispatches the upload (async),
        registers the page in the prefix index and parks it PENDING. The
        walk stops at the first tier miss, at a page some other query is
        already prefetching, or when taking one more page would leave the
        pool unable to cover the rest of this prompt (prefetch must never
        starve the admission it serves)."""
        tiers = self.tiers
        ps = self.page_size
        i = len(matched)
        parent = matched[-1] if matched else NULL_PAGE
        parent_ck = self._page_ck.get(parent, b"")
        total = cdiv(len(tokens), ps)
        while i < limit:
            chunk = tuple(tokens[i * ps:(i + 1) * ps])
            if (parent, chunk) in self._prefix_index:
                break  # already resident (pending from an earlier query)
            if self.pool.available < total - i:
                break
            ck = chain_key(parent_ck, chunk)
            arrays = tiers.lookup(ck)
            if arrays is None:
                break
            t0 = time.perf_counter()
            (page,) = self.pool.alloc(1)
            self._upload_page(page, arrays)
            self.pool.park(page)
            key = (parent, chunk)
            self._prefix_index[key] = page
            self._page_key[page] = key
            self._page_ck[page] = ck
            tiers.park(page, ck)
            tiers.pending.add(page)
            tiers.counters["prefetched_pages"] += 1
            tiers.counters["prefetch_bytes"] += sum(
                a.nbytes for a in arrays.values()
            )
            tiers.counters["prefetch_s"] += time.perf_counter() - t0
            parent, parent_ck = page, ck
            i += 1

    def _next_is_pending(self, matched: list[int], tokens) -> bool:
        """True when the first chunk past the device match maps to a page
        whose prefetch is still pending — the caller should defer admission
        one step instead of re-prefilling a prefix that is already in flight."""
        if self.tiers is None:
            return False
        i = len(matched)
        if i >= self._prefix_limit(tokens):
            return False
        ps = self.page_size
        parent = matched[-1] if matched else NULL_PAGE
        page = self._prefix_index.get(
            (parent, tuple(tokens[i * ps:(i + 1) * ps]))
        )
        return page is not None and page in self.tiers.pending

    def register_prefix(self, slot: int, tokens, upto: int) -> None:
        """Publish ``slot``'s full pages covering ``tokens[:upto]`` into the
        prefix index. MUST only be called once the K/V for those positions
        has been dispatched (the index hands these pages to other slots).

        Keys chain through THIS slot's own pages (not a previously
        registered twin): the slot provably keeps its own parent alive, so
        child entries never dangle behind a freed/recycled parent id. If a
        twin chain registered first (concurrent identical prefills), ours
        becomes an unreachable side chain — a missed match, never a wrong
        one — and admission deferral makes that window rare."""
        ps = self.page_size
        parent = NULL_PAGE
        parent_ck = b""
        for i in range(min(upto, len(tokens)) // ps):
            chunk = tuple(tokens[i * ps:(i + 1) * ps])
            key = (parent, chunk)
            page = self._slot_pages[slot][i]
            if key not in self._prefix_index:
                self._prefix_index[key] = page
                self._page_key[page] = key
                if self.tiers is not None:
                    self._page_ck[page] = chain_key(parent_ck, chunk)
            parent = page
            if self.tiers is not None:
                parent_ck = chain_key(parent_ck, chunk)

    def _deregister(self, page: int) -> None:
        key = self._page_key.pop(page, None)
        if key is not None:
            del self._prefix_index[key]
        self._page_ck.pop(page, None)

    # ------------------------------------------------------------------
    # tiers: park / reclaim / prefetch plumbing
    # ------------------------------------------------------------------
    def _drop_ref(self, page: int) -> None:
        """Drop one reference; a prefix-index page whose LAST reference
        drops is parked (tiers on) instead of freed, so a later rerun of
        the same prompt still matches it."""
        if (self.tiers is not None and page in self._page_key
                and self.pool.refcounts[page] == 1):
            self.pool.park(page)
            self.tiers.park(page, self._page_ck[page])
        elif self.pool.decref(page):
            self._deregister(page)

    def _alloc(self, n: int) -> list[int]:
        """``pool.alloc`` that reclaims parked pages under pressure first."""
        if self.tiers is not None and self.pool.available < n:
            self.reclaim_parked(n - self.pool.available)
        return self.pool.alloc(n)

    def reclaim_parked(self, n: int, protect=()) -> int:
        """Spill and free at least ``n`` parked pages (LRU first); returns
        how many were actually freed (0 when the tier is off or empty).

        Freeing a page whose id is a prefix-index *parent* would let the id
        recycle under surviving child entries (an ABA wrong-match), and a
        child whose parent left the index is unreachable anyway — so each
        reclaim cascades over the page's index descendants. Descendants of
        a parked page are provably parked too (any live holder of a child
        also holds the parent), so the cascade never touches a live slot.
        Contents are spilled to the host/persisted tiers before the device
        page is reused; content keys keep the spilled chain matchable."""
        if self.tiers is None or n <= 0:
            return 0
        tiers = self.tiers
        protect = set(protect)
        freed = 0
        while freed < n:
            got = tiers.pop_lru(protect)
            if got is None:
                break
            batch = [got]
            i = 0
            while i < len(batch):  # gather index descendants (all parked)
                parent_page = batch[i][0]
                i += 1
                for child, key in list(self._page_key.items()):
                    if key[0] == parent_page:
                        assert child in tiers.parked, (child, key)
                        batch.append((child, tiers.unpark(child)))
            t0 = time.perf_counter()
            for page, ck in batch:
                if tiers.wants_spill:
                    tiers.spill(ck, self._read_page(page))
                self._deregister(page)
                self.pool.reclaim(page)
                freed += 1
            tiers.counters["spill_s"] += time.perf_counter() - t0
            tiers.counters["reclaimed_pages"] += len(batch)
        return freed

    def tick_tiers(self) -> None:
        """Publish pending prefetches; the engine calls this once per step."""
        if self.tiers is not None:
            self.tiers.tick()

    def flush_tiers(self) -> int:
        """Spill and free EVERY parked page (idle demotion, or persisting
        the prefix cache before a planned restart). Returns pages freed."""
        if self.tiers is None:
            return 0
        self.tiers.tick()
        return self.reclaim_parked(len(self.tiers.parked))

    @property
    def parked_count(self) -> int:
        return 0 if self.tiers is None else len(self.tiers.parked)

    def _read_page(self, page: int) -> dict[str, np.ndarray]:
        """One physical page's contents (all layers) as host arrays."""
        return {key: np.asarray(arr[:, page]) for key, arr in self.pages.items()}

    def _upload_page(self, page: int, arrays: dict[str, np.ndarray]) -> None:
        """Dispatch (async) the device writes restoring one spilled page."""
        idx = jnp.asarray(page, jnp.int32)
        for key in self.pages:
            data = jnp.asarray(arrays[key][:, None])
            self.pages[key] = _write_page(self.pages[key], idx, data)

    # ------------------------------------------------------------------
    # slots
    # ------------------------------------------------------------------
    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    def can_admit(self, context_len: int, tokens=None) -> bool:
        """Admission check — with tiers attached this is also where the
        pressure valve lives: parked pages are reclaimed BEFORE the check
        can fail, and a prompt whose spilled prefix is mid-prefetch waits
        (returns False) rather than re-prefilling it."""
        if not self._free_slots:
            return False
        need = cdiv(max(context_len, 1), self.page_size)
        matched: list[int] = []
        if tokens is not None:
            if self.tiers is not None:
                self.tiers.counters["prefix_queries"] += 1
            matched = self.match_prefix(tokens, prefetch=True)[0]
            need -= len(matched)
            if self._next_is_pending(matched, tokens):
                return False
        if self.pool.available < need:
            self.reclaim_parked(need - self.pool.available, protect=matched)
        return self.pool.available >= need

    def admit(self, context_len: int, tokens=None) -> tuple[int, int]:
        """Claim a slot and pages for an initial context of ``context_len``.

        When ``tokens`` (the prompt) is given, full pages already holding a
        matching prefix are mapped read-only (refcount bumped; parked pages
        are revived in place) instead of allocated. Returns
        (slot, cached_len) — the caller only needs to prefill positions
        >= cached_len.
        """
        assert context_len <= self.max_pages_per_seq * self.page_size, (
            context_len, self.max_pages_per_seq * self.page_size)
        shared: list[int] = []
        cached = 0
        if tokens is not None:
            shared, cached = self.match_prefix(tokens)
        slot = self._free_slots.pop()
        for p in shared:
            if self.tiers is not None and p in self.tiers.parked:
                self.tiers.unpark(p)
                self.pool.revive(p)
                self.tiers.counters["device_hits"] += 1
            else:
                self.pool.incref(p)
        fresh = cdiv(max(context_len, 1), self.page_size) - len(shared)
        try:
            pages = shared + (self._alloc(fresh) if fresh > 0 else [])
        except RuntimeError:
            for p in shared:  # revived parked pages re-park, sharers decref
                self._drop_ref(p)
            self._free_slots.append(slot)
            raise
        if shared:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_tokens_reused"] += cached
        self._slot_pages[slot] = pages
        self.block_tables[slot] = NULL_PAGE
        self.block_tables[slot, : len(pages)] = pages
        self.lengths[slot] = context_len
        return slot, cached

    def fork(self, src_slot: int) -> int:
        """Map every page of ``src_slot`` (including the partial tail) into a
        fresh slot, copy-on-write. The clone starts at the same length; the
        first append into a still-shared page triggers exactly one copy."""
        assert self._slot_pages[src_slot], f"slot {src_slot} is empty"
        slot = self._free_slots.pop()
        pages = list(self._slot_pages[src_slot])
        for p in pages:
            self.pool.incref(p)
        self._slot_pages[slot] = pages
        self.block_tables[slot] = self.block_tables[src_slot]
        self.lengths[slot] = self.lengths[src_slot]
        return slot

    def ensure_append_capacity(self, slot: int, n: int = 1) -> bool:
        """Make sure positions ``lengths[slot] .. lengths[slot]+n-1`` are
        writable before a dispatch lands there: allocates a page at page
        boundaries (on-demand growth) and copy-on-writes a shared page
        anywhere else. ``n=1`` is the plain decode step; a speculative
        verify bundle passes ``n = k+1`` so every drafted position is
        writable BEFORE the single fused dispatch scatters them (rollback
        then only rewinds ``lengths`` — over-provisioned tail pages stay
        owned by the slot and are reused by the next append). Returns True
        when the block table changed; raises RuntimeError when the pool is
        exhausted (callers may preempt) — with tiers attached, parked pages
        are reclaimed first, so preemption is truly the last resort. On a
        mid-range RuntimeError the pages already granted remain recorded in
        the slot's table (no leak; the caller retries or preempts)."""
        changed = False
        length = int(self.lengths[slot])
        pages = self._slot_pages[slot]
        for pos in range(length, length + n):
            need = pos // self.page_size
            if need == len(pages):
                (new,) = self._alloc(1)
                pages.append(new)
                self.block_tables[slot, need] = new
                changed = True
                continue
            old = pages[need]
            if self.pool.refcounts[old] > 1:  # shared: copy before the write
                (new,) = self._alloc(1)
                self.pages = _copy_page(
                    self.pages,
                    jnp.asarray(old, jnp.int32), jnp.asarray(new, jnp.int32),
                )
                self.pool.decref(old)  # shared, so never frees here
                pages[need] = new
                self.block_tables[slot, need] = new
                self.stats["cow_copies"] += 1
                changed = True
        return changed

    def append(self, slot: int) -> None:
        """Record that the decode step wrote one token for this slot."""
        self.lengths[slot] += 1

    def release(self, slot: int) -> None:
        for p in self._slot_pages[slot]:
            self._drop_ref(p)
        self._slot_pages[slot] = []
        self.block_tables[slot] = NULL_PAGE
        self.lengths[slot] = 0
        self._free_slots.append(slot)

    # ------------------------------------------------------------------
    # device views
    # ------------------------------------------------------------------
    @property
    def k_pages(self) -> jax.Array:
        return self.pages["k"]

    @k_pages.setter
    def k_pages(self, value: jax.Array) -> None:
        self.pages["k"] = value

    @property
    def v_pages(self) -> jax.Array:
        return self.pages["v"]

    @v_pages.setter
    def v_pages(self, value: jax.Array) -> None:
        self.pages["v"] = value

    @property
    def page_nbytes(self) -> int:
        """Device bytes per physical page across every pool array (K, V and
        quantization scales) — the denominator for pages-per-HBM-byte."""
        total = 0
        for arr in self.pages.values():
            per = arr.dtype.itemsize
            for axis, dim in enumerate(arr.shape):
                if axis != 1:
                    per *= dim
            total += per
        return total

    def device_tables(self) -> tuple[jax.Array, jax.Array]:
        """Device copies of (block_tables, lengths).

        MUST copy: ``jnp.asarray`` may alias (or lazily transfer) the host
        numpy buffer, and these arrays are mutated in place between decode
        steps — an aliased buffer races with async device reads and shows up
        as stale block tables / lengths (observed on the CPU backend as
        dropped KV writes and off-by-one attention masks).
        """
        return jnp.asarray(self.block_tables.copy()), jnp.asarray(self.lengths.copy())

    def device_row(self, slot: int) -> jax.Array:
        """Device copy of one slot's block-table row (same aliasing rule)."""
        return jnp.asarray(self.block_tables[slot].copy())

    def set_pages(self, k_pages: jax.Array, v_pages: jax.Array) -> None:
        self.pages["k"], self.pages["v"] = k_pages, v_pages

    def swap_pages(self, pages: dict[str, jax.Array]) -> None:
        """Swap in the executor's post-step page arrays (donated calls)."""
        assert set(pages) == set(self.pages), (set(pages), set(self.pages))
        self.pages = pages

    def _reshard(self, sharding) -> None:
        """Re-place the page pool with explicit shardings (the serving
        executor shards the kv-head dim over its ``("model",)`` mesh) —
        either one sharding for every pool array or a dict keyed like
        ``pages``. Host-side bookkeeping is untouched: only the head dim
        may be sharded, so page ids stay shard-invariant."""
        if not isinstance(sharding, dict):
            sharding = {key: sharding for key in self.pages}
        for key in self.pages:
            self.pages[key] = jax.device_put(self.pages[key], sharding[key])

    def gather_dense(self, slot: int) -> tuple[np.ndarray, np.ndarray]:
        """Reassemble a slot's K/V as dense (L, len, KVH, Dh) — tests only.
        Quantized pools are dequantized, so callers compare fp32 values."""
        if self.quant == "int8":
            k = np.asarray(dequantize_pages(self.pages["k"], self.pages["k_scale"]))
            v = np.asarray(dequantize_pages(self.pages["v"], self.pages["v_scale"]))
        else:
            k = np.asarray(self.pages["k"])
            v = np.asarray(self.pages["v"])
        n = int(self.lengths[slot])
        pages = self._slot_pages[slot]
        out_k = np.concatenate([k[:, p] for p in pages], axis=1)[:, :n]
        out_v = np.concatenate([v[:, p] for p in pages], axis=1)[:, :n]
        return out_k, out_v


def write_prefill_pages(
    pages: dict[str, jax.Array],  # pool arrays — donated by the caller's jit
    k_new: jax.Array,     # (L, S, KVH, Dh) dense prefill K (S may be padded)
    v_new: jax.Array,
    table_row: jax.Array,  # (MP,) int32 physical page per logical page
    valid_len: jax.Array,  # scalar int32: positions < valid_len are real
) -> dict[str, jax.Array]:
    """Scatter one sequence's dense prefill K/V into its pages.

    Padded positions (>= valid_len) are routed out of bounds and dropped —
    bucketed prompt padding never lands anywhere, and every surviving
    scatter index is unique (duplicate-index scatter order is undefined).
    Quantized pools (``k_scale`` present) quantize the dense chunk on the
    way in and scatter the scales alongside.
    """
    num_pages, page = pages["k"].shape[1:3]
    s = k_new.shape[1]
    pos = jnp.arange(s)
    phys = jnp.where(pos < valid_len, table_row[pos // page], num_pages)
    off = pos % page
    out = dict(pages)
    if "k_scale" in pages:
        k_new, k_sc = quantize_kv(k_new)
        v_new, v_sc = quantize_kv(v_new)
        out["k_scale"] = pages["k_scale"].at[:, phys, off].set(k_sc, mode="drop")
        out["v_scale"] = pages["v_scale"].at[:, phys, off].set(v_sc, mode="drop")
    out["k"] = pages["k"].at[:, phys, off].set(
        k_new.astype(pages["k"].dtype), mode="drop")
    out["v"] = pages["v"].at[:, phys, off].set(
        v_new.astype(pages["v"].dtype), mode="drop")
    return out
