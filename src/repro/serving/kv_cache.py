"""Block-table KV cache: refcounted pages with prefix sharing + copy-on-write.

The device side is two arrays per model — ``k_pages``/``v_pages`` of shape
(L, P, page_size, KVH, Dh) — plus per-step int32 inputs (block tables and
lengths), so the jitted decode step sees ONE static shape no matter how many
sequences are in flight or how long each one is. The host side is a
refcounted free-list allocator (:class:`PagePool`) and per-slot bookkeeping
(:class:`PagedKVCache`) that hands the engine ready-to-transfer block tables.

Sharded serving (the scheduler/executor split) places the pool on a
``("model",)`` mesh sharded along the **kv-head** dim only
(:meth:`PagedKVCache._reshard`): every shard then holds the same physical
pages for its slice of heads, so page ids, block tables, refcounts and the
prefix index below are shard-invariant and stay SINGLE host-side
structures — nothing in this module knows how many devices exist. The
copy-on-write page copy (:func:`_copy_page`) slices along the page dim,
which keeps the head sharding intact.

Page 0 is reserved as the **null page**: unused block-table entries and idle
decode slots point at it, so the kernel's gathers never go out of bounds and
idle-slot writes land in a sink nobody reads (reads are masked by length).

Sharing model (this PR):

* Every page carries a **refcount**. A page is physically freed (returned to
  the free list) only when its refcount reaches zero, so two sequences can
  map the same physical page and release independently.
* A **prefix index** maps the token content of a chain of full pages to the
  physical page holding its K/V. Keys are hash-chained — (parent physical
  page, this page's token chunk), root = the null page — so lookup and
  registration are O(1) per page, and a page is only reused when the
  ENTIRE prefix matches (the parent id names the whole chain), not just
  that page's tokens.
  :meth:`PagedKVCache.admit` consults it to map shared full pages read-only;
  matches are capped below the prompt's last token (the engine always needs
  at least one position's logits, and recomputing it must never write into
  a shared page).
* **Copy-on-write**: :meth:`ensure_append_capacity` copies a page (device
  page-granular copy, donated buffers so XLA updates in place) before a
  sequence writes into a page whose refcount is > 1. With admission-time
  sharing restricted to full pages this only triggers after :meth:`fork`,
  which maps *all* of a sequence's pages — including the partial tail —
  into a second slot.

Pages are registered into the prefix index by the engine *after* the prefill
chunk that fills them has been dispatched (dispatch order = execution order
on one device stream), so a concurrent admission can never read a shared
page before its contents exist.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NULL_PAGE = 0


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


class PagePool:
    """Refcounted LIFO free-list allocator over physical page ids [1, num_pages).

    ``alloc`` hands out pages with refcount 1; ``incref`` adds a sharer;
    ``decref`` returns the page to the free list when the count hits zero.
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need at least the null page + one real page"
        self.num_pages = num_pages
        # LIFO so recently-freed (cache-warm) pages are reused first
        self._free = list(range(num_pages - 1, 0, -1))
        self.refcounts = np.zeros((num_pages,), np.int32)

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        """Pop n pages (each refcount 1); RuntimeError when exhausted."""
        assert n > 0, n  # n=0 would slice the whole free list without popping
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: want {n}, have {len(self._free)}"
            )
        taken = self._free[-n:][::-1]
        del self._free[len(self._free) - n:]
        for p in taken:
            self.refcounts[p] = 1
        return taken

    def incref(self, page: int) -> None:
        assert page != NULL_PAGE and self.refcounts[page] > 0, page
        self.refcounts[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        assert page != NULL_PAGE, "cannot free the null page"
        assert self.refcounts[page] > 0, f"decref of free page {page}"
        self.refcounts[page] -= 1
        if self.refcounts[page] == 0:
            self._free.append(page)
            return True
        return False

    def free(self, pages: list[int]) -> None:
        for p in pages:
            self.decref(p)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _copy_page(k_pages, v_pages, src, dst):
    """Copy one physical page (all layers) src -> dst, in place (donated)."""
    ks = jax.lax.dynamic_slice_in_dim(k_pages, src, 1, axis=1)
    vs = jax.lax.dynamic_slice_in_dim(v_pages, src, 1, axis=1)
    k_pages = jax.lax.dynamic_update_slice_in_dim(k_pages, ks, dst, axis=1)
    v_pages = jax.lax.dynamic_update_slice_in_dim(v_pages, vs, dst, axis=1)
    return k_pages, v_pages


class PagedKVCache:
    """Device page pool + host block tables for up to ``max_slots`` sequences.

    The engine owns the jitted functions; this class owns allocation state
    (slots, refcounts, the prefix index) and the current device arrays
    (which the engine swaps after each donated decode/prefill-write call via
    :meth:`set_pages`).
    """

    def __init__(
        self,
        *,
        num_layers: int,
        num_kv_heads: int,
        head_dim: int,
        dtype,
        max_slots: int,
        max_context: int,
        page_size: int = 16,
        num_pages: int | None = None,
    ):
        self.page_size = page_size
        self.max_slots = max_slots
        self.max_pages_per_seq = cdiv(max_context, page_size)
        if num_pages is None:  # worst case: every slot at max context, + null
            num_pages = max_slots * self.max_pages_per_seq + 1
        self.num_pages = num_pages
        shape = (num_layers, num_pages, page_size, num_kv_heads, head_dim)
        self.k_pages = jnp.zeros(shape, dtype)
        self.v_pages = jnp.zeros(shape, dtype)

        self.pool = PagePool(num_pages)
        self.block_tables = np.full(
            (max_slots, self.max_pages_per_seq), NULL_PAGE, np.int32
        )
        self.lengths = np.zeros((max_slots,), np.int32)
        self._slot_pages: list[list[int]] = [[] for _ in range(max_slots)]
        self._free_slots = list(range(max_slots - 1, -1, -1))
        # prefix index: (parent physical page, token chunk) -> physical page
        self._prefix_index: dict[tuple, int] = {}
        self._page_key: dict[int, tuple] = {}  # reverse map for dereg on free
        self.stats = {"prefix_hits": 0, "prefix_tokens_reused": 0,
                      "cow_copies": 0}

    # ------------------------------------------------------------------
    # prefix index
    # ------------------------------------------------------------------
    def _prefix_limit(self, tokens) -> int:
        """Number of full pages eligible for sharing: capped strictly below
        the last token, so recomputing the sampling position never writes
        into a shared page (see module docstring)."""
        return max(0, (len(tokens) - 1) // self.page_size)

    def match_prefix(self, tokens) -> tuple[list[int], int]:
        """Longest chain of registered full pages matching ``tokens``.

        Keys are hash-chained, (parent physical page, this page's token
        chunk) — O(1) per level instead of rehashing the whole prefix —
        with NULL_PAGE as the chain root. A parent page id uniquely names
        its prefix because every sharer of a child page also holds the
        parent (prefix structure), so a parent entry can never be freed
        (and its id recycled) while a child entry survives.

        Returns (pages, matched_token_count). Read-only: the caller
        (:meth:`admit`) takes the references.
        """
        ps = self.page_size
        pages: list[int] = []
        parent = NULL_PAGE
        for i in range(self._prefix_limit(tokens)):
            page = self._prefix_index.get(
                (parent, tuple(tokens[i * ps:(i + 1) * ps]))
            )
            if page is None:
                break
            pages.append(page)
            parent = page
        return pages, len(pages) * ps

    def register_prefix(self, slot: int, tokens, upto: int) -> None:
        """Publish ``slot``'s full pages covering ``tokens[:upto]`` into the
        prefix index. MUST only be called once the K/V for those positions
        has been dispatched (the index hands these pages to other slots).

        Keys chain through THIS slot's own pages (not a previously
        registered twin): the slot provably keeps its own parent alive, so
        child entries never dangle behind a freed/recycled parent id. If a
        twin chain registered first (concurrent identical prefills), ours
        becomes an unreachable side chain — a missed match, never a wrong
        one — and admission deferral makes that window rare."""
        ps = self.page_size
        parent = NULL_PAGE
        for i in range(min(upto, len(tokens)) // ps):
            key = (parent, tuple(tokens[i * ps:(i + 1) * ps]))
            page = self._slot_pages[slot][i]
            if key not in self._prefix_index:
                self._prefix_index[key] = page
                self._page_key[page] = key
            parent = page

    def _deregister(self, page: int) -> None:
        key = self._page_key.pop(page, None)
        if key is not None:
            del self._prefix_index[key]

    # ------------------------------------------------------------------
    # slots
    # ------------------------------------------------------------------
    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    def can_admit(self, context_len: int, tokens=None) -> bool:
        need = cdiv(max(context_len, 1), self.page_size)
        if tokens is not None:
            need -= len(self.match_prefix(tokens)[0])
        return bool(self._free_slots) and self.pool.available >= need

    def admit(self, context_len: int, tokens=None) -> tuple[int, int]:
        """Claim a slot and pages for an initial context of ``context_len``.

        When ``tokens`` (the prompt) is given, full pages already holding a
        matching prefix are mapped read-only (refcount bumped) instead of
        allocated. Returns (slot, cached_len) — the caller only needs to
        prefill positions >= cached_len.
        """
        assert context_len <= self.max_pages_per_seq * self.page_size, (
            context_len, self.max_pages_per_seq * self.page_size)
        shared: list[int] = []
        cached = 0
        if tokens is not None:
            shared, cached = self.match_prefix(tokens)
        slot = self._free_slots.pop()
        for p in shared:
            self.pool.incref(p)
        fresh = cdiv(max(context_len, 1), self.page_size) - len(shared)
        try:
            pages = shared + (self.pool.alloc(fresh) if fresh > 0 else [])
        except RuntimeError:
            for p in shared:
                self.pool.decref(p)
            self._free_slots.append(slot)
            raise
        if shared:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_tokens_reused"] += cached
        self._slot_pages[slot] = pages
        self.block_tables[slot] = NULL_PAGE
        self.block_tables[slot, : len(pages)] = pages
        self.lengths[slot] = context_len
        return slot, cached

    def fork(self, src_slot: int) -> int:
        """Map every page of ``src_slot`` (including the partial tail) into a
        fresh slot, copy-on-write. The clone starts at the same length; the
        first append into a still-shared page triggers exactly one copy."""
        assert self._slot_pages[src_slot], f"slot {src_slot} is empty"
        slot = self._free_slots.pop()
        pages = list(self._slot_pages[src_slot])
        for p in pages:
            self.pool.incref(p)
        self._slot_pages[slot] = pages
        self.block_tables[slot] = self.block_tables[src_slot]
        self.lengths[slot] = self.lengths[src_slot]
        return slot

    def ensure_append_capacity(self, slot: int) -> bool:
        """Make sure position ``lengths[slot]`` is writable before a decode
        step lands there: allocates a page at page boundaries (on-demand
        growth) and copy-on-writes a shared page anywhere else. Returns True
        when the block table changed; raises RuntimeError when the pool is
        exhausted (callers may preempt)."""
        need = int(self.lengths[slot]) // self.page_size
        pages = self._slot_pages[slot]
        if need == len(pages):
            (new,) = self.pool.alloc(1)
            pages.append(new)
            self.block_tables[slot, need] = new
            return True
        old = pages[need]
        if self.pool.refcounts[old] > 1:  # shared: copy before the write
            (new,) = self.pool.alloc(1)
            self.k_pages, self.v_pages = _copy_page(
                self.k_pages, self.v_pages,
                jnp.asarray(old, jnp.int32), jnp.asarray(new, jnp.int32),
            )
            self.pool.decref(old)  # shared, so never frees here
            pages[need] = new
            self.block_tables[slot, need] = new
            self.stats["cow_copies"] += 1
            return True
        return False

    def append(self, slot: int) -> None:
        """Record that the decode step wrote one token for this slot."""
        self.lengths[slot] += 1

    def release(self, slot: int) -> None:
        for p in self._slot_pages[slot]:
            if self.pool.decref(p):
                self._deregister(p)
        self._slot_pages[slot] = []
        self.block_tables[slot] = NULL_PAGE
        self.lengths[slot] = 0
        self._free_slots.append(slot)

    # ------------------------------------------------------------------
    # device views
    # ------------------------------------------------------------------
    def device_tables(self) -> tuple[jax.Array, jax.Array]:
        """Device copies of (block_tables, lengths).

        MUST copy: ``jnp.asarray`` may alias (or lazily transfer) the host
        numpy buffer, and these arrays are mutated in place between decode
        steps — an aliased buffer races with async device reads and shows up
        as stale block tables / lengths (observed on the CPU backend as
        dropped KV writes and off-by-one attention masks).
        """
        return jnp.asarray(self.block_tables.copy()), jnp.asarray(self.lengths.copy())

    def device_row(self, slot: int) -> jax.Array:
        """Device copy of one slot's block-table row (same aliasing rule)."""
        return jnp.asarray(self.block_tables[slot].copy())

    def set_pages(self, k_pages: jax.Array, v_pages: jax.Array) -> None:
        self.k_pages, self.v_pages = k_pages, v_pages

    def _reshard(self, sharding) -> None:
        """Re-place the page pool with an explicit sharding (the serving
        executor shards the kv-head dim over its ``("model",)`` mesh).
        Host-side bookkeeping is untouched: only the head dim may be
        sharded, so page ids stay shard-invariant."""
        self.set_pages(
            jax.device_put(self.k_pages, sharding),
            jax.device_put(self.v_pages, sharding),
        )

    def gather_dense(self, slot: int) -> tuple[np.ndarray, np.ndarray]:
        """Reassemble a slot's K/V as dense (L, len, KVH, Dh) — tests only."""
        k = np.asarray(self.k_pages)
        v = np.asarray(self.v_pages)
        n = int(self.lengths[slot])
        pages = self._slot_pages[slot]
        out_k = np.concatenate([k[:, p] for p in pages], axis=1)[:, :n]
        out_v = np.concatenate([v[:, p] for p in pages], axis=1)[:, :n]
        return out_k, out_v


def write_prefill_pages(
    k_pages: jax.Array,   # (L, P, page, KVH, Dh) — donated by the caller's jit
    v_pages: jax.Array,
    k_new: jax.Array,     # (L, S, KVH, Dh) dense prefill K (S may be padded)
    v_new: jax.Array,
    table_row: jax.Array,  # (MP,) int32 physical page per logical page
    valid_len: jax.Array,  # scalar int32: positions < valid_len are real
) -> tuple[jax.Array, jax.Array]:
    """Scatter one sequence's dense prefill K/V into its pages.

    Padded positions (>= valid_len) are routed out of bounds and dropped —
    bucketed prompt padding never lands anywhere, and every surviving
    scatter index is unique (duplicate-index scatter order is undefined).
    """
    num_pages, page = k_pages.shape[1:3]
    s = k_new.shape[1]
    pos = jnp.arange(s)
    phys = jnp.where(pos < valid_len, table_row[pos // page], num_pages)
    off = pos % page
    k_pages = k_pages.at[:, phys, off].set(k_new, mode="drop")
    v_pages = v_pages.at[:, phys, off].set(v_new, mode="drop")
    return k_pages, v_pages
