"""Serving metrics: request latency summaries + per-step engine gauges.

Two kinds of measurement live here (single source for the
percentile/format logic used by ``launch/serve.py`` and
``benchmarks/run.py``):

* **Request-level latency** — ``ttft``/``itl`` are stamped per-request by
  the ``RequestHandle`` lifecycle machinery (``serving/api.py``), so every
  protocol engine — paged and lockstep alike — reports them; Results
  lacking latency data are skipped.
* **Per-step engine gauges** (:class:`UtilizationMetrics`) — decode-slot
  occupancy and page-pool utilization, recorded once per decode step by
  both engines. These answer the capacity questions request counters
  can't: is the decode batch actually full (occupancy), and is throughput
  page-bound or slot-bound (page utilization vs occupancy)?
  ``launch/serve.py`` prints both in its stats output.
* **Per-dispatch batch composition** (``record_batch``) — how each device
  dispatch divides its rows between decode, live prefill and padding, and
  what fraction of dispatches were fused (decode + chunk in one call).
  This is the observability knob for the fused mixed step: a low fused
  fraction under mixed load means the scheduler is starving one side;
  high padding means ``max_slots`` is oversized for the offered load.
* **KV tier gauges** (``record_tiers``) — per-step parked/host/persisted
  page counts plus deltas of the :class:`~repro.serving.kv_tiers.
  KVTierManager` counters (tier hits, spill/prefetch bytes and seconds).
  This answers whether prefix reuse is actually landing (device vs host vs
  persisted hits) and what the spill traffic costs.
* **Speculation counters** (``record_spec``) — per-bundle proposed/
  accepted/rolled-back token counts. The acceptance rate is THE health
  metric for speculative decoding: the verify dispatch costs roughly one
  decode step regardless of k, so tokens/step ≈ 1 + accepted/bundle, and
  a rate near zero means speculation is pure overhead for this workload.
"""

from __future__ import annotations

import numpy as np


class UtilizationMetrics:
    """Per-decode-step occupancy/utilization gauges for one engine.

    ``record`` is called by the engine once per decode step with the
    number of actively decoding slots and (paged engine only) the page
    pool's in-use count. ``summary()`` aggregates to mean/peak fractions;
    ``merge`` combines trackers from multiple workers.
    """

    def __init__(self):
        self.slot_samples: list[float] = []   # decoding / total slots
        self.page_samples: list[float] = []   # pages in use / usable pages
        # per-dispatch batch composition (fused mixed step observability)
        self.dispatches = 0
        self.fused_dispatches = 0
        self.decode_rows = 0
        self.prefill_rows = 0
        self.padded_rows = 0
        # KV tier gauges (paged engine with tiers enabled): per-step page
        # counts per tier, plus the latest snapshot of the tier manager's
        # additive counters (one manager per engine, counters start at 0)
        self.parked_samples: list[int] = []
        self.host_samples: list[int] = []
        self.persist_samples: list[int] = []
        self._tier_latest: dict | None = None
        self._tier_merged: dict = {}
        # speculative decoding counters (additive, per verify bundle)
        self.spec_bundles = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_rollbacks = 0

    def record(self, *, active: int, slots: int,
               pages_used: int | None = None,
               pages_total: int | None = None) -> None:
        self.slot_samples.append(active / max(slots, 1))
        if pages_total:
            self.page_samples.append(pages_used / pages_total)

    def record_batch(self, *, decode_rows: int, prefill_rows: int,
                     padded_rows: int, fused: bool) -> None:
        """Record one device dispatch's row composition. ``fused`` marks a
        mixed dispatch (decode slots + a prefill chunk in one call)."""
        self.dispatches += 1
        self.fused_dispatches += int(fused)
        self.decode_rows += decode_rows
        self.prefill_rows += prefill_rows
        self.padded_rows += padded_rows

    def record_tiers(self, *, parked: int, host: int, persisted: int,
                     counters: dict) -> None:
        """Record one step's KV tier state: page counts per tier (gauges)
        plus a snapshot of the tier manager's additive counters. The tier
        manager is born with the engine and its counters start at zero, so
        the latest snapshot IS this engine's lifetime total — admissions
        that precede the first decode step (prefix queries, prefetches) are
        included, not baselined away."""
        self.parked_samples.append(parked)
        self.host_samples.append(host)
        self.persist_samples.append(persisted)
        self._tier_latest = dict(counters)

    def record_spec(self, *, proposed: int, accepted: int,
                    rollbacks: int) -> None:
        """Record one speculation bundle's outcome: ``proposed`` drafted
        tokens went into the verify dispatch, the leading ``accepted`` of
        them matched what the sampler produced, and the ``rollbacks``
        rejected tail positions were rewound (the bonus/correction token
        on top of ``accepted`` is a plain decode token, not counted
        here)."""
        self.spec_bundles += 1
        self.spec_proposed += proposed
        self.spec_accepted += accepted
        self.spec_rollbacks += rollbacks

    def _tier_deltas(self) -> dict:
        """This tracker's counter totals plus anything merged in."""
        out = dict(self._tier_merged)
        if self._tier_latest is not None:
            for key, val in self._tier_latest.items():
                out[key] = out.get(key, 0) + val
        return out

    def merge(self, other: "UtilizationMetrics") -> None:
        self.slot_samples.extend(other.slot_samples)
        self.page_samples.extend(other.page_samples)
        self.dispatches += other.dispatches
        self.fused_dispatches += other.fused_dispatches
        self.decode_rows += other.decode_rows
        self.prefill_rows += other.prefill_rows
        self.padded_rows += other.padded_rows
        self.parked_samples.extend(other.parked_samples)
        self.host_samples.extend(other.host_samples)
        self.persist_samples.extend(other.persist_samples)
        for key, val in other._tier_deltas().items():
            self._tier_merged[key] = self._tier_merged.get(key, 0) + val
        self.spec_bundles += other.spec_bundles
        self.spec_proposed += other.spec_proposed
        self.spec_accepted += other.spec_accepted
        self.spec_rollbacks += other.spec_rollbacks

    @property
    def steps(self) -> int:
        return len(self.slot_samples)

    def summary(self) -> dict | None:
        """Mean/peak slot occupancy, page utilization (fractions) and
        dispatch composition, or None when nothing was recorded."""
        if not self.slot_samples and not self.dispatches:
            return None
        out = {"decode_steps": len(self.slot_samples)}
        if self.slot_samples:
            out["slot_occupancy_mean"] = float(np.mean(self.slot_samples))
            out["slot_occupancy_peak"] = float(np.max(self.slot_samples))
        if self.page_samples:
            out["page_util_mean"] = float(np.mean(self.page_samples))
            out["page_util_peak"] = float(np.max(self.page_samples))
        if self.dispatches:
            rows = self.decode_rows + self.prefill_rows + self.padded_rows
            out["dispatches"] = self.dispatches
            out["fused_step_fraction"] = self.fused_dispatches / self.dispatches
            out["decode_rows"] = self.decode_rows
            out["prefill_rows"] = self.prefill_rows
            out["padded_rows"] = self.padded_rows
            out["padded_row_fraction"] = self.padded_rows / max(rows, 1)
        tiers = self._tier_deltas()
        if self.parked_samples or tiers:
            t: dict = {}
            if self.parked_samples:
                t["parked_pages_mean"] = float(np.mean(self.parked_samples))
                t["parked_pages_peak"] = int(np.max(self.parked_samples))
                t["host_pages_peak"] = int(np.max(self.host_samples))
                t["persisted_pages_peak"] = int(np.max(self.persist_samples))
            t.update(tiers)
            q = t.get("prefix_queries", 0)
            if q:
                # hits count PAGES revived, queries count admissions — the
                # quotient is cached pages served per prefix lookup, not a
                # 0..1 rate (a deep cached prefix yields many pages per hit)
                hits = (t.get("device_hits", 0) + t.get("host_hits", 0)
                        + t.get("persist_hits", 0))
                t["tier_hit_pages_per_query"] = hits / q
            out["kv_tiers"] = t
        if self.spec_bundles:
            out["speculation"] = {
                "bundles": self.spec_bundles,
                "tokens_proposed": self.spec_proposed,
                "tokens_accepted": self.spec_accepted,
                "rollbacks": self.spec_rollbacks,
                "acceptance_rate": (self.spec_accepted
                                    / max(self.spec_proposed, 1)),
                # +1: each bundle also emits its bonus/correction token
                "tokens_per_bundle": (self.spec_accepted / self.spec_bundles
                                      + 1.0),
            }
        return out

    def format(self) -> str:
        s = self.summary()
        if s is None:
            return "no_utilization_data"
        txt = "slot_occupancy_mean=n/a"
        if "slot_occupancy_mean" in s:
            txt = (f"slot_occupancy_mean={s['slot_occupancy_mean']:.0%}/"
                   f"peak={s['slot_occupancy_peak']:.0%}")
        if "page_util_mean" in s:
            txt += (f";page_util_mean={s['page_util_mean']:.0%}/"
                    f"peak={s['page_util_peak']:.0%}")
        txt += f";decode_steps={s['decode_steps']}"
        if "dispatches" in s:
            txt += (f";dispatches={s['dispatches']}"
                    f";fused_frac={s['fused_step_fraction']:.0%}"
                    f";rows=d{s['decode_rows']}/p{s['prefill_rows']}"
                    f"/pad{s['padded_rows']}")
        if "kv_tiers" in s:
            t = s["kv_tiers"]
            txt += (f";tiers=parked_peak{t.get('parked_pages_peak', 0)}"
                    f"/host_peak{t.get('host_pages_peak', 0)}"
                    f"/persist_peak{t.get('persisted_pages_peak', 0)}"
                    f";tier_hits=dev{t.get('device_hits', 0)}"
                    f"/host{t.get('host_hits', 0)}"
                    f"/pv{t.get('persist_hits', 0)}"
                    f";spilled={t.get('spilled_pages', 0)}"
                    f";prefetched={t.get('prefetched_pages', 0)}")
        if "speculation" in s:
            sp = s["speculation"]
            txt += (f";spec=bundles{sp['bundles']}"
                    f"/prop{sp['tokens_proposed']}"
                    f"/acc{sp['tokens_accepted']}"
                    f"/rb{sp['rollbacks']}"
                    f";accept_rate={sp['acceptance_rate']:.0%}"
                    f";tok_per_bundle={sp['tokens_per_bundle']:.2f}")
        return txt


class FleetMetrics:
    """Fleet-level supervision counters (``serving/fleet.py``).

    Where :class:`UtilizationMetrics` answers "is one engine full", this
    answers "what did fault tolerance cost": how many workers crashed or
    were restarted, how many in-flight requests were resubmitted, how many
    regenerated tokens the supervisor's index-dedupe suppressed (each one
    a token a client would otherwise have seen twice), and the recovery
    latency distribution (crash detected -> first token delivered past the
    crash boundary). ``mismatched_deltas``/``gapped_deltas`` must stay 0 —
    a nonzero count means a regenerated stream diverged from the original
    or skipped an index, i.e. the replay-identical recovery contract broke.
    """

    def __init__(self):
        self.crashes = 0            # workers that died or livelocked
        self.restarts = 0           # replacement attempts spawned
        self.resubmitted = 0        # in-flight requests replayed elsewhere
        self.duplicate_deltas = 0   # regenerated tokens dropped by dedupe
        self.mismatched_deltas = 0  # dup token != recorded token (MUST be 0)
        self.gapped_deltas = 0      # delta index skipped ahead (MUST be 0)
        self.direct_cancels = 0     # cancelled-during-crash finished by sup
        self.recovery_s: list[float] = []  # crash -> first resumed token

    def record_recovery(self, seconds: float) -> None:
        self.recovery_s.append(seconds)

    def summary(self) -> dict:
        out = {
            "crashes": self.crashes,
            "restarts": self.restarts,
            "resubmitted": self.resubmitted,
            "duplicate_deltas": self.duplicate_deltas,
            "mismatched_deltas": self.mismatched_deltas,
            "gapped_deltas": self.gapped_deltas,
            "direct_cancels": self.direct_cancels,
        }
        if self.recovery_s:
            out["recovery_s_mean"] = float(np.mean(self.recovery_s))
            out["recovery_s_max"] = float(np.max(self.recovery_s))
        return out

    def format(self) -> str:
        s = self.summary()
        txt = (f"crashes={s['crashes']};restarts={s['restarts']};"
               f"resubmitted={s['resubmitted']};"
               f"dedup={s['duplicate_deltas']}")
        if self.recovery_s:
            txt += (f";recovery_s_mean={s['recovery_s_mean']:.3f}"
                    f"/max={s['recovery_s_max']:.3f}")
        return txt


def latency_percentiles(results) -> dict | None:
    """p50/p90/p99 TTFT and inter-token latency (ms) + max ITL (the decode
    stall bound). Returns None when no result carries latency data."""
    ttfts = [r.ttft for r in results if getattr(r, "ttft", None) is not None]
    itls = [g for r in results for g in getattr(r, "itl", [])]
    if not ttfts or not itls:
        return None
    pt = np.percentile(ttfts, [50, 90, 99]) * 1e3
    pi = np.percentile(itls, [50, 90, 99]) * 1e3
    return {
        "ttft_ms": tuple(float(x) for x in pt),
        "itl_ms": tuple(float(x) for x in pi),
        "itl_ms_max": float(max(itls) * 1e3),
    }


def format_latency(results) -> str:
    """Compact ``k=p50/p90/p99``-style summary for bench rows and logs."""
    p = latency_percentiles(results)
    if p is None:
        return "no_latency_data"
    t, i = p["ttft_ms"], p["itl_ms"]
    return (f"ttft_ms_p50={t[0]:.1f}/p90={t[1]:.1f}/p99={t[2]:.1f};"
            f"itl_ms_p50={i[0]:.1f}/p90={i[1]:.1f}/p99={i[2]:.1f};"
            f"itl_ms_max={p['itl_ms_max']:.1f}")
