"""Latency summaries over engine Results (single source for the
percentile/format logic used by ``launch/serve.py`` and ``benchmarks/run.py``).

``ttft``/``itl`` are stamped per-request by the ``RequestHandle`` lifecycle
machinery (``serving/api.py``), so every protocol engine — paged and
lockstep alike — reports them; Results lacking latency data are skipped.
"""

from __future__ import annotations

import numpy as np


def latency_percentiles(results) -> dict | None:
    """p50/p90/p99 TTFT and inter-token latency (ms) + max ITL (the decode
    stall bound). Returns None when no result carries latency data."""
    ttfts = [r.ttft for r in results if getattr(r, "ttft", None) is not None]
    itls = [g for r in results for g in getattr(r, "itl", [])]
    if not ttfts or not itls:
        return None
    pt = np.percentile(ttfts, [50, 90, 99]) * 1e3
    pi = np.percentile(itls, [50, 90, 99]) * 1e3
    return {
        "ttft_ms": tuple(float(x) for x in pt),
        "itl_ms": tuple(float(x) for x in pi),
        "itl_ms_max": float(max(itls) * 1e3),
    }


def format_latency(results) -> str:
    """Compact ``k=p50/p90/p99``-style summary for bench rows and logs."""
    p = latency_percentiles(results)
    if p is None:
        return "no_latency_data"
    t, i = p["ttft_ms"], p["itl_ms"]
    return (f"ttft_ms_p50={t[0]:.1f}/p90={t[1]:.1f}/p99={t[2]:.1f};"
            f"itl_ms_p50={i[0]:.1f}/p90={i[1]:.1f}/p99={i[2]:.1f};"
            f"itl_ms_max={p['itl_ms_max']:.1f}")
