"""SSM/hybrid continuous-batching engine: per-slot recurrent state.

Mamba2 serving is the page-pool design turned inside out: a sequence's
whole history is a CONSTANT-SIZE recurrent state (the ``init_mamba_cache``
pytree — f32 SSD state ``(H, P, N)`` plus three conv tails), so instead of
a :class:`~repro.serving.kv_cache.PagedKVCache` the engine owns a
:class:`SlotStateBank` — that pytree stacked over layers and batched over
slots. Admission binds a request to a bank slot; chunked prefill runs the
prompt through ``ops.ssd_scan`` (carrying the state chunk-to-chunk, padded
tail positions neutralized by dt=0); decode is ONE fused jitted
dispatch per step under ``shard_map`` on the ``("model",)`` mesh — state
sharded on ``ssm_heads`` / ``ff`` per ``MAMBA_CACHE_AXES``, sampled tokens
returning replicated, with the same packed device-mirror feedback loop as
:class:`~repro.serving.executor.ModelExecutor` (zero host->device
transfers in steady state).

Fault tolerance is where constant-size state pays: :meth:`SSMEngine
.preempt_youngest` evicts the youngest decoding sequence either by
discarding its state (default — the requeued request re-prefills and the
``(seed, token_index)``-keyed sampler regenerates a byte-identical stream,
already-emitted deltas de-duplicated by the handle) or with
``snapshot=True`` by parking the slot's state pytree on the host, restored
verbatim at re-admission so the sequence resumes decoding WITHOUT
re-prefill. The fleet crash-replay path (PR 7) needs no engine-specific
work: replayed requests re-prefill deterministically exactly like a
discarded preemption.

The hybrid (Zamba2) case routes the shared attention block through a
``PagedKVCache`` sized for ``num_layers // attn_every`` layers and every
Mamba layer through the state bank in the SAME fused step; attention page
exhaustion preempts youngest-first exactly like the paged engine.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_unchecked
from repro.models import build_model
from repro.models.common import sample_tokens
from repro.models.ssm import init_mamba_cache
from repro.parallel.collectives import tensor_parallel
from repro.serving.api import (
    EngineBase,
    FinishReason,
    Request,
    StreamEvent,
    validate_request,
)
from repro.serving.executor import (
    PAGE_SPEC,
    SCALE_SPEC,
    _serving_param_specs,
    default_serving_mesh,
    validate_serving_mesh,
)
from repro.serving.kv_cache import NULL_PAGE, PagedKVCache, cdiv
from repro.serving.metrics import UtilizationMetrics
from repro.serving.scheduler import DecodeInputs, Sequence

__all__ = ["SSMEngine", "SlotStateBank"]

# Stacked-bank PartitionSpecs, MAMBA_CACHE_AXES with a leading layer axis
# and the cache_batch axis reinterpreted as the slot axis: the SSD state
# shards on ssm_heads, the x conv tail on its d_inner channels, and the
# B/C conv tails (state-dim N, replicated projections) stay replicated.
STATE_SPECS = {
    "ssm": P(None, None, "model", None, None),     # (L, S, HN, PN, N)
    "conv_x": P(None, None, None, "model"),        # (L, S, W-1, DIN)
    "conv_b": P(),                                 # (L, S, W-1, N)
    "conv_c": P(),
}


class SlotStateBank:
    """The per-slot recurrent-state bank: ``init_mamba_cache`` stacked over
    layers (leading axis L) and batched over slots (second axis S).

    The bank is a plain pytree of device arrays — the executor's fused
    step functions take it as a donated argument and hand back the
    advanced bank, so steady-state decode never copies it. Host-side slot
    bookkeeping (which slot belongs to which request) lives in the engine;
    the bank only knows shapes, snapshots and restores.
    """

    def __init__(self, cfg, max_slots: int, dtype) -> None:
        mc = init_mamba_cache(cfg, max_slots, dtype, abstract=True)
        self.state: dict[str, jax.Array] = {
            k: jnp.zeros((cfg.num_layers,) + s.shape, s.dtype)
            for k, s in mc.items()
        }
        self.max_slots = max_slots
        self.shardings: dict | None = None  # set by the executor when tp > 1

    def commit(self, state: dict) -> None:
        """Adopt an updated bank, re-pinning the serving sharding after
        host-side slot surgery (restore) so the jitted steps see their
        expected layout."""
        if self.shardings is not None:
            state = {
                k: jax.device_put(v, self.shardings[k])
                for k, v in state.items()
            }
        self.state = state

    def snapshot(self, slot: int) -> dict[str, np.ndarray]:
        """Copy one slot's full state pytree to the host — (L, ...) leaves
        with the slot axis dropped."""
        return {k: np.asarray(v[:, slot]) for k, v in self.state.items()}

    def restore(self, slot: int, snap: dict[str, np.ndarray]) -> None:
        """Write a host snapshot back into a (newly allocated) slot."""
        self.commit({
            k: v.at[:, slot].set(jnp.asarray(snap[k], v.dtype))
            for k, v in self.state.items()
        })


class SSMExecutor:
    """Compute half of the SSM engine: jitted fused decode+sample and
    chunked-prefill step functions under ``shard_map``, plus the packed
    device mirrors of the decode batch (same ``di``/``df`` packing and
    steady-state zero-transfer loop as
    :class:`~repro.serving.executor.ModelExecutor`)."""

    # di (S, MP+6) int32: block-table row (MP=0 for pure SSM), then
    # [lens, active, tokens, top_ks, seeds, idx]; df (S, 2) f32:
    # [temps, top_ps]. lens only drives attention in the hybrid case but
    # is advanced uniformly so both layouts share one packing.
    _DI_COLS = 6

    def __init__(self, cfg, params, bank: SlotStateBank,
                 cache: PagedKVCache | None, *, max_len: int,
                 mesh: Mesh | None = None, attn_impl: str | None = None,
                 ssd_impl: str | None = None):
        self.cfg = cfg
        # "auto": Pallas SSD/attention kernels on TPU, the XLA reference
        # lowering elsewhere — same contract either way (kernel fuzz suite)
        self.model = build_model(
            cfg, attn_impl=attn_impl or "auto", ssd_impl=ssd_impl or "auto"
        )
        self.bank = bank
        self.cache = cache
        self.max_len = max_len
        self.mesh = mesh if mesh is not None else default_serving_mesh(cfg)
        self.tp = validate_serving_mesh(cfg, self.mesh)
        self.vocab_sharded = (not cfg.tie_embeddings) and self.tp > 1
        self.param_specs = _serving_param_specs(
            self.model, self.mesh, self.vocab_sharded
        )
        self.params = self._place(params)
        self._decode_fns: dict[bool, object] = {}
        self._chunk_fns: dict[bool, object] = {}
        self._greedy_only = True
        self._di = self._df = None

    # ------------------------------------------------------------------
    # sharding
    # ------------------------------------------------------------------
    def _place(self, params):
        if self.tp == 1:
            return params
        ns = lambda spec: NamedSharding(self.mesh, spec)
        placed = jax.tree.map(
            lambda arr, spec: jax.device_put(arr, ns(spec)),
            params, self.param_specs,
        )
        self.bank.shardings = {k: ns(s) for k, s in STATE_SPECS.items()}
        self.bank.commit(self.bank.state)
        if self.cache is not None:
            self.cache._reshard(
                {key: ns(spec) for key, spec in self._page_specs().items()}
            )
        return placed

    def _page_specs(self) -> dict:
        return {
            key: PAGE_SPEC if arr.ndim == 5 else SCALE_SPEC
            for key, arr in self.cache.pages.items()
        }

    def _smap(self, fn, in_specs, out_specs):
        return shard_map_unchecked(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs
        )

    def _tp_ctx(self):
        return tensor_parallel("model", vocab_sharded=self.vocab_sharded)

    def _sample(self, logits, di, df, mp, greedy_only):
        if greedy_only:
            return jnp.argmax(
                logits[..., :self.cfg.vocab_size], axis=-1
            ).astype(jnp.int32)
        return sample_tokens(logits, df[:, 0], di[:, mp + 3], df[:, 1],
                             di[:, mp + 4], di[:, mp + 5],
                             self.cfg.vocab_size)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _decode_fn(self, greedy_only: bool):
        """ONE dispatch per decode step: every slot's recurrent-state step
        (plus the shared attention pool read/write in the hybrid case) and
        sampling fused; logits never leave the device. The donated state
        bank comes back advanced, idle slots' state writeback gated by the
        ``active`` column."""
        if greedy_only not in self._decode_fns:
            if self.cache is None:
                def fn(params, state, di, df):
                    mp = di.shape[1] - self._DI_COLS
                    lens, active = di[:, mp], di[:, mp + 1]
                    with self._tp_ctx():
                        state, logits = self.model.decode_step_ssm(
                            params, state, di[:, mp + 2:mp + 3], active
                        )
                        toks = self._sample(logits, di, df, mp, greedy_only)
                    di = di.at[:, mp].set(lens + active)
                    di = di.at[:, mp + 2].set(toks)
                    di = di.at[:, mp + 5].add(active)
                    return state, di, toks

                smapped = self._smap(
                    fn,
                    in_specs=(self.param_specs, STATE_SPECS) + (P(),) * 2,
                    out_specs=(STATE_SPECS, P(), P()),
                )
                self._decode_fns[greedy_only] = jax.jit(
                    smapped, donate_argnums=(1, 2)
                )
            else:
                def fn(params, pages, state, di, df):
                    mp = di.shape[1] - self._DI_COLS
                    bt, lens, active = di[:, :mp], di[:, mp], di[:, mp + 1]
                    with self._tp_ctx():
                        pages, state, logits = self.model.decode_step_hybrid(
                            params, pages, state, bt, lens,
                            di[:, mp + 2:mp + 3], active,
                        )
                        toks = self._sample(logits, di, df, mp, greedy_only)
                    di = di.at[:, mp].set(lens + active)
                    di = di.at[:, mp + 2].set(toks)
                    di = di.at[:, mp + 5].add(active)
                    return pages, state, di, toks

                page_specs = self._page_specs()
                smapped = self._smap(
                    fn,
                    in_specs=(self.param_specs, page_specs, STATE_SPECS)
                    + (P(),) * 2,
                    out_specs=(page_specs, STATE_SPECS, P(), P()),
                )
                self._decode_fns[greedy_only] = jax.jit(
                    smapped, donate_argnums=(1, 2, 3)
                )
        return self._decode_fns[greedy_only]

    def refresh(self, inputs: DecodeInputs) -> None:
        """Mirror a freshly assembled decode batch to the device (two
        transfers: packed int32 + packed f32)."""
        self._greedy_only = inputs.greedy_only
        bt = inputs.block_tables
        s, mp = bt.shape
        di = np.empty((s, mp + self._DI_COLS), np.int32)
        di[:, :mp] = bt
        di[:, mp] = inputs.lengths
        di[:, mp + 1] = inputs.active
        di[:, mp + 2] = inputs.tokens[:, 0]
        di[:, mp + 3] = inputs.top_ks
        di[:, mp + 4] = inputs.seeds
        di[:, mp + 5] = inputs.idx
        self._di = jnp.asarray(di)
        self._df = jnp.asarray(
            np.stack([inputs.temps, inputs.top_ps], axis=1).astype(np.float32)
        )

    def decode(self, inputs: DecodeInputs | None = None) -> np.ndarray:
        """Run one decode step; ``None`` reuses the device-advanced batch
        from last step (steady state transfers nothing to the device).
        Returns the sampled token per slot, (S,) int32 on the host."""
        if inputs is not None:
            self.refresh(inputs)
        fn = self._decode_fn(self._greedy_only)
        if self.cache is None:
            state, self._di, toks = fn(
                self.params, self.bank.state, self._di, self._df
            )
        else:
            pages = dict(self.cache.pages)
            pages, state, self._di, toks = fn(
                self.params, pages, self.bank.state, self._di, self._df
            )
            self.cache.swap_pages(pages)
        self.bank.state = state
        return np.asarray(toks)

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------
    def _chunk_fn(self, greedy_only: bool):
        """One fixed-size prompt chunk of ONE sequence: dynamic-slice the
        slot's state out of the bank (zeroed when ``start == 0`` so a
        recycled slot never leaks its previous occupant), run the SSD scan
        continuation, scatter the advanced state back, and sample the
        chunk's token (meaningful on the final chunk)."""
        if greedy_only not in self._chunk_fns:
            cfg = self.cfg

            def slot_state(state, slot, start):
                sl = {
                    k: jax.lax.dynamic_index_in_dim(v, slot, axis=1,
                                                    keepdims=True)
                    for k, v in state.items()
                }
                fresh = start == 0
                return {
                    k: jnp.where(fresh, jnp.zeros_like(v), v)
                    for k, v in sl.items()
                }

            def put_back(state, new_sl, slot):
                return {
                    k: jax.lax.dynamic_update_index_in_dim(
                        v, new_sl[k].astype(v.dtype), slot, axis=1
                    )
                    for k, v in state.items()
                }

            def sample1(logits, ci, cf, tail):
                if greedy_only:
                    return jnp.argmax(
                        logits[:cfg.vocab_size], axis=-1
                    ).astype(jnp.int32)
                return sample_tokens(
                    logits[None], cf[0:1], ci[tail + 3:tail + 4], cf[1:2],
                    ci[tail + 4:tail + 5], jnp.zeros((1,), jnp.int32),
                    cfg.vocab_size,
                )[0]

            if self.cache is None:
                def fn(params, state, ci, cf):
                    c = ci.shape[0] - 5
                    toks, (slot, start, valid) = ci[:c], ci[c:c + 3]
                    sl = slot_state(state, slot, start)
                    with self._tp_ctx():
                        new_sl, logits = self.model.prefill_chunk_ssm(
                            params, sl, toks, valid
                        )
                        tok = sample1(logits, ci, cf, c)
                    return put_back(state, new_sl, slot), tok

                smapped = self._smap(
                    fn,
                    in_specs=(self.param_specs, STATE_SPECS) + (P(),) * 2,
                    out_specs=(STATE_SPECS, P()),
                )
                self._chunk_fns[greedy_only] = jax.jit(
                    smapped, donate_argnums=(1,)
                )
            else:
                mp = self.cache.block_tables.shape[1]

                def fn(params, pages, state, ci, cf):
                    c = ci.shape[0] - mp - 5
                    row, toks = ci[:mp], ci[mp:mp + c]
                    slot, start, valid = ci[mp + c:mp + c + 3]
                    sl = slot_state(state, slot, start)
                    with self._tp_ctx():
                        pages, new_sl, logits = (
                            self.model.prefill_chunk_hybrid(
                                params, pages, sl, row, toks, start, valid
                            )
                        )
                        tok = sample1(logits, ci, cf, mp + c)
                    return pages, put_back(state, new_sl, slot), tok

                page_specs = self._page_specs()
                smapped = self._smap(
                    fn,
                    in_specs=(self.param_specs, page_specs, STATE_SPECS)
                    + (P(),) * 2,
                    out_specs=(page_specs, STATE_SPECS, P()),
                )
                self._chunk_fns[greedy_only] = jax.jit(
                    smapped, donate_argnums=(1, 2)
                )
        return self._chunk_fns[greedy_only]

    def prefill_chunk(self, slot: int, seq: Sequence, tokens: np.ndarray,
                      start: int, valid: int) -> int:
        """Dispatch one padded chunk for ``slot``; returns the sampled
        token (the request's first token on the prompt's final chunk)."""
        sp = seq.request.sampling
        c = tokens.shape[0]
        if self.cache is not None:
            row = self.cache.block_tables[slot]
            m = row.shape[0]
            ci = np.empty(m + c + 5, np.int32)
            ci[:m] = row
            ci[m:m + c] = tokens
            ci[m + c:] = (slot, start, valid, sp.top_k, seq.handle.seed)
        else:
            ci = np.empty(c + 5, np.int32)
            ci[:c] = tokens
            ci[c:] = (slot, start, valid, sp.top_k, seq.handle.seed)
        cf = np.array([sp.temperature, sp.top_p], np.float32)
        fn = self._chunk_fn(sp.temperature <= 0)
        if self.cache is None:
            state, tok = fn(self.params, self.bank.state,
                            jnp.asarray(ci), jnp.asarray(cf))
        else:
            pages = dict(self.cache.pages)
            pages, state, tok = fn(self.params, pages, self.bank.state,
                                   jnp.asarray(ci), jnp.asarray(cf))
            self.cache.swap_pages(pages)
        self.bank.state = state
        return int(tok)


class SSMEngine(EngineBase):
    """Continuous-batching :class:`~repro.serving.api.EngineCore` for the
    ``ssm`` (Mamba2) and ``hybrid`` (Zamba2) families.

    Same protocol surface and streaming semantics as
    :class:`~repro.serving.engine.ContinuousBatchingEngine` — continuous
    admission, chunked prefill interleaved with decode, transparent
    preemption, ``(seed, token_index)``-keyed sampling — over a
    :class:`SlotStateBank` instead of (pure SSM) or alongside (hybrid) a
    paged KV pool. Pure-SSM engines deliberately have NO ``cache``
    attribute: there are no pages to account for, and per-request memory
    is constant, so admission is bounded by slots alone.
    """

    def __init__(self, cfg, params, *, max_len: int = 256,
                 max_slots: int = 8, prefill_chunk: int | None = 32,
                 page_size: int = 16, num_pages: int | None = None,
                 admission=None, seed: int = 0,
                 max_preemptions: int | None = None,
                 attn_impl: str | None = None, ssd_impl: str | None = None):
        assert not cfg.is_encoder_decoder, "SSM engine is decoder-only"
        assert cfg.family in ("ssm", "hybrid"), (
            f"SSMEngine serves recurrent-state families; family "
            f"{cfg.family!r} should use the paged or lockstep engine"
        )
        self.cfg = cfg
        self.max_len = max_len
        self.max_slots = max_slots
        self.max_preemptions = max_preemptions
        if prefill_chunk == 0:  # CLI convention: 0 disables chunking
            prefill_chunk = None
        if prefill_chunk is None:
            # the state bank has no whole-prompt path; one max_len-sized
            # chunk is semantically identical (dt=0 padding is exact)
            prefill_chunk = max_len
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        self.hybrid = cfg.family == "hybrid"
        if self.hybrid:
            self.cache = PagedKVCache(
                num_layers=cfg.num_layers // cfg.attn_every,
                num_kv_heads=cfg.eff_kv_heads,
                head_dim=cfg.head_dim,
                dtype=jnp.dtype(cfg.dtype),
                max_slots=max_slots,
                max_context=max_len,
                page_size=page_size,
                num_pages=num_pages,
            )
        else:
            self._free = list(range(max_slots - 1, -1, -1))  # pop() -> slot 0 first
        self.bank = SlotStateBank(cfg, max_slots, jnp.dtype(cfg.dtype))
        self.executor = SSMExecutor(
            cfg, params, self.bank, self.cache if self.hybrid else None,
            max_len=max_len, attn_impl=attn_impl, ssd_impl=ssd_impl,
        )
        self.model = self.executor.model
        self.params = self.executor.params
        self.slots: dict[int, Sequence] = {}
        self._order = 0
        # uid -> (host state snapshot, attempt token list) parked by
        # preempt_youngest(snapshot=True)
        self._snapshots: dict[str, dict] = {}
        self._dirty = True
        self._init_api(admission=admission, seed=seed)
        self.utilization = UtilizationMetrics()
        self.stats.update({"decode_steps": 0, "prefills": 0,
                           "prefill_chunks": 0, "preemptions": 0,
                           "restores": 0})

    # ------------------------------------------------------------------
    # EngineBase hooks
    # ------------------------------------------------------------------
    def _validate(self, request: Request) -> None:
        validate_request(request, max_len=self.max_len)
        if self.hybrid:
            worst = cdiv(len(request.prompt) + request.sampling.max_new_tokens,
                         self.cache.page_size)
            if worst > self.cache.num_pages - 1:
                raise ValueError(
                    f"request {request.uid}: needs {worst} KV pages, pool "
                    f"has {self.cache.num_pages - 1} — it could never be "
                    f"scheduled"
                )

    def _find(self, uid: str) -> int | None:
        for slot, seq in self.slots.items():
            if seq.request.uid == uid:
                return slot
        return None

    def _cancel_active(self, uid: str) -> bool:
        slot = self._find(uid)
        if slot is None:
            return False
        seq = self._release(slot)
        self._finish_handle(seq.handle, FinishReason.CANCELLED)
        return True

    def _finish_handle(self, h, reason, error=None, now=None):
        self._snapshots.pop(h.uid, None)  # parked state must not leak
        super()._finish_handle(h, reason, error=error, now=now)

    # ------------------------------------------------------------------
    # protocol surface
    # ------------------------------------------------------------------
    @property
    def idle(self) -> bool:
        return not (len(self.admission) or self.slots or self._events)

    def capacity(self) -> int:
        free = (self.cache.free_slot_count if self.hybrid
                else len(self._free))
        return max(0, free - len(self.admission))

    # ------------------------------------------------------------------
    # admission + release
    # ------------------------------------------------------------------
    def _release(self, slot: int) -> Sequence:
        seq = self.slots.pop(slot)
        if self.hybrid:
            self.cache.release(slot)
        else:
            self._free.append(slot)
        self._dirty = True
        return seq

    def _admit(self) -> int:
        now = time.perf_counter()
        self._expire_queue(now)
        admitted = 0
        while True:
            req = self.admission.peek(now)
            if req is None:
                break
            if self.hybrid:
                if not self.cache.can_admit(len(req.prompt)):
                    break
                slot, _ = self.cache.admit(len(req.prompt))
            else:
                if not self._free:
                    break
                slot = self._free.pop()
            self.admission.pop(now)
            handle = self._handles[req.uid]
            self._order += 1
            seq = Sequence(req, handle, [], order=self._order,
                           phase="prefill", prefill_pos=0)
            self.slots[slot] = seq
            admitted += 1
            parked = self._snapshots.pop(req.uid, None)
            if parked is not None:
                # snapshot-preempted: resume decoding where it left off —
                # the bank gets the parked state verbatim alongside the
                # attempt's own token list (NOT the handle's delivered
                # stream, which is longer when the attempt was itself a
                # regeneration after an earlier discard preemption); its
                # last entry is the sampled-but-not-yet-fed pending token
                snap, attempt_tokens = parked
                self.bank.restore(slot, snap)
                seq.tokens = list(attempt_tokens)
                seq.phase = "decode"
                seq.prefill_pos = len(req.prompt)
                self._dirty = True
                self.stats["restores"] += 1
        return admitted

    def _first_token(self, slot: int, seq: Sequence, tok: int) -> None:
        """Prompt fully scanned into the slot state: deliver the sampled
        first token (attempt index 0 — after a preemption the handle
        de-duplicates it)."""
        now = time.perf_counter()
        seq.tokens.append(tok)
        seq.phase = "decode"
        self._dirty = True
        self.stats["prefills"] += 1
        if self._deliver(seq.handle, tok, 0, now):
            self._release(slot)

    # ------------------------------------------------------------------
    # preemption + snapshot/restore
    # ------------------------------------------------------------------
    def preempt_youngest(self, *, snapshot: bool = False) -> str | None:
        """Evict the youngest decoding sequence; returns its uid (None
        when nothing is decoding).

        Default: discard the slot's state and requeue the request — it
        re-prefills on re-admission and the ``(seed, token_index)``-keyed
        sampler regenerates a byte-identical stream (emitted deltas are
        de-duplicated). ``snapshot=True`` (pure SSM only) parks the slot's
        constant-size state pytree on the host instead; re-admission
        restores it and decoding resumes without re-prefill.
        """
        decoding = [(seq.order, slot) for slot, seq in self.slots.items()
                    if seq.phase == "decode"]
        if not decoding:
            return None
        _, slot = max(decoding)
        return self._preempt_slot(slot, snapshot=snapshot)

    def _preempt_slot(self, slot: int, snapshot: bool = False) -> str:
        seq = self.slots[slot]
        uid = seq.request.uid
        if snapshot:
            if self.hybrid:
                raise ValueError(
                    "snapshot preemption is pure-SSM only: a hybrid slot's "
                    "attention pages are released on preemption, so the "
                    "sequence must re-prefill (snapshot=False)"
                )
            if seq.phase == "decode" and seq.tokens:
                self._snapshots[uid] = (self.bank.snapshot(slot),
                                        list(seq.tokens))
        self._release(slot)
        self.stats["preemptions"] += 1
        h = seq.handle
        h.preemptions += 1
        if (self.max_preemptions is not None
                and h.preemptions > self.max_preemptions):
            self._finish_handle(
                h, FinishReason.PREEMPTED,
                error=f"request {uid}: preempted {h.preemptions} times "
                      f"(max_preemptions={self.max_preemptions})",
            )
        else:
            self._events.append(
                StreamEvent(uid, "preempted", t=time.perf_counter())
            )
            self.admission.requeue(seq.request, h.arrival)
        return uid

    def _ensure_decode_pages(self) -> None:
        """Hybrid only: grow every decoding slot's attention page chain
        before the fused step; pool exhaustion preempts youngest-first
        (the victim may be the requesting slot itself)."""
        for slot in sorted(s for s, q in self.slots.items()
                           if q.phase == "decode"):
            while slot in self.slots and self.slots[slot].phase == "decode":
                try:
                    if self.cache.ensure_append_capacity(slot):
                        self._dirty = True
                    break
                except RuntimeError:
                    decoding = [(q.order, s) for s, q in self.slots.items()
                                if q.phase == "decode"]
                    _, victim = max(decoding)
                    self._preempt_slot(victim)
                    if victim == slot:
                        break

    # ------------------------------------------------------------------
    # stepping
    # ------------------------------------------------------------------
    def _has_decodable(self) -> bool:
        return any(seq.phase == "decode" for seq in self.slots.values())

    def step(self) -> list[StreamEvent]:
        """Interleaved step: admit, advance the oldest in-flight prefill
        by one chunk, then run one fused decode dispatch over every
        decoding slot. Cold start (nothing decodable yet) drains prefill
        chunks back-to-back so the first token is never gated on an empty
        decode batch."""
        self._admit()
        while not self._has_decodable():
            if not self._prefill_step():
                return self._drain_events()
            self._admit()
        self._prefill_step()
        self._decode_once()
        return self._drain_events()

    def _prefill_step(self) -> bool:
        cand = [(q.order, s) for s, q in self.slots.items()
                if q.phase == "prefill"]
        if not cand:
            return False
        _, slot = min(cand)
        seq = self.slots[slot]
        prompt = seq.request.prompt
        c = self.prefill_chunk
        start = seq.prefill_pos
        valid = min(c, len(prompt) - start)
        tokens = np.zeros(c, np.int32)
        tokens[:valid] = prompt[start:start + valid]
        tok = self.executor.prefill_chunk(slot, seq, tokens, start, valid)
        self.stats["prefill_chunks"] += 1
        self.utilization.record_batch(decode_rows=0, prefill_rows=valid,
                                      padded_rows=c - valid, fused=False)
        seq.prefill_pos += valid
        if seq.prefill_pos >= len(prompt):
            self._first_token(slot, seq, tok)
        return True

    def _decode_inputs(self) -> DecodeInputs:
        s = self.max_slots
        mp = self.cache.block_tables.shape[1] if self.hybrid else 0
        bt = np.full((s, mp), NULL_PAGE, np.int32)
        lengths = np.zeros(s, np.int32)
        active = np.zeros(s, np.int32)
        tokens = np.zeros((s, 1), np.int32)
        top_ks = np.zeros(s, np.int32)
        seeds = np.zeros(s, np.int32)
        idx = np.zeros(s, np.int32)
        temps = np.zeros(s, np.float32)
        top_ps = np.ones(s, np.float32)
        greedy = True
        for slot, seq in self.slots.items():
            if seq.phase != "decode":
                continue
            sp = seq.request.sampling
            if self.hybrid:
                bt[slot] = self.cache.block_tables[slot]
                lengths[slot] = self.cache.lengths[slot]
            active[slot] = 1
            tokens[slot, 0] = seq.tokens[-1]
            top_ks[slot] = sp.top_k
            seeds[slot] = seq.handle.seed
            idx[slot] = len(seq.tokens)
            temps[slot] = sp.temperature
            top_ps[slot] = sp.top_p
            if sp.temperature > 0:
                greedy = False
        return DecodeInputs(
            tokens=tokens, temps=temps, top_ks=top_ks, top_ps=top_ps,
            seeds=seeds, idx=idx, active=active, block_tables=bt,
            lengths=lengths, greedy_only=greedy,
        )

    def _decode_once(self) -> None:
        if self.hybrid:
            self._ensure_decode_pages()
        decoding = sorted(s for s, q in self.slots.items()
                          if q.phase == "decode")
        if not decoding:
            return
        if self._dirty:
            self.executor.refresh(self._decode_inputs())
            self._dirty = False
        toks = self.executor.decode()
        self.stats["decode_steps"] += 1
        self.utilization.record(
            active=len(decoding), slots=self.max_slots,
            pages_used=(self.cache.num_pages - 1 - self.cache.pool.available
                        if self.hybrid else None),
            pages_total=self.cache.num_pages - 1 if self.hybrid else None,
        )
        self.utilization.record_batch(
            decode_rows=len(decoding), prefill_rows=0,
            padded_rows=self.max_slots - len(decoding), fused=False,
        )
        now = time.perf_counter()
        for slot in decoding:
            seq = self.slots[slot]
            tok = int(toks[slot])
            seq.tokens.append(tok)
            if self.hybrid:
                self.cache.append(slot)
            if self._deliver(seq.handle, tok, len(seq.tokens) - 1, now):
                self._release(slot)
