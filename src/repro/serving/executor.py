"""Device-side model executor for the paged serving engine.

This is the COMPUTE half of the scheduler/executor split
(``docs/serving.md``): it owns the jitted fused prefill/decode+sample step
functions and runs every one of them under ``shard_map`` on a 1-D
``("model",)`` mesh (:func:`repro.launch.mesh.make_serving_mesh`), with
Megatron-style tensor parallelism:

* attention q/kv heads, MLP ff and (untied) unembed columns are sharded
  over ``"model"``; row-parallel output projections reduce with
  ``psum_tp`` and the vocab-sharded logits gather with
  ``all_gather_logits`` (both marked inside the model code,
  identity when unsharded);
* the KV page pool is sharded along its **head** dimension
  (``(L, P, page, KVH, Dh)`` -> ``P(None, None, None, "model", None)``;
  int8 pools add per-page scale arrays sharded the same way minus the
  ``Dh`` axis), so every shard holds the SAME pages for its slice of heads — block
  tables, page ids, refcounts and the prefix index stay single host-side
  structures in the :class:`~repro.serving.scheduler.Scheduler`;
* everything the host feeds per step (block tables, lengths, tokens,
  sampling params) is replicated, and the sampled tokens come back
  replicated, so the scheduler never sees the mesh.

A 1-device mesh runs the identical code path (psum/gather compile away),
which is what keeps the conformance suite engine-shape-agnostic: the same
engine passes it on one device and on a forced multi-device CPU host
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``; CI runs that
variant on every PR).

The mesh is chosen automatically: the largest device count that divides
the model's effective kv heads, q heads, ff width (and padded vocab when
the unembedding is untied). Pass ``mesh=`` explicitly, or set a process
default with :func:`set_default_serving_mesh` /
:func:`serving_mesh_scope` (what ``launch/serve.py --mesh`` uses) —
the public engine signature stays mesh-free.
"""

from __future__ import annotations

from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map_unchecked
from repro.launch.mesh import make_serving_mesh
from repro.models import build_model
from repro.models.common import sample_tokens
from repro.models.lm import padded_vocab
from repro.parallel.axes import logical_to_spec
from repro.parallel.collectives import tensor_parallel
from repro.serving.kv_cache import write_prefill_pages
from repro.serving.scheduler import DecodeInputs, PrefillChunk, StepPlan

__all__ = [
    "ModelExecutor",
    "default_serving_mesh",
    "pick_tp",
    "place_serving_params",
    "serving_mesh_scope",
    "set_default_serving_mesh",
    "validate_serving_mesh",
]

# (L, P, page, KVH, Dh): only the head dim is sharded, so page ids and
# block-table entries mean the same thing on every shard
PAGE_SPEC = P(None, None, None, "model", None)
# int8 pools carry per-page-per-head scale arrays (L, P, page, KVH) — same
# head sharding, no Dh axis
SCALE_SPEC = P(None, None, None, "model")

_DEFAULT_MESH: Mesh | None = None


def set_default_serving_mesh(mesh: Mesh | None) -> None:
    """Process-wide default mesh for engines built without an explicit one
    (``launch/serve.py --mesh``). ``None`` restores auto-selection."""
    global _DEFAULT_MESH
    _DEFAULT_MESH = mesh


@contextmanager
def serving_mesh_scope(mesh: Mesh | None):
    """Temporarily pin the default serving mesh (tests: force a 1-device
    mesh next to the auto-sharded one and compare outputs)."""
    global _DEFAULT_MESH
    prev = _DEFAULT_MESH
    _DEFAULT_MESH = mesh
    try:
        yield
    finally:
        _DEFAULT_MESH = prev


def _tp_dims(cfg) -> list[int]:
    """Tensor dims the mesh size must divide for this config."""
    # pure-SSM configs carry default head fields no layer ever uses —
    # only constrain on attention dims when attention layers exist
    dims = [] if cfg.family == "ssm" else [cfg.eff_kv_heads, cfg.eff_heads]
    if cfg.ssm_state:
        # SSM/hybrid: d_inner is ff-sharded and the state bank shards on
        # ssm_heads; keep both so pure-SSM configs never vacuously admit
        # any mesh size
        dims += [cfg.ssm_heads, cfg.d_inner]
    if cfg.d_ff:
        dims.append(cfg.d_ff)
    if not cfg.tie_embeddings:
        dims.append(padded_vocab(cfg))
    return [d for d in dims if d]


def pick_tp(cfg, num_devices: int | None = None) -> int:
    """Largest tensor-parallel degree <= the device count that divides every
    sharded dim (kv heads bound it in practice: pages shard along heads)."""
    n = num_devices if num_devices is not None else jax.device_count()
    dims = _tp_dims(cfg)
    tp = max(1, n)
    while tp > 1 and any(d % tp for d in dims):
        tp -= 1
    return tp


def default_serving_mesh(cfg) -> Mesh:
    if _DEFAULT_MESH is not None:
        return _DEFAULT_MESH
    return make_serving_mesh(pick_tp(cfg))


def _mesh_tp(mesh: Mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def validate_serving_mesh(cfg, mesh: Mesh) -> int:
    """Check ``mesh`` can shard ``cfg`` (every TP dim divisible); returns
    the TP degree. Drivers call this ONCE up front so a bad explicit
    ``--mesh N`` fails fast in the main thread instead of crashing every
    worker as it builds its engine."""
    tp = _mesh_tp(mesh)
    bad = [d for d in _tp_dims(cfg) if d % tp]
    if bad:
        raise ValueError(
            f"serving mesh size {tp} does not divide sharded dims {bad} of "
            f"{cfg.name} (kv_heads={cfg.eff_kv_heads}, "
            f"heads={cfg.eff_heads}, d_ff={cfg.d_ff})"
        )
    return tp


def _serving_param_specs(model, mesh: Mesh, vocab_sharded: bool):
    """PartitionSpec tree for a params tree under the serving TP rules."""
    rules = {
        "heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "ssm_heads": "model",
        "vocab": "model" if vocab_sharded else None,
    }
    is_leaf = lambda v: v is None or (
        isinstance(v, tuple)
        and all(isinstance(e, (str, type(None))) for e in v)
    )
    specs = jax.tree.map(
        lambda ax: logical_to_spec(ax, rules=rules, mesh=mesh),
        model.axes(), is_leaf=is_leaf,
    )
    # the token-embedding table is looked up by GLOBAL token id
    # (jnp.take), so it must stay replicated even when the (untied)
    # unembedding shards its vocab columns
    if "embed" in specs:
        specs["embed"] = P()
    return specs


def place_serving_params(cfg, params, mesh: Mesh | None = None):
    """Shard a params tree for the serving mesh ONCE, up front.

    Multi-worker drivers (``launch/serve.py``) call this before spawning
    engines: every :class:`ModelExecutor` built from the returned tree sees
    leaves already carrying the target sharding, and its own ``device_put``
    is then a no-op — all workers share ONE placed copy instead of each
    materializing its own.
    """
    mesh = mesh if mesh is not None else default_serving_mesh(cfg)
    tp = validate_serving_mesh(cfg, mesh)
    if tp == 1:
        return params
    vocab_sharded = not cfg.tie_embeddings
    specs = _serving_param_specs(build_model(cfg), mesh, vocab_sharded)
    return jax.tree.map(
        lambda arr, spec: jax.device_put(arr, NamedSharding(mesh, spec)),
        params, specs,
    )


class ModelExecutor:
    """Owns params, page-pool device arrays and the jitted step functions.

    Stateless with respect to scheduling: it executes
    :class:`~repro.serving.scheduler.PrefillChunk` /
    :class:`~repro.serving.scheduler.DecodeInputs` work items and keeps
    device mirrors of the last decode batch so steady-state steps transfer
    nothing to the device.
    """

    def __init__(self, cfg, params, cache, *, max_len: int,
                 mesh: Mesh | None = None, attn_impl: str | None = None):
        self.cfg = cfg
        # serving defaults to "auto" (not the model-default "xla_chunked"):
        # on TPU every hot path — paged decode, chunked prefill, legacy
        # whole-prompt flash — dispatches its Pallas kernel per shard; on
        # CPU "auto" resolves to the identical XLA reference lowering
        self.model = build_model(cfg, attn_impl=attn_impl or "auto")
        self.cache = cache
        self.max_len = max_len
        self.nf = cfg.num_frontend_tokens if cfg.family == "vlm" else 0
        self.mesh = mesh if mesh is not None else default_serving_mesh(cfg)
        self.tp = validate_serving_mesh(cfg, self.mesh)
        self.vocab_sharded = (not cfg.tie_embeddings) and self.tp > 1
        self.param_specs = _serving_param_specs(
            self.model, self.mesh, self.vocab_sharded
        )
        self.params = self._place(params)

        self._decode_fns: dict[bool, object] = {}
        self._mixed_fns: dict[bool, object] = {}
        self._chunk_fn = None
        self._prefill_fns: dict[int, object] = {}
        self._verify_fns: dict[bool, object] = {}
        # device mirrors of the last decode batch, PACKED into one int32
        # and one f32 array (refreshed only when the scheduler reports a
        # composition change). Packing matters off-TPU: per-transfer
        # dispatch overhead dominates small-step serving, so a refresh is
        # two device_puts instead of nine, and a chunk rides in two more
        # instead of six (see ``_DI_COLS``).
        self._greedy_only = True
        self._di = self._df = None

    # ------------------------------------------------------------------
    # sharding
    # ------------------------------------------------------------------
    def _place(self, params):
        """Shard params + page pool onto the mesh (no-op layout on 1 dev).

        When the caller pre-placed the tree (:func:`place_serving_params`,
        the multi-worker path) every ``device_put`` here no-ops and all
        executors share one device copy of the weights."""
        if self.tp == 1:
            return params
        ns = lambda spec: NamedSharding(self.mesh, spec)
        placed = jax.tree.map(
            lambda arr, spec: jax.device_put(arr, ns(spec)),
            params, self.param_specs,
        )
        self.cache._reshard(
            {key: ns(spec) for key, spec in self._page_specs().items()}
        )
        return placed

    def _page_specs(self) -> dict:
        """Per-array PartitionSpecs for the cache's page dict (scale arrays
        drop the Dh axis but shard the same head dim)."""
        return {
            key: PAGE_SPEC if arr.ndim == 5 else SCALE_SPEC
            for key, arr in self.cache.pages.items()
        }

    def _smap(self, fn, in_specs, out_specs):
        return shard_map_unchecked(
            fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs
        )

    def _tp_ctx(self):
        return tensor_parallel("model", vocab_sharded=self.vocab_sharded)

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    # Packed decode batch ``di`` (S, MP+6) int32: block table row, then
    # _DI_COLS-indexed columns [lens, active, tokens, top_ks, seeds, idx];
    # ``df`` (S, 2) f32: [temps, top_ps]. The jitted fns slice at static
    # offsets (MP is fixed per cache) and return an ADVANCED ``di`` —
    # lens/idx stepped, sampled tokens written back — so the steady-state
    # loop feeds device outputs straight into the next step.
    _DI_COLS = 6

    def _decode_fn(self, greedy_only: bool):
        """ONE dispatch per decode step: sharded model step + sampling
        fused, logits never leave the device (the vocab gather is an
        on-device collective). ``greedy_only`` is a host-known flag — the
        all-greedy compile pays a plain argmax and the per-row
        top-k/top-p/seeded sampler only costs when a sampled request is in
        flight. The advanced packed batch returns replicated and feeds the
        next step directly."""
        if greedy_only not in self._decode_fns:
            cfg = self.cfg

            def fn(params, pages, di, df):
                mp = di.shape[1] - self._DI_COLS
                bt, lens, active = di[:, :mp], di[:, mp], di[:, mp + 1]
                with self._tp_ctx():
                    pages, logits = self.model.decode_step_paged(
                        params, pages, bt, lens, di[:, mp + 2:mp + 3]
                    )
                    if greedy_only:
                        toks = jnp.argmax(
                            logits[..., :cfg.vocab_size], axis=-1
                        ).astype(jnp.int32)
                    else:
                        toks = sample_tokens(logits, df[:, 0], di[:, mp + 3],
                                             df[:, 1], di[:, mp + 4],
                                             di[:, mp + 5], cfg.vocab_size)
                di = di.at[:, mp].set(lens + active)
                di = di.at[:, mp + 2].set(toks)
                di = di.at[:, mp + 5].add(active)
                return pages, di, toks

            page_specs = self._page_specs()
            smapped = self._smap(
                fn,
                in_specs=(self.param_specs, page_specs) + (P(),) * 2,
                out_specs=(page_specs, P(), P()),
            )
            self._decode_fns[greedy_only] = jax.jit(
                smapped, donate_argnums=(1, 2)
            )
        return self._decode_fns[greedy_only]

    def refresh(self, inputs: DecodeInputs) -> None:
        """Mirror a freshly assembled decode batch to the device (two
        transfers: the packed int32 batch and the packed f32 sampling
        params)."""
        self._greedy_only = inputs.greedy_only
        bt = inputs.block_tables
        s, mp = bt.shape
        di = np.empty((s, mp + self._DI_COLS), np.int32)
        di[:, :mp] = bt
        di[:, mp] = inputs.lengths
        di[:, mp + 1] = inputs.active
        di[:, mp + 2] = inputs.tokens[:, 0]
        di[:, mp + 3] = inputs.top_ks
        di[:, mp + 4] = inputs.seeds
        di[:, mp + 5] = inputs.idx
        self._di = jnp.asarray(di)
        self._df = jnp.asarray(
            np.stack([inputs.temps, inputs.top_ps], axis=1).astype(np.float32)
        )

    def decode(self, inputs: DecodeInputs | None = None) -> np.ndarray:
        """Run one decode step. ``inputs`` refreshes the device mirrors
        (admission/eviction/page growth); None reuses last step's device
        outputs — the steady-state loop transfers nothing to the device.
        Returns the sampled token per slot, (S,) int32 on the host."""
        if inputs is not None:
            self.refresh(inputs)
        pages = dict(self.cache.pages)
        fn = self._decode_fn(self._greedy_only)
        pages, self._di, toks = fn(self.params, pages, self._di, self._df)
        self.cache.swap_pages(pages)
        return np.asarray(toks)

    # ------------------------------------------------------------------
    # fused mixed step (decode batch + one prefill chunk, one dispatch)
    # ------------------------------------------------------------------
    def _pack_chunk(self, chunk) -> tuple[jax.Array, jax.Array]:
        """Pack one prefill chunk's host state into two transfers:
        ``ci`` (MP+C+4,) int32 = [block-table row | padded tokens | start,
        valid, top_k, seed] and ``cf`` (2,) f32 = [temperature, top_p]."""
        sp = chunk.seq.request.sampling
        row = self.cache.block_tables[chunk.slot]
        mp, c = row.shape[0], chunk.tokens.shape[0]
        ci = np.empty(mp + c + 4, np.int32)
        ci[:mp] = row
        ci[mp:mp + c] = chunk.tokens
        ci[mp + c:] = (chunk.start, chunk.valid, sp.top_k,
                       chunk.seq.handle.seed)
        cf = np.array([sp.temperature, sp.top_p], np.float32)
        return jnp.asarray(ci), jnp.asarray(cf)

    def _mixed_fn(self, greedy_only: bool):
        """ONE dispatch per mixed step: every decode slot AND one prefill
        chunk run a single sharded model step + fused sampling over S+C
        single-token rows — the full-occupancy step the interleaved path's
        two dispatches approximate. Decode rows keep their exact decode
        semantics (same device-mirror feedback: sampled tokens / advanced
        lengths / advanced sample indices return replicated and feed the
        next step); the chunk contributes C rows sharing its slot's
        block-table row and one extra sampled token at index 0, meaningful
        only on the prompt's final chunk. ``greedy_only`` covers the chunk
        too — a sampled chunk (temperature > 0) selects the sampling
        compile, where greedy rows still reduce to argmax, so streams
        cannot depend on the compile chosen."""
        if greedy_only not in self._mixed_fns:
            cfg = self.cfg

            def fn(params, pages, di, df, ci, cf):
                s = di.shape[0]
                mp = di.shape[1] - self._DI_COLS
                c = ci.shape[0] - mp - 4
                bt, lens, active = di[:, :mp], di[:, mp], di[:, mp + 1]
                crow, ctoks = ci[:mp], ci[mp:mp + c]
                cstart, cvalid = ci[mp + c], ci[mp + c + 1]
                with self._tp_ctx():
                    # rows [0,S): decode slots at position = length (-1 when
                    # idle); rows [S,S+C): the chunk at start+i (-1 past valid)
                    cidx = jnp.arange(c, dtype=jnp.int32)
                    positions = jnp.concatenate([
                        jnp.where(active == 1, lens, -1),
                        jnp.where(cidx < cvalid, cstart + cidx, -1),
                    ]).astype(jnp.int32)
                    tables = jnp.concatenate([
                        bt, jnp.broadcast_to(crow, (c, mp)),
                    ])
                    pages, logits = self.model.mixed_step_paged(
                        params, pages, tables, positions,
                        jnp.concatenate([di[:, mp + 2:mp + 3],
                                         ctoks[:, None]]),
                        num_decode=s, chunk_valid=cvalid,
                    )  # logits (S+1, Vp): decode rows + the chunk's row
                    if greedy_only:
                        toks = jnp.argmax(
                            logits[..., :cfg.vocab_size], axis=-1
                        ).astype(jnp.int32)
                    else:
                        toks = sample_tokens(
                            logits,
                            jnp.concatenate([df[:, 0], cf[0][None]]),
                            jnp.concatenate([di[:, mp + 3],
                                             ci[mp + c + 2][None]]),
                            jnp.concatenate([df[:, 1], cf[1][None]]),
                            jnp.concatenate([di[:, mp + 4],
                                             ci[mp + c + 3][None]]),
                            jnp.concatenate([di[:, mp + 5],
                                             jnp.zeros((1,), jnp.int32)]),
                            cfg.vocab_size,
                        )
                dtoks = toks[:s]
                di = di.at[:, mp].set(lens + active)
                di = di.at[:, mp + 2].set(dtoks)
                di = di.at[:, mp + 5].add(active)
                return pages, di, dtoks, toks[s]

            page_specs = self._page_specs()
            smapped = self._smap(
                fn,
                in_specs=(self.param_specs, page_specs) + (P(),) * 4,
                out_specs=(page_specs, P(), P(), P()),
            )
            self._mixed_fns[greedy_only] = jax.jit(
                smapped, donate_argnums=(1, 2)
            )
        return self._mixed_fns[greedy_only]

    def step(self, plan: StepPlan) -> tuple[np.ndarray | None, int | None]:
        """Execute one step plan. Returns ``(decode_toks, chunk_tok)``:
        the sampled token per slot ((S,) int32 on the host, None when the
        plan had no decode rows) and the chunk's sampled first token (None
        when the plan had no chunk; meaningful only on a final chunk).

        Degenerate plans route to the specialized dispatches — chunk-only
        (cold start / post-burst refill) runs the chunk kernel without S
        dead decode rows, decode-only (steady state between prefills) runs
        the existing decode step with its zero-transfer device mirrors."""
        chunk = plan.chunk
        if not plan.decode_slots:
            ctok = self.prefill_chunk(chunk) if chunk is not None else None
            return None, ctok
        if chunk is None:
            return self.decode(plan.decode), None
        if plan.decode is not None:
            self.refresh(plan.decode)
        sp = chunk.seq.request.sampling
        fn = self._mixed_fn(self._greedy_only and sp.temperature <= 0.0)
        ci, cf = self._pack_chunk(chunk)
        pages = dict(self.cache.pages)
        pages, self._di, toks, ctok = fn(
            self.params, pages, self._di, self._df, ci, cf
        )
        self.cache.swap_pages(pages)
        return np.asarray(toks), int(ctok)

    # ------------------------------------------------------------------
    # speculative verify (one bundle = one fused dispatch)
    # ------------------------------------------------------------------
    # Packed bundle ``vi`` (MP+W+5,) int32 = [block-table row | padded
    # tokens | start, valid, top_k, seed, idx0] and ``vf`` (2,) f32 =
    # [temperature, top_p] — the chunk packing plus the base token index,
    # which keys each row's sample.
    def _verify_fn(self, greedy_only: bool):
        """ONE dispatch scores a whole speculation bundle: the sharded
        verify forward (``models/lm.py::verify_step_paged`` — a k+1-row
        chunk over the slot's own block table) plus per-row sampling,
        fused so logits never leave the device. Row j samples with the
        request's ``(seed, idx0 + j)`` key — the SAME key a sequential
        decode loop would use for token index ``idx0 + j`` — which is the
        whole acceptance argument: where the drafted prefix matches what
        sequential decoding would have produced, the logits match, the
        keys match, and therefore the samples match (greedy rows are a
        plain argmax, so greedy streams are byte-identical by
        construction). ``greedy_only`` picks the argmax compile exactly
        like the decode/mixed steps."""
        if greedy_only not in self._verify_fns:
            cfg = self.cfg
            mp = self.cache.block_tables.shape[1]

            def fn(params, pages, vi, vf):
                w = vi.shape[0] - mp - 5
                row, tokens = vi[:mp], vi[mp:mp + w]
                start, valid = vi[mp + w], vi[mp + w + 1]
                with self._tp_ctx():
                    pages, logits = self.model.verify_step_paged(
                        params, pages, row, tokens, start, valid,
                    )  # (W, Vp): row j scores token index idx0 + j
                    if greedy_only:
                        toks = jnp.argmax(
                            logits[..., :cfg.vocab_size], axis=-1
                        ).astype(jnp.int32)
                    else:
                        ones = jnp.ones((w,), jnp.float32)
                        toks = sample_tokens(
                            logits, vf[0] * ones,
                            jnp.broadcast_to(vi[mp + w + 2], (w,)),
                            vf[1] * ones,
                            jnp.broadcast_to(vi[mp + w + 3], (w,)),
                            vi[mp + w + 4] + jnp.arange(w, dtype=jnp.int32),
                            cfg.vocab_size,
                        )
                return pages, toks

            page_specs = self._page_specs()
            smapped = self._smap(
                fn,
                in_specs=(self.param_specs, page_specs) + (P(),) * 2,
                out_specs=(page_specs, P()),
            )
            self._verify_fns[greedy_only] = jax.jit(
                smapped, donate_argnums=(1,)
            )
        return self._verify_fns[greedy_only]

    def verify(self, bundle) -> np.ndarray:
        """Dispatch one speculation bundle (``scheduler.SpecBundle``).
        Returns the sampled token per bundle row, (W,) int32 on the host
        — row 0 is the true next token, row j (j < valid) the true token
        IF rows < j were all accepted; rows past ``valid`` are garbage
        the engine ignores. The dispatch also scattered the bundle's k+1
        candidate KV positions; the engine commits the accepted prefix by
        setting the slot's length (rollback = rewind, nothing else)."""
        sp = bundle.seq.request.sampling
        row = self.cache.block_tables[bundle.slot]
        mp, w = row.shape[0], bundle.tokens.shape[0]
        vi = np.empty(mp + w + 5, np.int32)
        vi[:mp] = row
        vi[mp:mp + w] = bundle.tokens
        vi[mp + w:] = (bundle.start, bundle.valid, sp.top_k,
                       bundle.seq.handle.seed, len(bundle.seq.tokens))
        vf = np.array([sp.temperature, sp.top_p], np.float32)
        fn = self._verify_fn(sp.temperature <= 0.0)
        pages, toks = fn(
            self.params, dict(self.cache.pages),
            jnp.asarray(vi), jnp.asarray(vf),
        )
        self.cache.swap_pages(pages)
        return np.asarray(toks)

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------
    def _chunk_prefill_fn(self):
        """ONE jitted function (static chunk shape) covers every prompt
        length — sharded chunk forward + page scatter + sample fused. The
        sampled token is only meaningful on a prompt's final chunk.

        Under the mesh this runs per shard exactly like decode: the chunk
        attention (Pallas kernel on TPU, XLA ref elsewhere — see
        ``ops.paged_prefill_attention``) sees the local kv-head slice of
        the page pool with the block-table row replicated."""
        if self._chunk_fn is None:
            mp = self.cache.block_tables.shape[1]

            def fn(params, pages, ci, cf):
                c = ci.shape[0] - mp - 4
                row, tokens = ci[:mp], ci[mp:mp + c]
                start, valid = ci[mp + c], ci[mp + c + 1]
                with self._tp_ctx():
                    pages, logits = self.model.prefill_chunk(
                        params, pages, row, tokens, start, valid,
                    )
                    tok = sample_tokens(
                        logits[None], cf[0][None], ci[mp + c + 2][None],
                        cf[1][None], ci[mp + c + 3][None],
                        jnp.zeros((1,), jnp.int32), self.cfg.vocab_size,
                    )
                return pages, tok[0]

            page_specs = self._page_specs()
            smapped = self._smap(
                fn,
                in_specs=(self.param_specs, page_specs) + (P(),) * 2,
                out_specs=(page_specs, P()),
            )
            self._chunk_fn = jax.jit(smapped, donate_argnums=(1,))
        return self._chunk_fn

    def prefill_chunk(self, work: PrefillChunk) -> int:
        """Dispatch one chunk; returns the sampled first token (meaningful
        only when this was the prompt's final chunk)."""
        ci, cf = self._pack_chunk(work)
        pages, tok = self._chunk_prefill_fn()(
            self.params, dict(self.cache.pages), ci, cf
        )
        self.cache.swap_pages(pages)
        return int(tok)

    # ------------------------------------------------------------------
    # legacy whole-prompt prefill (prefill_chunk=None / vlm)
    # ------------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        b = 16
        while b < plen:
            b *= 2
        return min(b, max(self.max_len - self.nf, 1))

    def _prefill_fn(self, bucket: int):
        """Whole-prompt path: ONE dispatch per admission — sharded prefill
        forward + page scatter + first-token sample, jitted per
        prompt-length bucket."""
        if bucket not in self._prefill_fns:
            s_total = self.nf + bucket

            def fn(params, batch, idx, pages, row, valid_len,
                   temp, tk, tp, rseed):
                with self._tp_ctx():
                    cache, logits = self.model.prefill(
                        params, batch, s_total, logits_index=idx
                    )
                    # cache["k"] is (L, 1, S, KVH/tp, Dh): the local head
                    # slice scatters into the local page shard — positions
                    # and page ids are shard-invariant
                    pages = write_prefill_pages(
                        pages, cache["k"][:, 0], cache["v"][:, 0],
                        row, valid_len,
                    )
                    tok = sample_tokens(
                        logits, temp[None], tk[None], tp[None], rseed[None],
                        jnp.zeros((1,), jnp.int32), self.cfg.vocab_size,
                    )
                return pages, tok[0]

            page_specs = self._page_specs()
            smapped = self._smap(
                fn,
                in_specs=(self.param_specs, P(), P(), page_specs)
                + (P(),) * 6,
                out_specs=(page_specs, P()),
            )
            self._prefill_fns[bucket] = jax.jit(
                smapped, donate_argnums=(3,)
            )
        return self._prefill_fns[bucket]

    def prefill_whole(self, request, seed: int, slot: int) -> int:
        """Prefill a whole prompt into its pages; returns the first token."""
        plen = len(request.prompt)
        ctx = self.nf + plen
        bucket = self._bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = request.prompt
        batch = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "vlm":
            batch["vision_embeds"] = jnp.zeros(
                (1, self.nf, self.cfg.d_model), jnp.dtype(self.cfg.dtype)
            )
        sp = request.sampling
        pages, tok = self._prefill_fn(bucket)(
            self.params, batch, jnp.asarray(ctx - 1, jnp.int32),
            dict(self.cache.pages),
            self.cache.device_row(slot),
            jnp.asarray(ctx, jnp.int32),
            jnp.asarray(sp.temperature, jnp.float32),
            jnp.asarray(sp.top_k, jnp.int32),
            jnp.asarray(sp.top_p, jnp.float32),
            jnp.asarray(seed, jnp.int32),
        )
        self.cache.swap_pages(pages)
        return int(tok)
