"""Host-side scheduling policy for the paged serving engine.

This module is the POLICY half of the scheduler/executor split
(``docs/serving.md``): everything the continuous-batching engine decides on
the host — slot placement, chunked-prefill interleaving, prefix-sharing
deferral, preemption victim selection, page accounting and decode-batch
assembly — lives here as plain Python + numpy, with no jax import and no
device dispatch. The device half (:class:`repro.serving.executor.
ModelExecutor`) consumes the work items this module produces
(:class:`PrefillChunk`, :class:`DecodeInputs`) and never makes decisions.

The split is what makes sharded serving tractable: ONE scheduler instance
drives the whole mesh. Because the executor shards the KV page pool along
the head dimension, block tables and page ids are identical on every shard,
so the prefix/refcount index stays a single host-side structure — no
replication, no cross-shard reconciliation (the ROADMAP's
replicate-vs-shard question resolves to "neither: shard only the tensor
dim the host never indexes by").

It is also what makes the policy unit-testable: every method here can be
driven against a :class:`~repro.serving.kv_cache.PagedKVCache` without
compiling or dispatching a single model step (see
``tests/test_serving_sharded.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.serving.kv_cache import NULL_PAGE, PagedKVCache

__all__ = [
    "DecodeInputs",
    "PrefillChunk",
    "Scheduler",
    "Sequence",
]


@dataclass
class Sequence:
    """One in-flight sequence (a slot's host-side state)."""

    request: object         # serving.api.Request
    handle: object          # serving.api.RequestHandle
    tokens: list[int]       # this ATTEMPT's tokens (feed decode; the handle
                            # owns the emitted stream, which survives
                            # preemption)
    order: int = 0          # admission sequence number (preemption picks
                            # youngest)
    phase: str = "decode"   # "prefill" until the whole prompt is cached
    prefill_pos: int = 0    # prompt positions already resident in pages


@dataclass
class PrefillChunk:
    """One chunk of prefill work for the executor: ``tokens`` is the padded
    fixed-size chunk, positions ``[start, start+valid)`` are real."""

    slot: int
    seq: Sequence
    tokens: np.ndarray
    start: int
    valid: int


@dataclass
class DecodeInputs:
    """One decode step's host-assembled batch (numpy; the executor mirrors
    it to the device only when the composition changed)."""

    tokens: np.ndarray        # (S, 1) int32 last token per slot
    temps: np.ndarray         # (S,) f32
    top_ks: np.ndarray        # (S,) int32
    top_ps: np.ndarray        # (S,) f32
    seeds: np.ndarray         # (S,) int32
    idx: np.ndarray           # (S,) int32 per-request token index
    active: np.ndarray        # (S,) int32 1 for decoding slots
    block_tables: np.ndarray  # (S, MP) int32; masked slots -> null page
    lengths: np.ndarray       # (S,) int32; masked slots -> 0
    greedy_only: bool = True


class Scheduler:
    """Pure-host scheduler over a :class:`PagedKVCache`'s bookkeeping.

    Owns the slot map and every serving *decision*; owns NO jitted function
    and no device array. The engine translates its outputs into lifecycle
    events and executor calls.
    """

    def __init__(
        self,
        cache: PagedKVCache,
        *,
        prefill_chunk: int | None,
        chunked: bool,
        prefix_sharing: bool,
        extra_ctx: int = 0,
    ):
        self.cache = cache
        self.prefill_chunk = prefill_chunk
        self.chunked = chunked
        self.prefix_sharing = prefix_sharing and chunked
        self.extra_ctx = extra_ctx  # non-token context (vlm frontend tokens)
        self.slots: dict[int, Sequence] = {}
        self.dirty = True  # decode-batch composition changed since last build
        self._admit_counter = 0

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _pending_prefix_gain(self, tokens: list[int]) -> int:
        """Longest full-page prefix of ``tokens`` that an IN-FLIGHT prefill
        will publish to the prefix index but has not yet (its chunks haven't
        reached those pages). Admission waits for such a prefix instead of
        allocating private pages for content that is about to be shared —
        without this, a burst of same-prefix requests admitted in one step
        would get zero sharing."""
        ps = self.cache.page_size
        limit = self.cache._prefix_limit(tokens)
        best = 0
        for seq in self.slots.values():
            if seq.phase != "prefill":
                continue
            other = seq.request.prompt
            n = 0
            for i in range(min(limit, len(other) // ps)):
                if tokens[i * ps:(i + 1) * ps] != other[i * ps:(i + 1) * ps]:
                    break
                n += 1
            best = max(best, n * ps)
        return best

    def can_place(self, request) -> bool:
        """Whether the queue head should be admitted NOW — false when the
        cache lacks slots/pages for it, or when deferring would let it share
        a prefix an in-flight prefill is about to publish."""
        tokens = request.prompt if self.prefix_sharing else None
        if tokens is not None:
            matched = self.cache.match_prefix(tokens)[1]
            if self._pending_prefix_gain(tokens) > matched:
                return False  # a longer shared prefix lands within a few chunks
        return self.cache.can_admit(self.extra_ctx + len(request.prompt), tokens)

    def place(self, request, handle) -> tuple[int, Sequence, int]:
        """Claim a slot and pages for ``request``. Returns
        ``(slot, sequence, cached_len)``; chunked sequences start in the
        ``prefill`` phase at ``prefill_pos=cached_len`` (shared prefix pages
        already mapped), legacy whole-prompt sequences start decode-ready
        (the engine runs their prefill immediately)."""
        tokens = request.prompt if self.prefix_sharing else None
        slot, cached = self.cache.admit(
            self.extra_ctx + len(request.prompt), tokens
        )
        self._admit_counter += 1
        seq = Sequence(
            request, handle, [], order=self._admit_counter,
            phase="prefill" if self.chunked else "decode",
            prefill_pos=cached,
        )
        self.slots[slot] = seq
        self.dirty = True
        return slot, seq, cached

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------
    def next_prefill(self) -> PrefillChunk | None:
        """The OLDEST in-flight prefill's next fixed-size chunk (the engine
        runs at most one per step so concurrent decodes stall for one
        chunk's latency at worst), or None when nothing is prefilling."""
        cands = [(q.order, s) for s, q in self.slots.items()
                 if q.phase == "prefill"]
        if not cands:
            return None
        _, slot = min(cands)
        seq = self.slots[slot]
        prompt = seq.request.prompt
        start = seq.prefill_pos
        c = self.prefill_chunk
        valid = min(c, len(prompt) - start)
        toks = np.zeros((c,), np.int32)
        toks[:valid] = prompt[start:start + valid]
        return PrefillChunk(slot, seq, toks, start, valid)

    def complete_chunk(self, work: PrefillChunk) -> bool:
        """Record a dispatched chunk: advance the prefill cursor, publish
        the covered full pages to the prefix index (dispatch order is
        execution order, so a later admission can share them safely).
        Returns True when the prompt is now fully cached."""
        seq = work.seq
        prompt = seq.request.prompt
        seq.prefill_pos = work.start + work.valid
        if self.prefix_sharing:
            self.cache.register_prefix(work.slot, prompt, seq.prefill_pos)
        return seq.prefill_pos == len(prompt)

    def begin_decode(self, slot: int) -> None:
        """Prompt fully cached: the slot joins the decode batch."""
        self.slots[slot].phase = "decode"
        self.dirty = True

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def find(self, uid: str) -> int | None:
        for slot, seq in self.slots.items():
            if seq.request.uid == uid:
                return slot
        return None

    def release(self, slot: int) -> Sequence:
        """Free a finished/cancelled sequence's slot and pages."""
        seq = self.slots.pop(slot)
        self.cache.release(slot)
        self.dirty = True
        return seq

    def has_decodable(self) -> bool:
        return any(q.phase == "decode" for q in self.slots.values())

    def decoding(self) -> list[tuple[int, Sequence]]:
        """(slot, seq) pairs currently in the decode phase, slot order."""
        return sorted(
            (s, q) for s, q in self.slots.items() if q.phase == "decode"
        )

    def evict_youngest(self) -> tuple[int, Sequence]:
        """Release the youngest sequence (any phase) and hand it back for
        the engine to requeue or finish ``preempted``."""
        slot = max(self.slots, key=lambda s: self.slots[s].order)
        return slot, self.release(slot)

    def ensure_decode_capacity(self) -> list[Sequence]:
        """Give every DECODING slot a writable page for its next position —
        growing at page boundaries, copying a shared (refcount > 1) page
        anywhere else — evicting the youngest sequences if the pool runs
        dry. A lone sequence can always grow (submit rejects requests that
        exceed the whole pool), so this terminates with at least one slot
        making progress. Returns the evicted sequences (pages already
        released) for the engine's preemption bookkeeping."""
        preempted: list[Sequence] = []
        order = sorted(
            (s for s, q in self.slots.items() if q.phase == "decode"),
            key=lambda s: self.slots[s].order,
        )
        for slot in order:
            while slot in self.slots:
                try:
                    if self.cache.ensure_append_capacity(slot):
                        self.dirty = True
                    break
                except RuntimeError:
                    preempted.append(self.evict_youngest()[1])
        return preempted

    # ------------------------------------------------------------------
    # decode-batch assembly
    # ------------------------------------------------------------------
    def build_decode_inputs(self) -> DecodeInputs:
        """Assemble the fixed-width decode batch from host state. Slots that
        are idle or still prefilling are masked to the null page / length 0
        so the decode write lands in the sink and their (discarded)
        attention output reads nothing. Fresh copies throughout — the cache
        tables mutate between steps and the executor transfers these
        asynchronously."""
        n = self.cache.max_slots
        tokens = np.zeros((n, 1), np.int32)
        temps = np.zeros((n,), np.float32)
        top_ks = np.zeros((n,), np.int32)
        top_ps = np.ones((n,), np.float32)
        seeds = np.zeros((n,), np.int32)
        idx = np.zeros((n,), np.int32)
        active = np.zeros((n,), np.int32)
        bt = self.cache.block_tables.copy()
        lens = self.cache.lengths.copy()
        live = np.zeros((n,), bool)
        greedy = True
        for slot, seq in self.slots.items():
            if seq.phase != "decode":
                continue
            live[slot] = True
            tokens[slot, 0] = seq.tokens[-1]
            sp = seq.request.sampling
            temps[slot] = sp.temperature
            top_ks[slot] = sp.top_k
            top_ps[slot] = sp.top_p
            seeds[slot] = seq.handle.seed
            idx[slot] = len(seq.tokens)
            active[slot] = 1
            greedy = greedy and sp.temperature <= 0.0
        bt[~live] = NULL_PAGE
        lens[~live] = 0
        self.dirty = False
        return DecodeInputs(tokens, temps, top_ks, top_ps, seeds, idx,
                            active, bt, lens, greedy_only=greedy)

    # ------------------------------------------------------------------
    # gauges
    # ------------------------------------------------------------------
    def occupancy(self) -> tuple[int, int]:
        """(decoding slots, total slots) for the utilization gauges."""
        return (sum(1 for q in self.slots.values() if q.phase == "decode"),
                self.cache.max_slots)

    def page_utilization(self) -> tuple[int, int]:
        """(pages in use, usable pages) — excludes the reserved null page."""
        usable = self.cache.num_pages - 1
        return usable - self.cache.pool.available, usable
