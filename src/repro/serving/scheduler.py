"""Host-side scheduling policy for the paged serving engine.

This module is the POLICY half of the scheduler/executor split
(``docs/serving.md``): everything the continuous-batching engine decides on
the host — slot placement, chunked-prefill interleaving, prefix-sharing
deferral, preemption victim selection, page accounting and decode-batch
assembly — lives here as plain Python + numpy, with no jax import and no
device dispatch. The device half (:class:`repro.serving.executor.
ModelExecutor`) consumes the work items this module produces
(:class:`PrefillChunk`, :class:`DecodeInputs`) and never makes decisions.

The split is what makes sharded serving tractable: ONE scheduler instance
drives the whole mesh. Because the executor shards the KV page pool along
the head dimension, block tables and page ids are identical on every shard,
so the prefix/refcount index stays a single host-side structure — no
replication, no cross-shard reconciliation (the ROADMAP's
replicate-vs-shard question resolves to "neither: shard only the tensor
dim the host never indexes by").

It is also what makes the policy unit-testable: every method here can be
driven against a :class:`~repro.serving.kv_cache.PagedKVCache` without
compiling or dispatching a single model step (see
``tests/test_serving_sharded.py``).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.serving.kv_cache import NULL_PAGE, PagedKVCache

__all__ = [
    "DecodeInputs",
    "PrefillChunk",
    "Scheduler",
    "Sequence",
    "SpecBundle",
    "StepPlan",
]


@dataclass
class Sequence:
    """One in-flight sequence (a slot's host-side state)."""

    request: object         # serving.api.Request
    handle: object          # serving.api.RequestHandle
    tokens: list[int]       # this ATTEMPT's tokens (feed decode; the handle
                            # owns the emitted stream, which survives
                            # preemption)
    order: int = 0          # admission sequence number (preemption picks
                            # youngest)
    phase: str = "decode"   # "prefill" until the whole prompt is cached
    prefill_pos: int = 0    # prompt positions already resident in pages


@dataclass
class PrefillChunk:
    """One chunk of prefill work for the executor: ``tokens`` is the padded
    fixed-size chunk, positions ``[start, start+valid)`` are real."""

    slot: int
    seq: Sequence
    tokens: np.ndarray
    start: int
    valid: int


@dataclass
class DecodeInputs:
    """One decode step's host-assembled batch (numpy; the executor mirrors
    it to the device only when the composition changed)."""

    tokens: np.ndarray        # (S, 1) int32 last token per slot
    temps: np.ndarray         # (S,) f32
    top_ks: np.ndarray        # (S,) int32
    top_ps: np.ndarray        # (S,) f32
    seeds: np.ndarray         # (S,) int32
    idx: np.ndarray           # (S,) int32 per-request token index
    active: np.ndarray        # (S,) int32 1 for decoding slots
    block_tables: np.ndarray  # (S, MP) int32; masked slots -> null page
    lengths: np.ndarray       # (S,) int32; masked slots -> 0
    greedy_only: bool = True


@dataclass
class SpecBundle:
    """One speculation bundle: chunk-style verify rows for ONE decoding
    slot. Row 0 feeds the last committed token (whose KV is not yet
    cached — exactly what a plain decode row would feed), rows 1..k feed
    the proposer's drafts; the executor scores all of them in one fused
    dispatch over the slot's own block table at positions
    ``start .. start+valid-1``. ``tokens`` is padded to the static bundle
    width (``spec_k + 1``) so the jitted verify step never recompiles."""

    slot: int
    seq: Sequence
    tokens: np.ndarray   # (W,) int32 padded [t_last, d_1 .. d_k]
    start: int           # cache length L before the bundle dispatched
    valid: int           # 1 + k live rows
    drafts: list[int]    # the k proposed tokens (unpadded)


@dataclass
class StepPlan:
    """Everything one fused engine step dispatches: the decode batch plus at
    most one token-budgeted prefill chunk, all with static padded shapes
    (``decode`` is always the full S-slot batch, ``chunk`` always C padded
    tokens), so the executor's fused function never recompiles.

    ``decode_slots`` captures the decoding slots at plan time — the engine
    harvests exactly these after the dispatch, so a sequence that becomes
    decodable mid-step (the chunk finishing its prompt) is never harvested
    from a dispatch it was not part of. ``decode`` is None when the device
    mirrors are already current (the steady-state zero-transfer path).
    ``step_tokens`` is the plan's token-budget spend: one per decode row
    plus the chunk's valid tokens plus each spec bundle's live rows.

    ``spec`` carries this step's speculation bundles (at most one per
    decoding slot): each is ONE work item the executor scores with one
    fused verify dispatch. Bundled slots are excluded from
    ``decode_slots`` and masked in the decode batch — their step happens
    through the bundle, never twice.
    """

    decode_slots: list[int]
    decode: DecodeInputs | None
    chunk: PrefillChunk | None
    step_tokens: int
    spec: list[SpecBundle] = None  # None == no speculation this step


class Scheduler:
    """Pure-host scheduler over a :class:`PagedKVCache`'s bookkeeping.

    Owns the slot map and every serving *decision*; owns NO jitted function
    and no device array. The engine translates its outputs into lifecycle
    events and executor calls.
    """

    def __init__(
        self,
        cache: PagedKVCache,
        *,
        prefill_chunk: int | None,
        chunked: bool,
        prefix_sharing: bool,
        extra_ctx: int = 0,
        token_budget: int | None = None,
    ):
        self.cache = cache
        self.prefill_chunk = prefill_chunk
        self.chunked = chunked
        self.prefix_sharing = prefix_sharing and chunked
        self.extra_ctx = extra_ctx  # non-token context (vlm frontend tokens)
        # Sarathi-style cap on tokens per fused step (decode rows + chunk
        # valid); None = uncapped. Only build_step_plan applies it — the
        # interleaved A/B path is unaffected.
        self.token_budget = token_budget
        self.slots: dict[int, Sequence] = {}
        self._admit_counter = 0
        # persistent decode-batch mirrors: build_decode_inputs refreshes
        # only the slots marked dirty since the last build, so host-side
        # per-step assembly stops scaling with max_slots
        n, mp = cache.block_tables.shape
        self._mir_tokens = np.zeros((n, 1), np.int32)
        self._mir_temps = np.zeros((n,), np.float32)
        self._mir_tks = np.zeros((n,), np.int32)
        self._mir_tps = np.ones((n,), np.float32)
        self._mir_seeds = np.zeros((n,), np.int32)
        self._mir_idx = np.zeros((n,), np.int32)
        self._mir_active = np.zeros((n,), np.int32)
        self._mir_bt = np.full((n, mp), NULL_PAGE, np.int32)
        self._mir_lens = np.zeros((n,), np.int32)
        self._dirty_slots: set[int] = set()
        self._all_dirty = True  # composition changed since last build

    @property
    def dirty(self) -> bool:
        """True when the decode batch must be (re)built before dispatching
        (composition changed: admission, begin/end of decode, eviction,
        block-table growth/COW). Length/token advances from decoded tokens
        do NOT dirty the batch — the executor's jitted step advances its
        device copies identically."""
        return self._all_dirty or bool(self._dirty_slots)

    def _mark(self, slot: int) -> None:
        self._dirty_slots.add(slot)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _pending_prefix_gain(self, tokens: list[int]) -> int:
        """Longest full-page prefix of ``tokens`` that an IN-FLIGHT prefill
        will publish to the prefix index but has not yet (its chunks haven't
        reached those pages). Admission waits for such a prefix instead of
        allocating private pages for content that is about to be shared —
        without this, a burst of same-prefix requests admitted in one step
        would get zero sharing."""
        ps = self.cache.page_size
        limit = self.cache._prefix_limit(tokens)
        best = 0
        for seq in self.slots.values():
            if seq.phase != "prefill":
                continue
            other = seq.request.prompt
            n = 0
            for i in range(min(limit, len(other) // ps)):
                if tokens[i * ps:(i + 1) * ps] != other[i * ps:(i + 1) * ps]:
                    break
                n += 1
            best = max(best, n * ps)
        return best

    def can_place(self, request) -> bool:
        """Whether the queue head should be admitted NOW — false when the
        cache lacks slots/pages for it, or when deferring would let it share
        a prefix an in-flight prefill is about to publish."""
        tokens = request.prompt if self.prefix_sharing else None
        if tokens is not None:
            matched = self.cache.match_prefix(tokens)[1]
            if self._pending_prefix_gain(tokens) > matched:
                return False  # a longer shared prefix lands within a few chunks
        return self.cache.can_admit(self.extra_ctx + len(request.prompt), tokens)

    def place(self, request, handle) -> tuple[int, Sequence, int]:
        """Claim a slot and pages for ``request``. Returns
        ``(slot, sequence, cached_len)``; chunked sequences start in the
        ``prefill`` phase at ``prefill_pos=cached_len`` (shared prefix pages
        already mapped), legacy whole-prompt sequences start decode-ready
        (the engine runs their prefill immediately)."""
        tokens = request.prompt if self.prefix_sharing else None
        slot, cached = self.cache.admit(
            self.extra_ctx + len(request.prompt), tokens
        )
        self._admit_counter += 1
        seq = Sequence(
            request, handle, [], order=self._admit_counter,
            phase="prefill" if self.chunked else "decode",
            prefill_pos=cached,
        )
        self.slots[slot] = seq
        self._mark(slot)
        return slot, seq, cached

    # ------------------------------------------------------------------
    # chunked prefill
    # ------------------------------------------------------------------
    def next_prefill(self, limit: int | None = None,
                     width: int | None = None) -> PrefillChunk | None:
        """The OLDEST in-flight prefill's next fixed-size chunk (the engine
        runs at most one per step so concurrent decodes stall for one
        chunk's latency at worst), or None when nothing is prefilling.
        ``limit`` caps the chunk's live tokens (the fused step's token
        budget); a zero limit defers the chunk entirely this step.
        ``width`` shrinks the chunk's STATIC buffer below
        ``prefill_chunk`` — under a token budget the live tokens can never
        exceed the budget, so padding the buffer past it would make every
        fused dispatch pay compute for rows the mask kills."""
        cands = [(q.order, s) for s, q in self.slots.items()
                 if q.phase == "prefill"]
        if not cands:
            return None
        _, slot = min(cands)
        seq = self.slots[slot]
        prompt = seq.request.prompt
        start = seq.prefill_pos
        c = self.prefill_chunk if width is None else min(
            self.prefill_chunk, max(1, width))
        valid = min(c, len(prompt) - start)
        if limit is not None:
            valid = min(valid, limit)
        if valid <= 0:
            return None  # budget exhausted by decode rows: defer one step
        toks = np.zeros((c,), np.int32)
        toks[:valid] = prompt[start:start + valid]
        return PrefillChunk(slot, seq, toks, start, valid)

    def complete_chunk(self, work: PrefillChunk) -> bool:
        """Record a dispatched chunk: advance the prefill cursor, publish
        the covered full pages to the prefix index (dispatch order is
        execution order, so a later admission can share them safely).
        Returns True when the prompt is now fully cached."""
        seq = work.seq
        prompt = seq.request.prompt
        seq.prefill_pos = work.start + work.valid
        if self.prefix_sharing:
            self.cache.register_prefix(work.slot, prompt, seq.prefill_pos)
        return seq.prefill_pos == len(prompt)

    def begin_decode(self, slot: int) -> None:
        """Prompt fully cached: the slot joins the decode batch."""
        self.slots[slot].phase = "decode"
        self._mark(slot)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def find(self, uid: str) -> int | None:
        for slot, seq in self.slots.items():
            if seq.request.uid == uid:
                return slot
        return None

    def release(self, slot: int) -> Sequence:
        """Free a finished/cancelled sequence's slot and pages."""
        seq = self.slots.pop(slot)
        self.cache.release(slot)
        self._mark(slot)
        return seq

    def has_decodable(self) -> bool:
        return any(q.phase == "decode" for q in self.slots.values())

    def decoding(self) -> list[tuple[int, Sequence]]:
        """(slot, seq) pairs currently in the decode phase, slot order."""
        return sorted(
            (s, q) for s, q in self.slots.items() if q.phase == "decode"
        )

    def evict_youngest(self) -> tuple[int, Sequence]:
        """Release the youngest sequence (any phase) and hand it back for
        the engine to requeue or finish ``preempted``."""
        slot = max(self.slots, key=lambda s: self.slots[s].order)
        return slot, self.release(slot)

    def ensure_decode_capacity(
        self, extra: dict[int, int] | None = None
    ) -> list[Sequence]:
        """Give every DECODING slot a writable page for its next position —
        growing at page boundaries, copying a shared (refcount > 1) page
        anywhere else — evicting the youngest sequences if the pool runs
        dry. ``extra[slot]`` requests that many positions BEYOND the next
        one: a speculative verify bundle scatters k+1 candidate positions
        in one dispatch, so every one of them must be writable up front
        (rollback then never has to un-allocate — it only rewinds the
        length, and over-provisioned tail pages stay owned by the slot).
        A lone sequence can always grow (submit rejects requests that
        exceed the whole pool, and the engine caps drafts at the request's
        validated max_new budget), so this terminates with at least one
        slot making progress. Returns the evicted sequences (pages already
        released) for the engine's preemption bookkeeping."""
        preempted: list[Sequence] = []
        order = sorted(
            (s for s, q in self.slots.items() if q.phase == "decode"),
            key=lambda s: self.slots[s].order,
        )
        for slot in order:
            n = 1 + (extra.get(slot, 0) if extra else 0)
            while slot in self.slots:
                try:
                    if self.cache.ensure_append_capacity(slot, n):
                        self._mark(slot)  # table grew or a page was COWed
                    break
                except RuntimeError:
                    # pages granted before the failure are already in the
                    # table; the retry (or eviction) sees them as owned
                    self._mark(slot)
                    preempted.append(self.evict_youngest()[1])
        return preempted

    # ------------------------------------------------------------------
    # decode-batch assembly
    # ------------------------------------------------------------------
    def append_decoded(self, slot: int, token: int) -> None:
        """Record one sampled token for a decoding slot (both step modes'
        harvest path): advance the cache length and the attempt's token
        list, and keep the persistent mirrors current WITHOUT dirtying the
        batch — the executor's jitted step advanced its device copies
        (token, length, sample index) identically, so no re-upload is
        needed."""
        seq = self.slots[slot]
        self.cache.append(slot)
        seq.tokens.append(token)
        self._mir_tokens[slot, 0] = token
        self._mir_idx[slot] = len(seq.tokens)
        self._mir_lens[slot] = self.cache.lengths[slot]

    def _refresh_slot(self, slot: int) -> None:
        """Bring one slot's mirror row up to date with host truth."""
        seq = self.slots.get(slot)
        if seq is None or seq.phase != "decode":
            # idle or prefilling: mask to the null page / length 0 so the
            # decode write lands in the sink and the (discarded) attention
            # output reads nothing
            self._mir_active[slot] = 0
            self._mir_bt[slot] = NULL_PAGE
            self._mir_lens[slot] = 0
            self._mir_tokens[slot, 0] = 0
            self._mir_temps[slot] = 0.0
            self._mir_tks[slot] = 0
            self._mir_tps[slot] = 1.0
            self._mir_seeds[slot] = 0
            self._mir_idx[slot] = 0
            return
        sp = seq.request.sampling
        self._mir_active[slot] = 1
        self._mir_bt[slot] = self.cache.block_tables[slot]
        self._mir_lens[slot] = self.cache.lengths[slot]
        self._mir_tokens[slot, 0] = seq.tokens[-1]
        self._mir_temps[slot] = sp.temperature
        self._mir_tks[slot] = sp.top_k
        self._mir_tps[slot] = sp.top_p
        self._mir_seeds[slot] = seq.handle.seed
        self._mir_idx[slot] = len(seq.tokens)

    def build_decode_inputs(self) -> DecodeInputs:
        """Assemble the fixed-width decode batch from the persistent
        mirrors, refreshing only the slots dirtied since the last build —
        host-side per-step overhead tracks the number of lifecycle events,
        not max_slots. Fresh copies on return — the cache tables mutate
        between steps and the executor transfers these asynchronously."""
        if self._all_dirty:
            for slot in range(self.cache.max_slots):
                self._refresh_slot(slot)
        else:
            for slot in self._dirty_slots:
                self._refresh_slot(slot)
        self._dirty_slots.clear()
        self._all_dirty = False
        act = self._mir_active.astype(bool)
        greedy = bool((self._mir_temps[act] <= 0.0).all())
        return DecodeInputs(
            self._mir_tokens.copy(), self._mir_temps.copy(),
            self._mir_tks.copy(), self._mir_tps.copy(),
            self._mir_seeds.copy(), self._mir_idx.copy(),
            self._mir_active.copy(), self._mir_bt.copy(),
            self._mir_lens.copy(), greedy_only=greedy,
        )

    # ------------------------------------------------------------------
    # speculation bundles
    # ------------------------------------------------------------------
    def build_spec_bundle(self, slot: int, drafts: list[int],
                          width: int) -> SpecBundle:
        """Package a proposer's drafts for one decoding slot as a verify
        work item: row 0 is the slot's last committed token (same feed as
        its plain decode row), rows 1..k the drafts, padded to the static
        ``width`` (= spec_k + 1). The caller must already have ensured
        append capacity for ``1 + len(drafts)`` positions."""
        seq = self.slots[slot]
        assert seq.phase == "decode" and seq.tokens, (slot, seq.phase)
        assert 0 < len(drafts) < width, (len(drafts), width)
        toks = np.zeros((width,), np.int32)
        toks[0] = seq.tokens[-1]
        toks[1:1 + len(drafts)] = drafts
        return SpecBundle(
            slot=slot, seq=seq, tokens=toks,
            start=int(self.cache.lengths[slot]),
            valid=1 + len(drafts), drafts=list(drafts),
        )

    def append_speculated(self, slot: int, token: int) -> None:
        """Record one accepted/bonus token from a verify bundle. Unlike
        :meth:`append_decoded` this does NOT advance the mirrors — the
        verify dispatch never touches the decode batch's device copies,
        so :meth:`commit_speculation` re-dirties the whole row instead."""
        self.slots[slot].tokens.append(token)

    def commit_speculation(self, slot: int, length: int) -> None:
        """Finalize a verify bundle for a slot that keeps decoding: set
        the cache length to the accepted prefix + the committed row
        (REWINDING the rejected tail — pages are append-only per slot, so
        rejected positions simply fall out of the attention mask and the
        next append overwrites them in place) and dirty the mirror row so
        the next decode batch re-uploads host truth."""
        assert length >= int(self.cache.lengths[slot]), (
            length, int(self.cache.lengths[slot]))  # never below the start
        self.cache.lengths[slot] = length
        self._mark(slot)

    # ------------------------------------------------------------------
    # fused step plan
    # ------------------------------------------------------------------
    def build_step_plan(self, spec: list[SpecBundle] | None = None
                        ) -> StepPlan:
        """Assemble ONE fused step: the full decode batch plus at most one
        prefill chunk, under the token budget (one token per decode row;
        the chunk's live tokens fill what remains — Sarathi-style, so an
        operator can trade TTFT for ITL tail). With no decode rows in
        flight the budget is waived (a chunk always makes progress; cold
        start cannot stall). ``decode`` is None on the steady-state path
        (device mirrors current); shapes are static either way.

        ``spec`` lists this step's speculation bundles: their slots leave
        ``decode_slots`` and are masked to the null page in the decode
        batch (their step happens through the verify dispatch instead —
        never twice), and their live rows count against ``step_tokens``.
        Masking mutates only the returned copies; the mirrors stay true
        and the slot is re-marked dirty for the next plain build."""
        spec = spec or []
        spec_slots = {b.slot for b in spec}
        decode_slots = [s for s, q in sorted(self.slots.items())
                        if q.phase == "decode" and s not in spec_slots]
        limit = width = None
        if self.token_budget is not None and decode_slots:
            # The chunk buffer is sized to what the budget can actually
            # spend AFTER the decode rows take their token each — not the
            # full budget — so a chunky step never carries buffer rows the
            # mask is guaranteed to kill. Widths vary with the decode
            # count, so the executor compiles at most max_slots chunk
            # shapes (once each, during warmup).
            limit = width = max(0, self.token_budget - len(decode_slots))
        chunk = (self.next_prefill(limit=limit, width=width)
                 if self.chunked else None)
        decode = None
        if decode_slots:
            if spec_slots:
                decode = self.build_decode_inputs()
                for s in spec_slots:
                    decode.active[s] = 0
                    decode.block_tables[s] = NULL_PAGE
                    decode.lengths[s] = 0
                    self._mark(s)  # device copy now diverges from mirror
                act = decode.active.astype(bool)
                decode.greedy_only = bool((decode.temps[act] <= 0.0).all())
            elif self.dirty:
                decode = self.build_decode_inputs()
        return StepPlan(
            decode_slots=decode_slots,
            decode=decode,
            chunk=chunk,
            step_tokens=(len(decode_slots) + (chunk.valid if chunk else 0)
                         + sum(b.valid for b in spec)),
            spec=spec,
        )

    # ------------------------------------------------------------------
    # gauges
    # ------------------------------------------------------------------
    def occupancy(self) -> tuple[int, int]:
        """(decoding slots, total slots) for the utilization gauges."""
        return (sum(1 for q in self.slots.values() if q.phase == "decode"),
                self.cache.max_slots)

    def page_utilization(self) -> tuple[int, int]:
        """(pages in use, usable pages) — excludes the reserved null page.
        Parked pages (zero-refcount prefix pages in the reclaim-under-
        pressure LRU) do not count as used: they are free capacity that
        happens to still hold reusable bytes."""
        usable = self.cache.num_pages - 1
        used = usable - self.cache.pool.available - self.cache.parked_count
        return used, usable
