"""The public serving surface: one engine protocol, streamed request lifecycle.

Every engine — the lockstep micro-batcher, the paged continuous batcher, and
future sharded/SSM engines — speaks the same contract, so the bus worker in
``launch/serve.py``, the workflow scheduler's retry/hedging machinery, and
benchmarks drive them identically:

* :class:`SamplingParams` — temperature, top-k, top-p, stop tokens,
  ``max_new_tokens`` and an optional per-request seed. Seeded requests
  reproduce the same tokens regardless of batch placement (the sampler keys
  RNG off ``(seed, token_index)``, never off engine-global step counters).
* :class:`Request` — uid + prompt + sampling, plus ``priority`` and
  ``deadline_s`` consumed by admission policies. The legacy
  ``max_new_tokens=`` / ``temperature=`` constructor arguments still work
  and fold into ``sampling``.
* :class:`EngineCore` — the protocol: ``submit() -> RequestHandle``,
  ``step() -> list[StreamEvent]``, ``cancel(uid)``, ``abort_all()``,
  ``capacity()``, ``idle``.
* :class:`RequestHandle` — the live view of one request: incremental token
  deltas (:meth:`RequestHandle.new_tokens`), TTFT / inter-token gaps, and a
  typed :class:`FinishReason` (length / stop / cancelled / rejected /
  preempted).
* :class:`AdmissionPolicy` — pluggable queue ordering: :class:`FIFOAdmission`
  (default), :class:`PriorityAdmission` (higher ``Request.priority`` first),
  :class:`DeadlineAdmission` (earliest deadline first; queued requests whose
  deadline lapses finish ``rejected`` instead of serving dead work).

Validation lives at this boundary (:func:`validate_request` +
:meth:`SamplingParams.validate`): empty prompts, non-positive
``max_new_tokens``, and prompts that exceed an engine's context budget are
rejected identically whether a request arrives via :meth:`EngineBase.submit`,
the deprecated ``enqueue``, or a bus topic (:func:`request_from_message`).
``submit`` never raises — an invalid request comes back as a handle already
finished with ``FinishReason.REJECTED`` and ``error`` set.

The driving loop every caller shares::

    handle = engine.submit(Request("r0", prompt, sampling=SamplingParams(...)))
    while not engine.idle:
        for ev in engine.step():       # StreamEvents: token deltas + finishes
            ...
    result = handle.result()           # tokens, ttft, itl, finish_reason
"""

from __future__ import annotations

import enum
import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Protocol, runtime_checkable


class FinishReason(str, enum.Enum):
    """Why a request stopped producing tokens (terminal, exactly one each)."""

    LENGTH = "length"        # produced sampling.max_new_tokens tokens
    STOP = "stop"            # sampled a token in sampling.stop_tokens
    CANCELLED = "cancelled"  # cancel(uid) / abort_all()
    REJECTED = "rejected"    # failed validation, or deadline lapsed queued
    PREEMPTED = "preempted"  # evicted under pressure past max_preemptions


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling controls, validated at the API boundary.

    ``temperature <= 0`` means greedy (top-k/top-p are then irrelevant).
    ``top_k=0`` and ``top_p=1.0`` disable their filters. ``stop_tokens``
    terminate the request with ``FinishReason.STOP``; the stop token itself
    is not emitted. ``seed`` pins the request's RNG stream: the same seeded
    request produces the same tokens no matter how it is batched.
    ``speculative=False`` opts this request out of speculative decoding on
    engines that enable it (streams are identical either way — the
    ``(seed, token_index)``-keyed sampler makes acceptance exact — so this
    is a latency/throughput knob, not a quality one).
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    stop_tokens: tuple[int, ...] = ()
    max_new_tokens: int = 16
    seed: int | None = None
    speculative: bool = True

    def __post_init__(self):
        if not isinstance(self.stop_tokens, tuple):
            object.__setattr__(self, "stop_tokens", tuple(self.stop_tokens))

    def validate(self) -> None:
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}"
            )
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if any(not isinstance(t, int) or t < 0 for t in self.stop_tokens):
            raise ValueError(f"stop_tokens must be non-negative ints: "
                             f"{self.stop_tokens}")


@dataclass
class Request:
    """One generation request.

    ``sampling`` is authoritative; the legacy ``max_new_tokens`` /
    ``temperature`` constructor arguments are kept for callers of the old
    two-field API and fold into a :class:`SamplingParams` when ``sampling``
    is not given (when it is, the legacy fields are synced *from* it, so both
    views always agree). ``priority`` and ``deadline_s`` (seconds after
    arrival) are consumed by :class:`PriorityAdmission` /
    :class:`DeadlineAdmission` and ignored by FIFO.
    """

    uid: str
    prompt: list[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    # optional caller-supplied arrival time for TTFT; when None the engine
    # stamps submit time itself (engine-side; the Request is never mutated
    # after construction, so resubmission stays safe)
    arrival_t: float | None = None
    sampling: SamplingParams | None = None
    priority: int = 0
    deadline_s: float | None = None

    def __post_init__(self):
        if self.sampling is None:
            self.sampling = SamplingParams(
                temperature=self.temperature,
                max_new_tokens=self.max_new_tokens,
            )
        else:
            self.max_new_tokens = self.sampling.max_new_tokens
            self.temperature = self.sampling.temperature


@dataclass
class Result:
    """Terminal summary of one request (see :meth:`RequestHandle.result`)."""

    uid: str
    tokens: list[int] = field(default_factory=list)
    ttft: float | None = None      # seconds, submit -> first token
    itl: list[float] = field(default_factory=list)  # inter-token gaps (s)
    finish_reason: FinishReason | None = None
    error: str | None = None


@dataclass(frozen=True)
class StreamEvent:
    """One observable lifecycle transition, returned by ``engine.step()``.

    ``kind`` is ``"token"`` (one incremental delta; ``token``/``index`` set),
    ``"finish"`` (terminal; ``finish_reason`` set), or ``"preempted"``
    (non-terminal: the request was evicted and requeued; its already-streamed
    tokens remain valid and will NOT be re-emitted when it regenerates).
    Within one ``step()`` batch a request's token events precede its finish
    event, and indices are consecutive.
    """

    uid: str
    kind: str  # "token" | "finish" | "preempted"
    token: int | None = None
    index: int | None = None
    finish_reason: FinishReason | None = None
    t: float = 0.0


class RequestHandle:
    """Live, caller-facing view of one submitted request.

    The engine appends tokens as they are produced; callers either poll
    :meth:`new_tokens` (drains deltas since the last call) or watch the
    :class:`StreamEvent` stream from ``engine.step()``. ``ttft``/``itl`` are
    stamped at emission time, and :meth:`result` snapshots everything once
    ``done``. Preemption is transparent: regenerated tokens are de-duplicated
    against what was already streamed (sampling is keyed off
    ``(seed, token_index)``, so a regenerated stream is identical).
    """

    def __init__(self, request: Request, engine: "EngineBase | None" = None):
        self.request = request
        self.uid = request.uid
        self.tokens: list[int] = []
        self.ttft: float | None = None
        self.itl: list[float] = []
        self.finish_reason: FinishReason | None = None
        self.error: str | None = None
        self.arrival: float | None = None
        self.seed: int = 0           # effective sampling seed (engine-set)
        self.preemptions: int = 0
        self._engine = engine
        self._cursor = 0
        self._last_t: float | None = None

    @property
    def done(self) -> bool:
        return self.finish_reason is not None

    def new_tokens(self) -> list[int]:
        """Drain and return the tokens emitted since the last call."""
        out = self.tokens[self._cursor:]
        self._cursor = len(self.tokens)
        return out

    def cancel(self) -> bool:
        """Cancel this request on its engine (queued or mid-decode)."""
        return self._engine.cancel(self.uid) if self._engine else False

    def result(self) -> Result:
        return Result(
            self.uid, list(self.tokens), ttft=self.ttft, itl=list(self.itl),
            finish_reason=self.finish_reason, error=self.error,
        )

    def _emit(self, tok: int, now: float) -> None:
        if not self.tokens:
            if self.arrival is not None:
                self.ttft = now - self.arrival
        elif self._last_t is not None:
            self.itl.append(now - self._last_t)
        self._last_t = now
        self.tokens.append(tok)


# ---------------------------------------------------------------------------
# admission policies
# ---------------------------------------------------------------------------


class AdmissionPolicy:
    """Orders the waiting queue; engines only ever see the head.

    ``push`` adds a newly submitted request; ``requeue`` re-adds a preempted
    one (policies should place it no later than its original position);
    ``peek``/``pop`` expose the next admission candidate; ``remove`` supports
    cancellation of queued requests; ``take_expired`` drains requests whose
    deadline lapsed before admission (the engine finishes them ``rejected``).
    """

    def push(self, req: Request, arrival: float) -> None:
        raise NotImplementedError

    def requeue(self, req: Request, arrival: float) -> None:
        self.push(req, arrival)

    def peek(self, now: float) -> Request | None:
        raise NotImplementedError

    def pop(self, now: float) -> Request:
        raise NotImplementedError

    def remove(self, uid: str) -> Request | None:
        raise NotImplementedError

    def take_expired(self, now: float) -> list[Request]:
        return []

    def __len__(self) -> int:
        raise NotImplementedError


class FIFOAdmission(AdmissionPolicy):
    """Arrival order; preempted requests rejoin at the front."""

    def __init__(self):
        self._q: deque[tuple[Request, float]] = deque()

    def push(self, req, arrival):
        self._q.append((req, arrival))

    def requeue(self, req, arrival):
        self._q.appendleft((req, arrival))

    def peek(self, now):
        return self._q[0][0] if self._q else None

    def pop(self, now):
        return self._q.popleft()[0]

    def remove(self, uid):
        for i, (r, _) in enumerate(self._q):
            if r.uid == uid:
                del self._q[i]
                return r
        return None

    def __len__(self):
        return len(self._q)


class _LazyHeapAdmission(AdmissionPolicy):
    """Heap-ordered queue with lazy deletion, shared by the priority and
    deadline policies. Subclasses define :meth:`_key` (the heap sort key
    for a request). Removal tombstones key off OBJECT identity, not uid: a
    uid freed by cancellation may be resubmitted while the stale entry
    still sits in the heap, and the new entry must not be swallowed.
    """

    def __init__(self):
        self._heap: list[tuple] = []  # (key, seq, req)
        self._gone: set[int] = set()
        self._seq = 0

    def _key(self, req: Request, arrival: float):
        raise NotImplementedError

    def push(self, req, arrival):
        self._seq += 1
        heapq.heappush(self._heap, (self._key(req, arrival), self._seq, req))

    def _clean(self):
        while self._heap and id(self._heap[0][2]) in self._gone:
            self._gone.discard(id(heapq.heappop(self._heap)[2]))

    def peek(self, now):
        self._clean()
        return self._heap[0][2] if self._heap else None

    def pop(self, now):
        self._clean()
        return heapq.heappop(self._heap)[2]

    def remove(self, uid):
        for _, _, r in self._heap:
            if r.uid == uid and id(r) not in self._gone:
                self._gone.add(id(r))
                return r
        return None

    def __len__(self):
        return len(self._heap) - len(self._gone)


class PriorityAdmission(_LazyHeapAdmission):
    """Higher ``Request.priority`` first; FIFO within a priority level.

    Preempted requests rejoin ahead of equal-priority arrivals (they already
    held resources once).
    """

    def __init__(self):
        super().__init__()
        self._front = 0

    def _key(self, req, arrival):
        return -req.priority

    def requeue(self, req, arrival):
        self._front -= 1
        heapq.heappush(self._heap, (self._key(req, arrival), self._front, req))


class DeadlineAdmission(_LazyHeapAdmission):
    """Earliest ``arrival + deadline_s`` first (EDF); no deadline sorts last.

    Queued requests whose deadline has already lapsed are surfaced through
    :meth:`take_expired` — the engine finishes them ``rejected`` instead of
    spending decode slots on answers nobody is waiting for.
    """

    _NO_DEADLINE = float("inf")

    def _key(self, req, arrival):
        if req.deadline_s is None:
            return self._NO_DEADLINE
        return arrival + req.deadline_s

    def take_expired(self, now):
        out = []
        self._clean()
        while self._heap and self._heap[0][0] < now:
            out.append(heapq.heappop(self._heap)[2])
            self._clean()
        return out


# ---------------------------------------------------------------------------
# validation + bus parsing (the shared API boundary)
# ---------------------------------------------------------------------------


class UnsupportedConfigError(ValueError):
    """No serving engine supports this model config.

    Raised by launch-time engine selection instead of silently falling
    back to a weaker engine: a driver asked for a family/feature
    combination (e.g. encoder-decoder behind the paged engine) that every
    available engine rejects, so the deployment must fail loudly up front
    rather than serve with surprising semantics.
    """


def validate_request(req: Request, *, max_len: int, extra_ctx: int = 0) -> None:
    """Boundary checks shared by every engine and ingress path.

    ``extra_ctx`` covers non-token context the engine prepends (e.g. vlm
    frontend tokens). Raises ValueError with a stable message; engines add
    their own capacity checks on top.
    """
    req.sampling.validate()
    if not req.prompt:
        raise ValueError(f"request {req.uid}: empty prompt")
    ctx = extra_ctx + len(req.prompt)
    if ctx + req.sampling.max_new_tokens > max_len:
        raise ValueError(
            f"request {req.uid}: context {ctx}+{req.sampling.max_new_tokens} "
            f"exceeds engine max_len={max_len}"
        )


def request_from_message(v: dict) -> Request:
    """Build a Request from a bus message value, carrying EVERY sampling
    field (the old per-engine parsers silently dropped ``temperature``).
    Raises KeyError/TypeError/ValueError on malformed payloads — callers
    treat those as poison messages."""
    sp = SamplingParams(
        temperature=float(v.get("temperature", 0.0)),
        top_k=int(v.get("top_k", 0)),
        top_p=float(v.get("top_p", 1.0)),
        stop_tokens=tuple(int(t) for t in v.get("stop_tokens", ())),
        max_new_tokens=int(v.get("max_new_tokens", 16)),
        seed=None if v.get("seed") is None else int(v["seed"]),
    )
    return Request(
        str(v["uid"]), [int(t) for t in v["prompt"]], sampling=sp,
        arrival_t=v.get("arrival_t"),
        priority=int(v.get("priority", 0)),
        deadline_s=None if v.get("deadline_s") is None else float(v["deadline_s"]),
    )


# ---------------------------------------------------------------------------
# the engine protocol + shared lifecycle machinery
# ---------------------------------------------------------------------------


@runtime_checkable
class EngineCore(Protocol):
    """What every serving engine exposes. ``submit`` never raises (invalid
    requests return a handle already finished ``rejected``); ``step`` runs
    one scheduling quantum and returns the lifecycle events it produced;
    ``capacity`` hints how many new requests the engine wants pulled from
    an ingress queue."""

    def submit(self, request: Request) -> RequestHandle: ...

    def step(self) -> list[StreamEvent]: ...

    def cancel(self, uid: str) -> bool: ...

    def abort_all(self) -> int: ...

    def capacity(self) -> int: ...

    @property
    def idle(self) -> bool: ...


class EngineBase:
    """Shared request-lifecycle machinery behind :class:`EngineCore`.

    Concrete engines provide ``_validate`` (capacity checks beyond
    :func:`validate_request`), ``_cancel_active`` (tear down an
    admitted/decoding request), ``step``, ``capacity`` and ``idle``; this
    base owns handles, the admission queue, event buffering, rejection
    bookkeeping and the deprecated synchronous wrappers."""

    def _init_api(self, *, admission: AdmissionPolicy | None, seed: int) -> None:
        self.admission = admission if admission is not None else FIFOAdmission()
        self._handles: dict[str, RequestHandle] = {}
        self._events: list[StreamEvent] = []
        self.rejections: list[tuple[str, str]] = []
        self.stats: dict[str, int] = {"tokens": 0, "rejected": 0}
        self._seed_base = seed
        self._submit_counter = 0

    # -- engine hooks ---------------------------------------------------
    def _validate(self, request: Request) -> None:
        raise NotImplementedError

    def _cancel_active(self, uid: str) -> bool:
        raise NotImplementedError

    # -- protocol -------------------------------------------------------
    def submit(self, request: Request) -> RequestHandle:
        """Validate and queue a request. Never raises: an invalid request
        returns a handle already finished ``FinishReason.REJECTED``."""
        h = RequestHandle(request, engine=self)
        try:
            self._validate(request)
            if request.uid in self._handles:
                raise ValueError(
                    f"request {request.uid}: uid already in flight"
                )
        except (ValueError, TypeError) as e:
            h.finish_reason = FinishReason.REJECTED
            h.error = str(e)
            self.rejections.append((request.uid, str(e)))
            self.stats["rejected"] += 1
            return h
        now = time.perf_counter()
        h.arrival = request.arrival_t if request.arrival_t is not None else now
        self._submit_counter += 1
        sp = request.sampling
        h.seed = (
            sp.seed if sp.seed is not None
            else (self._seed_base * 1_000_003 + self._submit_counter)
        ) & 0x7FFFFFFF
        self._handles[request.uid] = h
        self.admission.push(request, h.arrival)
        return h

    def cancel(self, uid: str) -> bool:
        """Cancel a queued or in-flight request; returns False when the uid
        is unknown or already finished. Streamed tokens stay on the handle;
        the finish event (reason ``cancelled``) is delivered by the next
        ``step()``."""
        h = self._handles.get(uid)
        if h is None or h.done:
            return False
        if self.admission.remove(uid) is not None:
            self._finish_handle(h, FinishReason.CANCELLED)
            return True
        return self._cancel_active(uid)

    def abort_all(self) -> int:
        """Cancel every queued and in-flight request; returns the count."""
        return sum(self.cancel(uid) for uid in list(self._handles))

    def capacity(self) -> int:
        raise NotImplementedError

    @property
    def idle(self) -> bool:
        raise NotImplementedError

    def step(self) -> list[StreamEvent]:
        raise NotImplementedError

    # -- shared internals ----------------------------------------------
    def _drain_events(self) -> list[StreamEvent]:
        out, self._events = self._events, []
        return out

    def _finish_handle(
        self,
        h: RequestHandle,
        reason: FinishReason,
        error: str | None = None,
        now: float | None = None,
    ) -> None:
        h.finish_reason = reason
        h.error = error
        self._handles.pop(h.uid, None)
        self._events.append(StreamEvent(
            h.uid, "finish", finish_reason=reason,
            t=time.perf_counter() if now is None else now,
        ))

    def _deliver(self, h: RequestHandle, tok: int, idx: int, now: float) -> bool:
        """Process one sampled token for ``h`` (attempt-local index ``idx``):
        de-duplicates regenerated tokens after preemption, applies stop
        tokens (the stop token is not emitted), emits the delta event, and
        finishes on length. Returns True when the request finished."""
        if idx < len(h.tokens):
            return False  # regenerating after preemption: already streamed
        sp = h.request.sampling
        if tok in sp.stop_tokens:
            self._finish_handle(h, FinishReason.STOP, now=now)
            return True
        h._emit(tok, now)
        self._events.append(StreamEvent(
            h.uid, "token", token=tok, index=len(h.tokens) - 1, t=now
        ))
        self.stats["tokens"] += 1
        if len(h.tokens) >= sp.max_new_tokens:
            self._finish_handle(h, FinishReason.LENGTH, now=now)
            return True
        return False

    def _expire_queue(self, now: float) -> None:
        for req in self.admission.take_expired(now):
            h = self._handles.get(req.uid)
            if h is not None:
                err = (f"request {req.uid}: deadline exceeded before "
                       f"admission")
                self._finish_handle(h, FinishReason.REJECTED, error=err,
                                    now=now)
                self.rejections.append((req.uid, err))
                self.stats["rejected"] += 1

    # -- ingress + deprecated wrappers ---------------------------------
    def admit_from_bus(self, bus, topic: str, group: str,
                       max_msgs: int = 32) -> int:
        """Pull pending requests from a ``core.bus`` topic (at-least-once:
        each message is committed after handling). Malformed or unservable
        messages are rejected — recorded in ``self.rejections`` /
        ``stats['rejected']`` — and still committed, so one poison message
        never wedges the consumer group."""
        n = 0
        if max_msgs <= 0:
            return 0
        for m in bus.consume(topic, group, limit=max_msgs):
            v = m.value
            try:
                req = request_from_message(v)
            except (ValueError, KeyError, TypeError) as e:
                uid = v.get("uid", "?") if isinstance(v, dict) else "?"
                self.rejections.append((str(uid), str(e)))
                self.stats["rejected"] += 1
            else:
                if self.submit(req).finish_reason is None:
                    n += 1
            bus.commit(topic, group, m.offset + 1)
        return n

    def drain_rejections(self) -> list[tuple[str, str]]:
        out, self.rejections = self.rejections, []
        return out

    def enqueue(self, req: Request) -> None:
        """Deprecated: :meth:`submit` with raise-on-reject semantics."""
        h = self.submit(req)
        if h.finish_reason is FinishReason.REJECTED:
            raise ValueError(h.error)

    def generate(self, requests: list[Request]) -> list[Result]:
        """Deprecated synchronous wrapper: drain ``requests`` through the
        engine and return Results in submission order. New callers should
        use :meth:`submit` + :meth:`step` (streaming, cancellable)."""
        handles = [self.submit(r) for r in requests]
        for h in handles:
            if h.finish_reason is FinishReason.REJECTED:
                raise ValueError(h.error)
        while not self.idle:
            self.step()
        return [h.result() for h in handles]


__all__ = [
    "AdmissionPolicy",
    "DeadlineAdmission",
    "EngineBase",
    "EngineCore",
    "FIFOAdmission",
    "FinishReason",
    "PriorityAdmission",
    "Request",
    "RequestHandle",
    "Result",
    "SamplingParams",
    "StreamEvent",
    "request_from_message",
    "validate_request",
]
