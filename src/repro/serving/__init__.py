from repro.serving.engine import (
    ContinuousBatchingEngine,
    GenerationEngine,
    Request,
    Result,
)
from repro.serving.kv_cache import PagedKVCache, PagePool

__all__ = [
    "ContinuousBatchingEngine",
    "GenerationEngine",
    "PagedKVCache",
    "PagePool",
    "Request",
    "Result",
]
