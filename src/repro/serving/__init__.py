"""Serving layer: one engine protocol over a paged, prefix-shared KV cache.

``repro.serving.api`` is the single public surface — :class:`EngineCore`
(``submit``/``step``/``cancel``/``abort_all``), :class:`SamplingParams`,
:class:`RequestHandle` streaming, typed :class:`FinishReason`, and pluggable
:class:`AdmissionPolicy` queues. ``GenerationEngine`` is the lockstep
micro-batching baseline; ``ContinuousBatchingEngine`` is the production path
— continuous admission, chunked prefill interleaved with decode, and
copy-on-write prefix sharing (see ``docs/serving.md`` for the full design).
``repro.serving.fleet`` supervises N engine workers behind the bus —
probes, crash-replay recovery, autoscaling (paper §3.5 fused with the
serving arc). ``repro.serving.kv_tiers`` keeps prefix KV pages alive past
release — parked on device, spilled to host RAM, persisted to an
ArtifactStore — with async prefetch back on prefix hits.
``repro.serving.speculative`` breaks the one-token-per-dispatch decode
chain: an n-gram or draft-model proposer drafts k tokens and one fused
verify dispatch scores them all, streams staying byte-identical to
spec-off. ``repro.serving.ssm_engine`` serves the recurrent-state
families (Mamba2/Zamba2): the same engine protocol over a per-slot
recurrent-state bank instead of a page pool.
"""

from repro.serving.api import (
    AdmissionPolicy,
    DeadlineAdmission,
    EngineCore,
    FIFOAdmission,
    FinishReason,
    PriorityAdmission,
    Request,
    RequestHandle,
    Result,
    SamplingParams,
    StreamEvent,
    UnsupportedConfigError,
    request_from_message,
)
from repro.serving.engine import ContinuousBatchingEngine, GenerationEngine
from repro.serving.fleet import (
    EngineWorker,
    FleetConfig,
    FleetSupervisor,
    fleet_seed,
)
from repro.serving.kv_cache import PagedKVCache, PagePool
from repro.serving.kv_tiers import KVTierManager
from repro.serving.metrics import FleetMetrics, format_latency, latency_percentiles
from repro.serving.speculative import (
    DraftModelProposer,
    NgramProposer,
    SpeculativeProposer,
    build_proposer,
)
from repro.serving.ssm_engine import SlotStateBank, SSMEngine

__all__ = [
    "AdmissionPolicy",
    "ContinuousBatchingEngine",
    "DeadlineAdmission",
    "DraftModelProposer",
    "EngineCore",
    "EngineWorker",
    "FIFOAdmission",
    "FinishReason",
    "FleetConfig",
    "FleetMetrics",
    "FleetSupervisor",
    "GenerationEngine",
    "KVTierManager",
    "NgramProposer",
    "PagedKVCache",
    "PagePool",
    "PriorityAdmission",
    "Request",
    "RequestHandle",
    "Result",
    "SSMEngine",
    "SamplingParams",
    "SlotStateBank",
    "SpeculativeProposer",
    "StreamEvent",
    "UnsupportedConfigError",
    "build_proposer",
    "fleet_seed",
    "format_latency",
    "latency_percentiles",
    "request_from_message",
]
