"""Serving layer: generation engines over a paged, prefix-shared KV cache.

``GenerationEngine`` is the lockstep micro-batching baseline;
``ContinuousBatchingEngine`` is the production path — continuous admission,
chunked prefill interleaved with decode, and copy-on-write prefix sharing
(see ``docs/serving.md`` for the full design).
"""

from repro.serving.engine import (
    ContinuousBatchingEngine,
    GenerationEngine,
    Request,
    Result,
)
from repro.serving.kv_cache import PagedKVCache, PagePool
from repro.serving.metrics import format_latency, latency_percentiles

__all__ = [
    "ContinuousBatchingEngine",
    "GenerationEngine",
    "PagedKVCache",
    "PagePool",
    "Request",
    "Result",
    "format_latency",
    "latency_percentiles",
]
