"""Serving layer: one engine protocol over a paged, prefix-shared KV cache.

``repro.serving.api`` is the single public surface — :class:`EngineCore`
(``submit``/``step``/``cancel``/``abort_all``), :class:`SamplingParams`,
:class:`RequestHandle` streaming, typed :class:`FinishReason`, and pluggable
:class:`AdmissionPolicy` queues. ``GenerationEngine`` is the lockstep
micro-batching baseline; ``ContinuousBatchingEngine`` is the production path
— continuous admission, chunked prefill interleaved with decode, and
copy-on-write prefix sharing (see ``docs/serving.md`` for the full design).
"""

from repro.serving.api import (
    AdmissionPolicy,
    DeadlineAdmission,
    EngineCore,
    FIFOAdmission,
    FinishReason,
    PriorityAdmission,
    Request,
    RequestHandle,
    Result,
    SamplingParams,
    StreamEvent,
    request_from_message,
)
from repro.serving.engine import ContinuousBatchingEngine, GenerationEngine
from repro.serving.kv_cache import PagedKVCache, PagePool
from repro.serving.metrics import format_latency, latency_percentiles

__all__ = [
    "AdmissionPolicy",
    "ContinuousBatchingEngine",
    "DeadlineAdmission",
    "EngineCore",
    "FIFOAdmission",
    "FinishReason",
    "GenerationEngine",
    "PagedKVCache",
    "PagePool",
    "PriorityAdmission",
    "Request",
    "RequestHandle",
    "Result",
    "SamplingParams",
    "StreamEvent",
    "format_latency",
    "latency_percentiles",
    "request_from_message",
]
