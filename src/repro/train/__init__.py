from repro.train.optimizer import AdamWConfig, make_optimizer
from repro.train.step import make_train_step, init_train_state, train_state_axes

__all__ = [
    "AdamWConfig",
    "make_optimizer",
    "make_train_step",
    "init_train_state",
    "train_state_axes",
]
