"""AdamW with low-precision moments, global-norm clipping, cosine schedule.

Built from scratch (no optax in this container). Distributed-memory notes:
moments default to bfloat16 (halves optimizer HBM vs fp32 — the difference
between grok-1 fitting on one v5e pod or not; see EXPERIMENTS.md §Dry-run),
update math always runs in fp32.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "bfloat16"  # "float32" for small/reduced runs


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum((step + 1.0) / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def make_optimizer(cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, opt_state, params, step):
        """Returns (new_params, new_opt_state, metrics)."""
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        lr = schedule(cfg, step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def one(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
            v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
            mhat = m32 / bc1
            vhat = v32 / bc2
            upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
            # decoupled weight decay on matrices only (ndim >= 2)
            if p.ndim >= 2:
                upd = upd + cfg.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - lr * upd
            return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(opt_state["m"])
        flat_v = tdef.flatten_up_to(opt_state["v"])
        out = [one(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, {"m": new_m, "v": new_v}, metrics

    return init, update
