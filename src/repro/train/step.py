"""Train-step factory: microbatch gradient accumulation, donation, sharding.

``make_train_step`` returns a function suitable for ``jax.jit`` with
``donate_argnums=(0,)`` — the trainer and the dry-run both lower it.

Distributed-optimization tricks wired here (see EXPERIMENTS.md §Perf):
  * gradient accumulation over ``ga`` microbatches via lax.scan (bounds
    activation memory at (B/ga) examples regardless of global batch);
  * gradients accumulate in ``accum_dtype`` (fp32 default; bf16 halves the
    cross-pod all-reduce bytes — "gradient compression" on the wire, since
    XLA reduces in the accumulation dtype);
  * the whole state is donated, so params/moments update in place.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import AdamWConfig, make_optimizer


def init_train_state(model, key, opt_cfg: AdamWConfig) -> dict:
    params = model.init(key)
    opt_init, _ = make_optimizer(opt_cfg)
    return {
        "params": params,
        "opt": opt_init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_train_state(model, opt_cfg: AdamWConfig) -> dict:
    """ShapeDtypeStruct version (dry-run: no allocation)."""
    params = model.abstract()
    mdt = jnp.dtype(opt_cfg.moment_dtype)
    mom = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, mdt), params)
    return {
        "params": params,
        "opt": {"m": mom, "v": mom},
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def train_state_axes(model) -> dict:
    paxes = model.axes()
    return {"params": paxes, "opt": {"m": paxes, "v": paxes}, "step": None}


def make_train_step(
    model,
    opt_cfg: AdamWConfig,
    *,
    ga: int = 1,
    accum_dtype: str = "float32",
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""
    _, opt_update = make_optimizer(opt_cfg)
    adt = jnp.dtype(accum_dtype)

    def loss_fn(params, mb):
        loss, metrics = model.loss_fn(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]

        if ga == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % ga == 0, (b, ga)
                return x.reshape((ga, b // ga) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                g_acc, l_acc = acc
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(adt), g_acc, grads
                )
                return (g_acc, l_acc + loss), ()

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (grads, loss_sum), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / ga, grads)
            loss = loss_sum / ga
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        new_params, new_opt, opt_metrics = opt_update(
            grads, state["opt"], params, state["step"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        out_metrics = {"loss": loss, **{k: metrics[k] for k in ("ce", "aux") if k in metrics}, **opt_metrics}
        return new_state, out_metrics

    return train_step


def make_eval_step(model) -> Callable:
    def eval_step(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return {"loss": loss, **metrics}

    return eval_step
