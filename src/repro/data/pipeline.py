"""Deterministic, resumable synthetic data pipeline.

Fault-tolerance contract (the property the Jup2Kub scheduler relies on):
``batch_at(step)`` is a pure function of (seed, step) — after a crash and
checkpoint restore at step k, the pipeline replays the *exact* same stream
from k, on any number of hosts, with no shared state.

The corpus is a seeded first-order Markov chain (bigram table), so a model
trained on it has real signal to learn — smoke-train loss curves must
*decrease*, which the integration tests assert.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # extra modality fields (stub frontends)
    vision_tokens: int = 0
    frames: bool = False
    d_model: int = 0
    dtype: str = "float32"


class SyntheticCorpus:
    """Markov-chain token stream, indexable by step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = min(cfg.vocab_size, 2048)
        self._v = v
        rng = np.random.default_rng(cfg.seed)
        # sparse bigram transition table: each token has 4 likely successors
        succ = rng.integers(0, v, size=(v, 4))
        self._succ = succ

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self._v, size=b)
        choices = rng.integers(0, 4, size=(b, s))
        noise = rng.random((b, s)) < 0.05
        rand = rng.integers(0, self._v, size=(b, s))
        for t in range(s):
            nxt = self._succ[toks[:, t], choices[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:].copy()}
        if cfg.vision_tokens:
            batch["vision_embeds"] = rng.standard_normal(
                (b, cfg.vision_tokens, cfg.d_model)
            ).astype(cfg.dtype)
        if cfg.frames:
            batch["frames"] = rng.standard_normal((b, s, cfg.d_model)).astype(cfg.dtype)
        return batch


class PrefetchLoader:
    """Background-thread prefetch over an indexable source; resumable."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
