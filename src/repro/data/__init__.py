from repro.data.pipeline import DataConfig, SyntheticCorpus, PrefetchLoader

__all__ = ["DataConfig", "SyntheticCorpus", "PrefetchLoader"]
