"""jit'd public wrappers around the Pallas kernels with XLA fallbacks.

Ops: ``flash_attention`` (train/prefill), ``paged_attention`` (single-token
decode over the serving page pool), ``paged_prefill_attention`` (chunked
prefill over the page pool), ``paged_mixed_attention`` (fused decode rows +
one prefill chunk, one dispatch per engine step), ``ssd_scan`` /
``ssd_decode_step`` (Mamba2).

``impl`` selection:
  * "pallas"      — the Pallas TPU kernel. On a non-TPU backend every op
                    falls back to the ``ref.py`` path with a one-time
                    warning (a compiled Pallas lowering needs TPU
                    hardware), so a TPU-tuned launch config still serves
                    correctly on CPU hosts.
  * "pallas_interpret" — the Pallas kernel in interpret mode on any backend
                    (tests, the differential kernel-fuzz harness, and the
                    kernel-path engine parity suite use this on CPU).
  * "xla_chunked" — pure-jnp chunked implementations from ``ref.py``
                    (bounded memory; the default lowering path everywhere in
                    this repo since the container has no TPU).
  * "naive"       — full-matrix references (tests/small inputs only).
  * "auto"        — "pallas" on TPU backends, else "xla_chunked".

Contract: for every op the ``ref.py`` implementation is the ground truth;
kernels must match it within the tolerance asserted in ``tests/``
(``tests/test_kernel_fuzz.py`` sweeps every kernel against its oracle in
interpret mode: 1e-3 max abs error bound, observed ~1e-6).
"""

from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.paged_attention import (
    paged_attention_bkgd,
    paged_mixed_attention_rkgd,
    paged_prefill_attention_ckgd,
)
from repro.kernels.ssd_scan import ssd_decode_step_bh, ssd_scan_bhsp


def _auto_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "xla_chunked"


# ops that already warned about a compiled-Pallas -> ref fallback (warn once
# per op per process, not once per step)
_PALLAS_FALLBACK_WARNED: set[str] = set()


def _resolve_pallas_impl(impl: str, interpret: bool, op: str) -> tuple[str, bool]:
    """Normalize ``impl``/``interpret`` for every Pallas-backed op.

    "pallas_interpret" forces the kernel through the interpreter (works on
    any backend); plain "pallas" on a non-TPU backend falls back to the
    ``ref.py`` path with a one-time warning — numerically it IS the oracle,
    so behavior is identical, just unfused. The policy is uniform across
    ops so a TPU-tuned launch config (``serve.py --attn-impl pallas``)
    serves correctly on CPU hosts on ALL paths, including the legacy
    whole-prompt prefill that lowers through ``flash_attention``.
    """
    if impl == "pallas_interpret":
        return "pallas", True
    if impl == "pallas" and not interpret and jax.default_backend() != "tpu":
        if op not in _PALLAS_FALLBACK_WARNED:
            _PALLAS_FALLBACK_WARNED.add(op)
            warnings.warn(
                f"{op}: impl='pallas' needs a TPU backend (have "
                f"{jax.default_backend()!r}); falling back to the XLA "
                f"reference path (one-time warning; use "
                f"impl='pallas_interpret' to run the kernel interpreted)",
                RuntimeWarning,
                stacklevel=3,
            )
        return "xla_chunked", False
    return impl, interpret


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, KVH, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    impl: str = "auto",
    block_q: int = 256,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Multi-head / grouped-query attention. Returns (B, Sq, H, D)."""
    if impl == "auto":
        impl = _auto_impl()
    impl, interpret = _resolve_pallas_impl(impl, interpret, "flash_attention")
    if impl == "naive":
        return ref.flash_attention_ref(q, k, v, causal=causal, scale=scale)
    if impl == "xla_chunked":
        return ref.flash_attention_chunked(
            q, k, v, causal=causal, scale=scale, chunk_kv=block_kv
        )
    if impl == "pallas":
        qt = jnp.swapaxes(q, 1, 2)  # (B, H, S, D)
        kt = jnp.swapaxes(k, 1, 2)
        vt = jnp.swapaxes(v, 1, 2)
        out = flash_attention_bhsd(
            qt, kt, vt,
            causal=causal, scale=scale,
            block_q=block_q, block_kv=block_kv,
            interpret=interpret,
        )
        return jnp.swapaxes(out, 1, 2)
    raise ValueError(f"unknown attention impl {impl!r}")


def paged_attention(
    q: jax.Array,             # (B, H, D) one query token per sequence
    k_pages: jax.Array,       # (P, page, KVH, D) shared page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, MP) int32
    lengths: jax.Array,       # (B,) int32 valid positions per sequence
    *,
    k_scale: jax.Array | None = None,  # (P, page, KVH) int8-page scales
    v_scale: jax.Array | None = None,
    scale: float | None = None,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """Single-token decode attention over a paged KV cache. Returns (B, H, D).

    Idle slots (length 0) return zeros rather than NaN, so a continuous
    batcher can keep dead rows in the decode batch.

    Quantized pools: when ``k_scale``/``v_scale`` are given the pages are
    int8 with one f32 scale per (page, position, kv head). The Pallas path
    fuses dequant into the page load; the XLA fallback dequantizes the pool
    through :func:`ref.dequantize_pages` and runs the unchanged fp32 oracle,
    so both lowerings share one ground truth.

    Shard-local contract (sharded serving): under the executor's
    ``shard_map`` this op receives the PER-SHARD head slice — q carries
    ``H/tp`` heads, the pools carry ``KVH/tp`` kv heads — while
    ``block_tables``/``lengths`` are replicated (page ids are
    shard-invariant). Heads shard in contiguous GQA groups, so the grouped
    reshape below is exactly the local slice's own grouping and every impl
    (Pallas and the XLA refs) works unchanged on the slice; the q/kv head
    ratio must survive the slicing, which the divisibility check asserts.
    """
    if impl == "auto":
        impl = _auto_impl()
    impl, interpret = _resolve_pallas_impl(impl, interpret, "paged_attention")
    b, h, d = q.shape
    kvh = k_pages.shape[2]
    assert kvh and h % kvh == 0, (
        f"q heads ({h}) must be a multiple of kv heads ({kvh}) — a sharded "
        f"caller must slice both by the same tensor-parallel degree"
    )
    if impl in ("naive", "xla_chunked"):
        if k_scale is not None:
            k_pages = ref.dequantize_pages(k_pages, k_scale)
            v_pages = ref.dequantize_pages(v_pages, v_scale)
        return ref.paged_attention_ref(
            q, k_pages, v_pages, block_tables, lengths, scale=scale
        )
    if impl == "pallas":
        qg = q.reshape(b, kvh, h // kvh, d)
        out = paged_attention_bkgd(
            qg, k_pages, v_pages, block_tables, lengths,
            k_scale=k_scale, v_scale=v_scale,
            scale=scale, interpret=interpret,
        )
        return out.reshape(b, h, d)
    raise ValueError(f"unknown paged attention impl {impl!r}")


def paged_prefill_attention(
    q: jax.Array,            # (C, H, D) one prefill chunk of ONE sequence
    k_pages: jax.Array,      # (P, page, KVH, D) shared page pool
    v_pages: jax.Array,
    block_table: jax.Array,  # (MP,) int32 the sequence's block-table row
    start: jax.Array,        # scalar int32: positions already cached
    valid: jax.Array,        # scalar int32: real tokens in this chunk
    *,
    k_scale: jax.Array | None = None,  # (P, page, KVH) int8-page scales
    v_scale: jax.Array | None = None,
    scale: float | None = None,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    """Chunked-prefill attention over a paged KV cache. Returns (C, H, D).

    The chunk's own K/V must already be scattered into the pages; query i
    (absolute position ``start + i``) attends causally to every cached
    position ``<= start + i`` through the block table, and padded queries
    (``i >= valid``) return zeros. The Pallas kernel
    (:func:`repro.kernels.paged_attention.paged_prefill_attention_ckgd`)
    mirrors the decode kernel's shard-local contract — under the serving
    executor's ``shard_map`` it receives the per-shard head slice with the
    block table replicated — and ``ref.paged_prefill_attention_ref`` stays
    the oracle and the CPU path.
    """
    if impl == "auto":
        impl = _auto_impl()
    impl, interpret = _resolve_pallas_impl(
        impl, interpret, "paged_prefill_attention"
    )
    c, h, d = q.shape
    kvh = k_pages.shape[2]
    assert kvh and h % kvh == 0, (
        f"q heads ({h}) must be a multiple of kv heads ({kvh}) — a sharded "
        f"caller must slice both by the same tensor-parallel degree"
    )
    if impl in ("naive", "xla_chunked"):
        if k_scale is not None:
            k_pages = ref.dequantize_pages(k_pages, k_scale)
            v_pages = ref.dequantize_pages(v_pages, v_scale)
        return ref.paged_prefill_attention_ref(
            q, k_pages, v_pages, block_table, start, valid, scale=scale
        )
    if impl == "pallas":
        qg = q.reshape(c, kvh, h // kvh, d)
        out = paged_prefill_attention_ckgd(
            qg, k_pages, v_pages, block_table, start, valid,
            k_scale=k_scale, v_scale=v_scale,
            scale=scale, interpret=interpret,
        )
        return out.reshape(c, h, d)
    raise ValueError(f"unknown paged prefill impl {impl!r}")


def paged_mixed_attention(
    q: jax.Array,             # (R, H, D) one query row per batch row
    k_pages: jax.Array,       # (P, page, KVH, D) shared page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # (R, MP) int32, one block-table row per row
    last_pos: jax.Array,      # (R,) int32 last attendable position, -1 = dead
    *,
    k_scale: jax.Array | None = None,  # (P, page, KVH) int8-page scales
    v_scale: jax.Array | None = None,
    scale: float | None = None,
    impl: str = "auto",
    interpret: bool = False,
    num_decode: int | None = None,
) -> jax.Array:
    """Fused mixed-step attention over a paged KV cache. Returns (R, H, D).

    Rows are independent: a decode row carries its own slot's block-table
    row with ``last_pos = length`` (the just-scattered token), a prefill
    chunk contributes C consecutive rows sharing one block-table row with
    ``last_pos = start + i`` for live rows, and padded rows (idle slots,
    chunk rows past ``valid``) use ``last_pos = -1`` and return exact
    zeros. One engine step therefore needs ONE attention dispatch. The
    Pallas kernel (:func:`repro.kernels.paged_attention.
    paged_mixed_attention_rkgd`) keeps the other paged kernels' shard-local
    contract — per-shard head slice under the serving executor's
    ``shard_map``, tables/positions replicated — and
    ``ref.paged_mixed_attention_ref`` is the oracle and the CPU path.

    ``num_decode`` is an OPTIONAL static structure hint: when set, the
    caller asserts rows ``[num_decode, R)`` form one prefill chunk — every
    row repeats the same block-table row, live rows hold contiguous
    positions ``start + i`` and dead rows are a suffix. The XLA fallback
    then evaluates decode rows through :func:`ref.paged_attention_ref` and
    chunk rows through :func:`ref.paged_prefill_attention_ref`, gathering
    the chunk's K/V ONCE instead of once per chunk row (the generic ref
    materializes (R, MP*page) keys, which duplicates the shared table C
    times — ruinous off-TPU). The Pallas kernel is row-generic and ignores
    the hint; the generic ref stays the oracle the fuzz harness compares
    both lowerings against.
    """
    if impl == "auto":
        impl = _auto_impl()
    impl, interpret = _resolve_pallas_impl(
        impl, interpret, "paged_mixed_attention"
    )
    r, h, d = q.shape
    kvh = k_pages.shape[2]
    assert kvh and h % kvh == 0, (
        f"q heads ({h}) must be a multiple of kv heads ({kvh}) — a sharded "
        f"caller must slice both by the same tensor-parallel degree"
    )
    if impl in ("naive", "xla_chunked"):
        if k_scale is not None:
            k_pages = ref.dequantize_pages(k_pages, k_scale)
            v_pages = ref.dequantize_pages(v_pages, v_scale)
        if num_decode is None or not 0 < num_decode < r:
            return ref.paged_mixed_attention_ref(
                q, k_pages, v_pages, block_tables, last_pos, scale=scale
            )
        s = num_decode
        dec = ref.paged_attention_ref(
            q[:s], k_pages, v_pages, block_tables[:s], last_pos[:s] + 1,
            scale=scale,
        )
        # dead chunk rows are a suffix, so the live count and the cursor
        # fall out of last_pos; valid == 0 masks every chunk row to zeros
        valid = jnp.sum(last_pos[s:] >= 0).astype(jnp.int32)
        start = jnp.maximum(last_pos[s], 0)
        chk = ref.paged_prefill_attention_ref(
            q[s:], k_pages, v_pages, block_tables[s], start, valid,
            scale=scale,
        )
        return jnp.concatenate([dec, chk], axis=0)
    if impl == "pallas":
        qg = q.reshape(r, kvh, h // kvh, d)
        out = paged_mixed_attention_rkgd(
            qg, k_pages, v_pages, block_tables, last_pos,
            k_scale=k_scale, v_scale=v_scale,
            scale=scale, interpret=interpret,
        )
        return out.reshape(r, h, d)
    raise ValueError(f"unknown paged mixed impl {impl!r}")


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def ssd_scan(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 256,
    impl: str = "auto",
    interpret: bool = False,
    init_state: jax.Array | None = None,  # (B, H, P, N) f32
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,P,N) f32).

    S need not divide ``chunk``: the tail is padded with dt=0 positions,
    which are exact identities on the recurrence (decay exp(0·a)=1, update
    dt·x=0), so one chunked dispatch covers any length and the padded
    outputs are simply sliced off. ``init_state`` continues a scan from a
    carried state (chunked prefill): the reference paths thread it
    natively; the Pallas kernel always starts from zeros, so its linear
    contribution — y_t += C_t·(e^{Σ≤t dA} h0), fs += e^{Σ dA} h0 — is
    superposed in closed form on top of the kernel output.
    """
    if impl == "auto":
        impl = _auto_impl()
    impl, interpret = _resolve_pallas_impl(impl, interpret, "ssd_scan")
    if impl == "naive":
        return ref.ssd_sequential(x, dt, A, Bm, Cm, init_state=init_state)

    s = x.shape[1]
    chunk_eff = min(chunk, s)
    pad = (chunk_eff - s % chunk_eff) % chunk_eff
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))

    if impl == "xla_chunked":
        y, fs = ref.ssd_chunked(x, dt, A, Bm, Cm, init_state, chunk=chunk_eff)
        return (y[:, :s] if pad else y), fs
    if impl == "pallas":
        xt = jnp.swapaxes(x, 1, 2)    # (B, H, S, P)
        dtt = jnp.swapaxes(dt, 1, 2)  # (B, H, S)
        y, fs = ssd_scan_bhsp(xt, dtt, A, Bm, Cm, chunk=chunk_eff,
                              interpret=interpret)
        y = jnp.swapaxes(y, 1, 2)[:, :s]
        if init_state is not None:
            h0 = init_state.astype(jnp.float32)
            dA = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]
            cs = jnp.cumsum(dA, axis=1)  # (B, S+pad, H)
            proj = jnp.einsum(
                "bsn,bhpn->bshp", Cm[:, :s].astype(jnp.float32), h0
            )
            y = (
                y.astype(jnp.float32) + jnp.exp(cs[:, :s, :, None]) * proj
            ).astype(x.dtype)
            fs = fs + jnp.exp(cs[:, -1])[..., None, None] * h0
        return y, fs
    raise ValueError(f"unknown ssd impl {impl!r}")


def ssd_decode_step(
    state: jax.Array,  # (B, H, P, N) f32
    x_t: jax.Array,    # (B, H, P)
    dt_t: jax.Array,   # (B, H)
    A: jax.Array,      # (H,)
    B_t: jax.Array,    # (B, N)
    C_t: jax.Array,    # (B, N)
    *,
    impl: str = "auto",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence. Returns (y (B,H,P), new_state f32)."""
    if impl == "auto":
        impl = _auto_impl()
    impl, interpret = _resolve_pallas_impl(impl, interpret, "ssd_decode_step")
    if impl in ("naive", "xla_chunked"):
        return ref.ssd_decode_step(state, x_t, dt_t, A, B_t, C_t)
    if impl == "pallas":
        return ssd_decode_step_bh(state, x_t, dt_t, A, B_t, C_t,
                                  interpret=interpret)
    raise ValueError(f"unknown ssd decode impl {impl!r}")
