"""Paged attention (decode, chunked prefill, fused mixed) as Pallas TPU kernels.

Three kernels over the same page-pool layout (`kv_cache.PagedKVCache`):

* ``paged_attention_bkgd`` — DECODE: one query token per sequence attends
  over K/V stored in the shared page pool; pages are gathered *inside the
  grid* via a scalar-prefetched block table, so sequences of wildly
  different lengths share one decode batch with zero re-padding and no
  dense gather in HBM. Oracle: ``ref.paged_attention_ref`` — identical
  masking/normalization conventions, idle (length-0) slots return exact
  zeros, never NaN.
* ``paged_prefill_attention_ckgd`` — CHUNKED PREFILL: C queries of ONE
  sequence (absolute positions ``start+i``) attend causally over the
  sequence's paged prefix *plus the chunk itself* (whose K/V the caller
  already scattered into the pages). Oracle:
  ``ref.paged_prefill_attention_ref``; padded queries (``i >= valid``)
  return exact zeros. The C=1, start=length-1 case degenerates to decode.
* ``paged_mixed_attention_rkgd`` — FUSED MIXED STEP: R rows, each carrying
  its OWN block-table row and a single scalar ``last_pos`` (the last
  attendable absolute position; ``-1`` = dead row -> exact zeros). Decode
  rows (``last_pos = length``, the just-scattered token) and one prefill
  chunk's C rows (``last_pos = start + i`` for live rows) ride in one
  dispatch, so a full-occupancy engine step is one kernel launch. Oracle:
  ``ref.paged_mixed_attention_ref``; subsumes both kernels above.

Decode grid: (batch, kv-head, logical-page), page innermost — TPU grid
steps are sequential, so the online-softmax state (acc, m, l) lives in VMEM
scratch and carries across pages of the same (batch, head), reusing the
scratch pattern from ``flash_attention.py``. The BlockSpec index_map reads
``block_tables[b, p]`` (scalar prefetch) to DMA the right physical page;
pages past a sequence's length map to the reserved null page 0 and are
skipped via ``pl.when``. GQA is native: q arrives grouped (B, KVH, G, D) and
each grid cell computes all G grouped heads against one kv head's page.

Prefill-chunk grid: (kv-head, logical-page), page innermost — one sequence,
so there is no batch dim; the whole chunk's grouped queries (flattened to
C*G rows) stay resident in VMEM across the page walk and the same
online-softmax scratch carries between pages. Causality is a per-row mask
(``kpos <= start + row//G``), so a chunk straddling a page boundary, a
partial last page, a COW-forked table or history length 0 all fall out of
the one mask — there is no special-cased edge. Pages wholly past the
chunk's last live query (``p*page >= start+valid``) are skipped.

All three kernels optionally take int8 pages with per-(position, head)
``k_scale``/``v_scale`` pools (shape (P, page, KVH), f32): the scales ride
the SAME scalar-prefetched block table as their pages and dequantization is
fused into the VMEM page load (``k * scale[:, None]``), so a quantized pool
costs one extra (page, 1)-shaped DMA per grid cell and no HBM-resident f32
copy ever exists. Oracle: ``ref.dequantize_pages`` + the fp32 refs.

Tensor-parallel serving dispatches BOTH kernels PER SHARD: the serving
executor's ``shard_map`` hands each device its contiguous kv-head slice of
the page pool (KVH/tp heads) and the matching grouped-q slice, with block
tables and lengths replicated. Nothing in the kernels changes — the grid's
kv-head extent is just the local ``KVH/tp``, and because pages shard only
along the head dim, the scalar-prefetched block-table values (physical page
ids) are identical on every shard.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128  # TPU vector lane count; scratch stats padded to it


def _paged_kernel(
    bt_ref,    # (B, MP) int32 scalar-prefetch: block tables
    len_ref,   # (B,)  int32 scalar-prefetch: valid positions per sequence
    q_ref, k_ref, v_ref,  # VMEM blocks
    *rest,     # [ks_ref, vs_ref when quant], o_ref, acc_ref, m_ref, l_ref
    scale: float,
    page_size: int,
    num_logical_pages: int,
    quant: bool = False,
):
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]
    # pages entirely past the valid prefix hold no live positions: skip
    run = p * page_size < length

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)     # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quant:
            # int8 pages: dequant fused into the page load — one row scale
            # per (position, head), never materialized outside VMEM
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                   # (G, page)
        pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, dimension=1
        )
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        pexp = jnp.exp(s - m_new[:, None])
        pexp = jnp.where(pos < length, pexp, 0.0)  # exact zeros on dead slots
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(pexp, axis=-1)
        pv = jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(p == num_logical_pages - 1)
    def _finalize():
        # max(l, eps): a length-0 slot (idle) finalizes to exact zeros
        l = l_ref[:, 0]
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        ).astype(o_ref.dtype)


def paged_attention_bkgd(
    q: jax.Array,             # (B, KVH, G, D) grouped query, one token per seq
    k_pages: jax.Array,       # (P, page, KVH, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, MP) int32
    lengths: jax.Array,       # (B,) int32
    *,
    k_scale: jax.Array | None = None,  # (P, page, KVH) f32 int8-page scales
    v_scale: jax.Array | None = None,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, kvh, group, d = q.shape
    _, page_size, pkvh, _ = k_pages.shape
    assert pkvh == kvh, (pkvh, kvh)
    mp = block_tables.shape[1]
    scale = scale if scale is not None else d ** -0.5
    quant = k_scale is not None
    assert quant == (v_scale is not None), "k_scale/v_scale go together"

    grid = (b, kvh, mp)
    kernel = functools.partial(
        _paged_kernel,
        scale=scale,
        page_size=page_size,
        num_logical_pages=mp,
        quant=quant,
    )
    page_spec = pl.BlockSpec(
        (1, page_size, 1, d),
        lambda b_, h_, p_, bt, ln: (bt[b_, p_], 0, h_, 0),
    )
    in_specs = [
        pl.BlockSpec(
            (1, 1, group, d), lambda b_, h_, p_, bt, ln: (b_, h_, 0, 0)
        ),
        # physical page comes from the prefetched block table
        page_spec,
        page_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        # per-(position, head) scales ride the same prefetched table
        scale_spec = pl.BlockSpec(
            (1, page_size, 1), lambda b_, h_, p_, bt, ln: (bt[b_, p_], 0, h_)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, group, d), lambda b_, h_, p_, bt, ln: (b_, h_, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),       # acc
            pltpu.VMEM((group, _LANES), jnp.float32),  # m (col 0 used)
            pltpu.VMEM((group, _LANES), jnp.float32),  # l (col 0 used)
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, d), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, *operands)


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


def _paged_prefill_kernel(
    bt_ref,    # (MP,) int32 scalar-prefetch: the sequence's block-table row
    meta_ref,  # (2,)  int32 scalar-prefetch: [start, valid]
    q_ref, k_ref, v_ref,  # VMEM blocks
    *rest,     # [ks_ref, vs_ref when quant], o_ref, acc_ref, m_ref, l_ref
    scale: float,
    page_size: int,
    num_logical_pages: int,
    group: int,
    quant: bool = False,
):
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    p = pl.program_id(1)
    start = meta_ref[0]
    valid = meta_ref[1]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # pages wholly past the chunk's last live query attend nothing: skip.
    # (valid == 0 leaves every row fully masked -> exact zeros, like the ref)
    run = p * page_size < start + valid

    @pl.when(run)
    def _compute():
        # q rows are the chunk flattened to (C*G, D): row r = chunk position
        # r // G, grouped head r % G — one mask expression covers causality,
        # chunk padding, partial pages and page-straddling chunks at once
        q = q_ref[0].astype(jnp.float32)        # (C*G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quant:
            # int8 pages: dequant fused into the page load
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                               # (C*G, page)
        kpos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, dimension=1
        )
        ci = jax.lax.broadcasted_iota(jnp.int32, s.shape, dimension=0) // group
        ok = (kpos <= start + ci) & (ci < valid)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        pexp = jnp.exp(s - m_new[:, None])
        pexp = jnp.where(ok, pexp, 0.0)  # exact zeros on masked slots
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(pexp, axis=-1)
        pv = jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(p == num_logical_pages - 1)
    def _finalize():
        # max(l, eps): fully masked rows (padded queries) finalize to zeros
        l = l_ref[:, 0]
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        ).astype(o_ref.dtype)


def paged_prefill_attention_ckgd(
    q: jax.Array,            # (C, KVH, G, D) grouped chunk queries, ONE seq
    k_pages: jax.Array,      # (P, page, KVH, D)
    v_pages: jax.Array,
    block_table: jax.Array,  # (MP,) int32 the sequence's block-table row
    start: jax.Array,        # scalar int32: positions already cached
    valid: jax.Array,        # scalar int32: real (non-padded) chunk tokens
    *,
    k_scale: jax.Array | None = None,  # (P, page, KVH) f32 int8-page scales
    v_scale: jax.Array | None = None,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Chunked-prefill paged attention; mirrors the decode kernel's contract
    (scalar-prefetched block table, per-shard head slice under the serving
    executor's ``shard_map``). Returns (C, KVH, G, D) in q.dtype."""
    c, kvh, group, d = q.shape
    _, page_size, pkvh, _ = k_pages.shape
    assert pkvh == kvh, (pkvh, kvh)
    mp = block_table.shape[0]
    scale = scale if scale is not None else d ** -0.5
    cg = c * group
    quant = k_scale is not None
    assert quant == (v_scale is not None), "k_scale/v_scale go together"

    # (C, KVH, G, D) -> (KVH, C*G, D): all of one kv head's grouped queries
    # become contiguous rows of one matmul operand
    qf = jnp.transpose(q, (1, 0, 2, 3)).reshape(kvh, cg, d)
    meta = jnp.stack([
        jnp.asarray(start, jnp.int32), jnp.asarray(valid, jnp.int32)
    ])

    grid = (kvh, mp)
    kernel = functools.partial(
        _paged_prefill_kernel,
        scale=scale,
        page_size=page_size,
        num_logical_pages=mp,
        group=group,
        quant=quant,
    )
    page_spec = pl.BlockSpec(
        (1, page_size, 1, d),
        lambda h_, p_, bt, mt: (bt[p_], 0, h_, 0),
    )
    in_specs = [
        pl.BlockSpec((1, cg, d), lambda h_, p_, bt, mt: (h_, 0, 0)),
        # physical page comes from the prefetched block table
        page_spec,
        page_spec,
    ]
    operands = [qf, k_pages, v_pages]
    if quant:
        scale_spec = pl.BlockSpec(
            (1, page_size, 1), lambda h_, p_, bt, mt: (bt[p_], 0, h_)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, cg, d), lambda h_, p_, bt, mt: (h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((cg, d), jnp.float32),       # acc
            pltpu.VMEM((cg, _LANES), jnp.float32),  # m (col 0 used)
            pltpu.VMEM((cg, _LANES), jnp.float32),  # l (col 0 used)
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((kvh, cg, d), q.dtype),
        interpret=interpret,
    )(block_table, meta, *operands)
    return jnp.transpose(out.reshape(kvh, c, group, d), (1, 0, 2, 3))


# ---------------------------------------------------------------------------
# fused mixed step (decode rows + one prefill chunk, one dispatch)
# ---------------------------------------------------------------------------


def _paged_mixed_kernel(
    bt_ref,    # (R, MP) int32 scalar-prefetch: block-table row per query row
    lp_ref,    # (R,)   int32 scalar-prefetch: last attendable position, -1 dead
    q_ref, k_ref, v_ref,  # VMEM blocks
    *rest,     # [ks_ref, vs_ref when quant], o_ref, acc_ref, m_ref, l_ref
    scale: float,
    page_size: int,
    num_logical_pages: int,
    quant: bool = False,
):
    if quant:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        ks_ref = vs_ref = None
        o_ref, acc_ref, m_ref, l_ref = rest
    r = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    lp = lp_ref[r]
    # pages entirely past the row's last attendable position hold nothing
    # it may read: skip. A dead row (lp < 0) skips every page -> exact zeros.
    run = p * page_size <= lp

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)     # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)
        if quant:
            # int8 pages: dequant fused into the page load
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                   # (G, page)
        pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, dimension=1
        )
        # the ONE mask of the fused step: decode causality, chunk causality,
        # partial pages and dead rows are all "position <= last_pos"
        ok = pos <= lp
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        pexp = jnp.exp(s - m_new[:, None])
        pexp = jnp.where(ok, pexp, 0.0)  # exact zeros on masked slots
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(pexp, axis=-1)
        pv = jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(p == num_logical_pages - 1)
    def _finalize():
        # max(l, eps): dead rows (last_pos < 0) finalize to exact zeros
        l = l_ref[:, 0]
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        ).astype(o_ref.dtype)


def paged_mixed_attention_rkgd(
    q: jax.Array,             # (R, KVH, G, D) grouped query, one row per row
    k_pages: jax.Array,       # (P, page, KVH, D)
    v_pages: jax.Array,
    block_tables: jax.Array,  # (R, MP) int32, one block-table row per row
    last_pos: jax.Array,      # (R,) int32 last attendable position, -1 = dead
    *,
    k_scale: jax.Array | None = None,  # (P, page, KVH) f32 int8-page scales
    v_scale: jax.Array | None = None,
    scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Fused mixed-step paged attention; the decode kernel's grid with the
    prefill kernel's per-row causal predicate collapsed to one prefetched
    scalar per row. Same shard-local contract as the other two kernels
    (per-shard head slice under the executor's ``shard_map``, tables and
    positions replicated). Returns (R, KVH, G, D) in q.dtype."""
    r, kvh, group, d = q.shape
    _, page_size, pkvh, _ = k_pages.shape
    assert pkvh == kvh, (pkvh, kvh)
    mp = block_tables.shape[1]
    scale = scale if scale is not None else d ** -0.5
    quant = k_scale is not None
    assert quant == (v_scale is not None), "k_scale/v_scale go together"

    grid = (r, kvh, mp)
    kernel = functools.partial(
        _paged_mixed_kernel,
        scale=scale,
        page_size=page_size,
        num_logical_pages=mp,
        quant=quant,
    )
    page_spec = pl.BlockSpec(
        (1, page_size, 1, d),
        lambda r_, h_, p_, bt, lp: (bt[r_, p_], 0, h_, 0),
    )
    in_specs = [
        pl.BlockSpec(
            (1, 1, group, d), lambda r_, h_, p_, bt, lp: (r_, h_, 0, 0)
        ),
        # physical page comes from the row's prefetched block table
        page_spec,
        page_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quant:
        scale_spec = pl.BlockSpec(
            (1, page_size, 1), lambda r_, h_, p_, bt, lp: (bt[r_, p_], 0, h_)
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale, v_scale]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, group, d), lambda r_, h_, p_, bt, lp: (r_, h_, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),       # acc
            pltpu.VMEM((group, _LANES), jnp.float32),  # m (col 0 used)
            pltpu.VMEM((group, _LANES), jnp.float32),  # l (col 0 used)
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, kvh, group, d), q.dtype),
        interpret=interpret,
    )(block_tables, last_pos, *operands)
