"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth (tests sweep shapes/dtypes and
``assert_allclose`` kernel vs. ref) AND the XLA fallback implementation the
models use on non-TPU backends.

* ``flash_attention_ref``         — naive full-matrix attention (small inputs
  only).
* ``flash_attention_chunked``     — online-softmax over KV chunks (bounded
  memory; what the models lower on XLA; numerically equal to naive).
* ``paged_attention_ref``         — single-token decode over a block-table
  page pool; oracle for ``paged_attention.py`` and the XLA decode path of
  the continuous-batching engine. Idle slots (length 0) yield zeros.
* ``paged_prefill_attention_ref`` — chunked prefill: a chunk of C queries of
  one sequence over its paged prefix + itself (causal). The C=1 case
  degenerates to ``paged_attention_ref``; oracle for the Pallas
  chunk-prefill kernel (``paged_attention.paged_prefill_attention_ckgd``)
  and the XLA/CPU serving path.
* ``paged_mixed_attention_ref``   — fused mixed step: R independent rows,
  each a (block-table row, last attended position) pair — decode rows and
  one prefill chunk's rows share a single dispatch. ``last_pos < 0`` marks
  a dead/padded row (exact zeros). Oracle for the Pallas mixed kernel
  (``paged_attention.paged_mixed_attention_rkgd``) and the XLA fused-step
  serving path; subsumes both refs above.
* ``ssd_sequential``              — Mamba2 SSD as the literal per-token
  recurrence.
* ``ssd_chunked``                 — the SSD block-decomposition (Dao & Gu
  2024), matches ``ssd_sequential``; what the models lower on XLA.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, num_q_heads: int) -> jax.Array:
    """(B, S, KVH, D) -> (B, S, H, D) by group repetition."""
    b, s, kvh, d = k.shape
    if kvh == num_q_heads:
        return k
    rep = num_q_heads // kvh
    return jnp.repeat(k, rep, axis=2)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Naive reference. q: (B, Sq, H, D); k/v: (B, Skv, KVH, D)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
    logits = logits * scale
    if causal:
        # queries are the LAST sq positions of the skv keys (supports Sq<Skv)
        qpos = jnp.arange(sq)[:, None] + (skv - sq)
        kpos = jnp.arange(skv)[None, :]
        logits = jnp.where(kpos <= qpos, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def flash_attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    chunk_kv: int = 512,
) -> jax.Array:
    """Online-softmax attention, scanning KV chunks. Memory O(Sq * chunk)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    kvh = k.shape[2]
    group = h // kvh
    chunk_kv = min(chunk_kv, skv)
    assert skv % chunk_kv == 0, (skv, chunk_kv)
    nkv = skv // chunk_kv

    # grouped views; keep kv heads un-repeated (GQA native)
    qg = q.reshape(b, sq, kvh, group, d).astype(jnp.float32) * scale
    kc = k.reshape(b, nkv, chunk_kv, kvh, d)
    vc = v.reshape(b, nkv, chunk_kv, kvh, d)
    kc = jnp.moveaxis(kc, 1, 0)  # (nkv, b, ckv, kvh, d)
    vc = jnp.moveaxis(vc, 1, 0)

    qpos = jnp.arange(sq) + (skv - sq)  # absolute position of each query

    # flash-attention memory semantics require NOT saving per-chunk logits
    # as scan residuals — checkpoint the body so backward recomputes them
    @jax.checkpoint
    def body(carry, inp):
        acc, m, l = carry
        idx, kblk, vblk = inp
        logits = jnp.einsum(
            "bqhgd,bkhd->bqhgk", qg, kblk.astype(jnp.float32)
        )  # (b, sq, kvh, g, ckv)
        if causal:
            kpos = idx * chunk_kv + jnp.arange(chunk_kv)
            mask = kpos[None, :] <= qpos[:, None]  # (sq, ckv)
            logits = jnp.where(mask[None, :, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vblk.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), ()

    acc0 = jnp.zeros((b, sq, kvh, group, d), jnp.float32)
    m0 = jnp.full((b, sq, kvh, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, group), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(
        body, (acc0, m0, l0), (jnp.arange(nkv), kc, vc)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV page quantization (tiered cache)
# ---------------------------------------------------------------------------


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization over the head_dim (last) axis.

    x (..., D) -> (q int8 (..., D), scale f32 (...,)): one absmax scale per
    (position, head), so dequantization is a row broadcast the paged kernels
    fuse into their K/V loads. The worst-case per-element error is scale/2
    (round-to-nearest over a +/-127 grid) — the quantize->dequant round-trip
    property in ``tests/test_kernel_fuzz.py`` asserts exactly that bound.
    """
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)  # all-zero rows quantize to zeros
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def dequantize_pages(pages: jax.Array, scales: jax.Array) -> jax.Array:
    """int8 pages (..., D) * per-row scales (...,) -> f32 pages.

    The XLA fallback for the quantized paged kernels: dequantize the pool,
    then run the unchanged fp32 oracle — so the fp32 refs stay the single
    ground truth and the Pallas fused-dequant variants are compared against
    ``dequantize_pages`` + the existing oracle in the fuzz harness.
    """
    return pages.astype(jnp.float32) * scales[..., None]


# ---------------------------------------------------------------------------
# paged attention (single-token decode over a block-table KV pool)
# ---------------------------------------------------------------------------


def paged_attention_ref(
    q: jax.Array,             # (B, H, D) one query token per sequence
    k_pages: jax.Array,       # (P, page, KVH, D) shared page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # (B, MP) int32 physical page per logical page
    lengths: jax.Array,       # (B,) int32 valid positions per sequence
    *,
    scale: float | None = None,
) -> jax.Array:
    """Gather-based oracle for the paged decode kernel.

    Each sequence reads its K/V through the block table; positions >= length
    are masked. A sequence with length 0 (an idle slot) returns zeros — the
    same convention as the Pallas kernel, so idle decode slots never produce
    NaNs. Returns (B, H, D) in q.dtype.
    """
    b, h, d = q.shape
    _, page, kvh, _ = k_pages.shape
    mp = block_tables.shape[1]
    group = h // kvh
    scale = scale if scale is not None else d ** -0.5

    # (B, MP, page, KVH, D) -> (B, MP*page, KVH, D): logical contiguous view
    keys = k_pages[block_tables].reshape(b, mp * page, kvh, d)
    vals = v_pages[block_tables].reshape(b, mp * page, kvh, d)

    qg = q.reshape(b, kvh, group, d).astype(jnp.float32) * scale
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg, keys.astype(jnp.float32)
    )  # (B, KVH, G, MP*page)
    valid = jnp.arange(mp * page)[None, :] < lengths[:, None]  # (B, S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    # explicit normalization (not jax.nn.softmax) so an all-masked row gives 0
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m) * valid[:, None, None, :]
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", p / jnp.maximum(l, 1e-30),
                     vals.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


def paged_prefill_attention_ref(
    q: jax.Array,            # (C, H, D) one chunk of queries for ONE sequence
    k_pages: jax.Array,      # (P, page, KVH, D) shared page pool
    v_pages: jax.Array,
    block_table: jax.Array,  # (MP,) int32 the sequence's block-table row
    start: jax.Array,        # scalar int32: positions already cached
    valid: jax.Array,        # scalar int32: real (non-padded) chunk tokens
    *,
    scale: float | None = None,
) -> jax.Array:
    """Chunked-prefill oracle: chunk queries over the paged prefix + chunk.

    Query i (absolute position start+i) attends to every cached position
    <= start+i, read through the block table — the chunk's own K/V must
    already be scattered into the pages (``attention`` does the scatter
    before calling this). Padded queries (i >= valid) return zeros. The
    masked-softmax convention matches :func:`paged_attention_ref`, of which
    this is the multi-query generalization (that kernel is the C=1 case).
    Returns (C, H, D) in q.dtype.
    """
    c, h, d = q.shape
    _, page, kvh, _ = k_pages.shape
    mp = block_table.shape[0]
    group = h // kvh
    scale = scale if scale is not None else d ** -0.5

    keys = k_pages[block_table].reshape(mp * page, kvh, d)
    vals = v_pages[block_table].reshape(mp * page, kvh, d)

    qg = q.reshape(c, kvh, group, d).astype(jnp.float32) * scale
    scores = jnp.einsum(
        "ckgd,skd->ckgs", qg, keys.astype(jnp.float32)
    )  # (C, KVH, G, MP*page)
    kpos = jnp.arange(mp * page)[None, :]
    qpos = start + jnp.arange(c)[:, None]
    ok = (kpos <= qpos) & (jnp.arange(c)[:, None] < valid)  # (C, S)
    scores = jnp.where(ok[:, None, None, :], scores, NEG_INF)
    # explicit normalization (not jax.nn.softmax) so an all-masked row gives 0
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m) * ok[:, None, None, :]
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("ckgs,skd->ckgd", p / jnp.maximum(l, 1e-30),
                     vals.astype(jnp.float32))
    return out.reshape(c, h, d).astype(q.dtype)


def paged_mixed_attention_ref(
    q: jax.Array,             # (R, H, D) one query row per batch row
    k_pages: jax.Array,       # (P, page, KVH, D) shared page pool
    v_pages: jax.Array,
    block_tables: jax.Array,  # (R, MP) int32 block-table row per query row
    last_pos: jax.Array,      # (R,) int32 last attendable position, -1 = dead
    *,
    scale: float | None = None,
) -> jax.Array:
    """Mixed-batch oracle: every row attends positions ``<= last_pos[r]``.

    One predicate covers the whole fused step: a decode row at length L
    (its new token already scattered at position L) uses ``last_pos = L``;
    chunk query i of a prefill at cursor ``start`` uses
    ``last_pos = start + i``; padded rows (idle decode slots, chunk rows
    past ``valid``) use ``last_pos = -1`` and return exact zeros — the same
    no-NaN convention as :func:`paged_attention_ref`, of which this is the
    per-row generalization (decode is ``last_pos = lengths - 1``; a chunk
    is C consecutive rows sharing one block-table row). Returns (R, H, D)
    in q.dtype.
    """
    r, h, d = q.shape
    _, page, kvh, _ = k_pages.shape
    mp = block_tables.shape[1]
    group = h // kvh
    scale = scale if scale is not None else d ** -0.5

    # (R, MP, page, KVH, D) -> (R, MP*page, KVH, D): logical contiguous view
    keys = k_pages[block_tables].reshape(r, mp * page, kvh, d)
    vals = v_pages[block_tables].reshape(r, mp * page, kvh, d)

    qg = q.reshape(r, kvh, group, d).astype(jnp.float32) * scale
    scores = jnp.einsum(
        "rkgd,rskd->rkgs", qg, keys.astype(jnp.float32)
    )  # (R, KVH, G, MP*page)
    ok = jnp.arange(mp * page)[None, :] <= last_pos[:, None]  # (R, S)
    scores = jnp.where(ok[:, None, None, :], scores, NEG_INF)
    # explicit normalization (not jax.nn.softmax) so an all-masked row gives 0
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m) * ok[:, None, None, :]
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("rkgs,rskd->rkgd", p / jnp.maximum(l, 1e-30),
                     vals.astype(jnp.float32))
    return out.reshape(r, h, d).astype(q.dtype)


def paged_verify_attention_ref(
    q: jax.Array,            # (C, H, D) bundle queries: t_last + k drafts
    k_pages: jax.Array,      # (P, page, KVH, D) shared page pool
    v_pages: jax.Array,
    block_table: jax.Array,  # (MP,) int32 the sequence's block-table row
    start: jax.Array,        # scalar int32: cached length L before the bundle
    valid: jax.Array,        # scalar int32: 1 + number of drafted tokens
    *,
    scale: float | None = None,
) -> jax.Array:
    """Speculative-verify oracle: score a k-token draft bundle in one pass.

    Row i is the query for absolute position ``start + i`` (row 0 is the
    last committed token, rows 1..k the drafts) and must attend exactly the
    positions a sequential i-step decode loop would see: the cached prefix
    plus the bundle rows ``<= i`` (the bundle's own K/V already scattered
    at ``start .. start+valid-1``). That predicate is precisely the mixed
    kernel's chunk half, so this oracle delegates to
    :func:`paged_mixed_attention_ref` with broadcast tables and positions
    ``start + i`` (dead past ``valid``) — pinning down, as executable
    documentation, that verify == chunk attention == an unrolled decode
    loop. ``tests/test_kernel_fuzz.py`` asserts all three agree to 1e-3
    for k in 1..8, including COW-forked and preempted-resumed tables.
    Returns (C, H, D) in q.dtype; padded rows are exact zeros.
    """
    c = q.shape[0]
    idx = jnp.arange(c)
    last_pos = jnp.where(idx < valid, start + idx, -1).astype(jnp.int32)
    tables = jnp.broadcast_to(block_table, (c,) + block_table.shape)
    return paged_mixed_attention_ref(
        q, k_pages, v_pages, tables, last_pos, scale=scale
    )


# ---------------------------------------------------------------------------
# Mamba2 SSD
# ---------------------------------------------------------------------------


def ssd_sequential(
    x: jax.Array,      # (B, S, H, P)
    dt: jax.Array,     # (B, S, H)       softplus-activated step sizes
    A: jax.Array,      # (H,)            negative decay rates
    Bm: jax.Array,     # (B, S, N)       input projection (G=1 group)
    Cm: jax.Array,     # (B, S, N)       output projection
    init_state: jax.Array | None = None,  # (B, H, P, N)
) -> tuple[jax.Array, jax.Array]:
    """Literal recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t * x_t B_t^T ;  y_t = h_t C_t."""
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (b,h,p), (b,h), (b,n), (b,n)
        decay = jnp.exp(dtt * Af[None, :])  # (b,h)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    init = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    state, ys = jax.lax.scan(step, init, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,H,P)
    return y, state


def _segsum(dA: jax.Array) -> jax.Array:
    """Stable 'segment sum': L[..., i, j] = sum_{k=j+1..i} dA[..., k] for i>=j else -inf.

    dA: (..., Q). Returns (..., Q, Q) lower-triangular log-decay matrix.
    """
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # cs_i - cs_j = sum_{j+1..i}
    iota = jnp.arange(q)
    mask = iota[:, None] >= iota[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    init_state: jax.Array | None = None,
    *,
    chunk: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """Block decomposition of the SSD recurrence (matches ssd_sequential).

    Splits S into chunks of length Q; within-chunk term is a masked
    attention-like matmul, cross-chunk term is a scan over chunk states.
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xf = x.astype(jnp.float32).reshape(b, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bf = Bm.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cf = Cm.astype(jnp.float32).reshape(b, nc, chunk, n)
    Af = A.astype(jnp.float32)

    dA = dtf * Af[None, None, None, :]            # (b,nc,q,h)
    dA = jnp.moveaxis(dA, -1, -2)                  # (b,nc,h,q)
    L = jnp.exp(_segsum(dA))                       # (b,nc,h,q,q)
    dA_cs = jnp.cumsum(dA, axis=-1)                # (b,nc,h,q)
    dA_total = dA_cs[..., -1]                      # (b,nc,h)

    # ---- intra-chunk (diagonal blocks) ----
    scores = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)         # (b,nc,q,q)
    scores = scores[:, :, None] * L                         # (b,nc,h,q,q)
    xdt = xf * dtf[..., None]                               # (b,nc,q,h,p)
    y_diag = jnp.einsum("bchij,bcjhp->bcihp", scores, xdt)

    # ---- chunk states: contribution of each chunk to the carried state ----
    decay_to_end = jnp.exp(dA_cs[..., -1:] - dA_cs)         # (b,nc,h,q)
    states = jnp.einsum(
        "bchq,bcqn,bcqhp->bchpn", decay_to_end, Bf, xdt
    )                                                        # (b,nc,h,p,n)

    # ---- scan chunk states ----
    def step(carry, inp):
        st, dtot = inp  # (b,h,p,n), (b,h)
        new = carry * jnp.exp(dtot)[..., None, None] + st
        return new, carry  # emit the state ENTERING this chunk

    init = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )
    final_state, entering = jax.lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(dA_total, 1, 0))
    )
    entering = jnp.moveaxis(entering, 0, 1)                 # (b,nc,h,p,n)

    # ---- inter-chunk output: y_off[i] = (C_i . state_in) * exp(dA_cs[i]) ----
    decay_from_start = jnp.exp(dA_cs)                        # (b,nc,h,q)
    y_off = jnp.einsum(
        "bcqn,bchpn,bchq->bcqhp", Cf, entering, decay_from_start
    )

    y = (y_diag + y_off).reshape(b, s, h, p).astype(x.dtype)
    return y, final_state


def ssd_decode_step(
    state: jax.Array,  # (B, H, P, N) f32
    x_t: jax.Array,    # (B, H, P)
    dt_t: jax.Array,   # (B, H)
    A: jax.Array,      # (H,)
    B_t: jax.Array,    # (B, N)
    C_t: jax.Array,    # (B, N)
) -> tuple[jax.Array, jax.Array]:
    """One-token SSD recurrence for serving."""
    dtf = dt_t.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None, :])
    upd = jnp.einsum(
        "bh,bhp,bn->bhpn", dtf, x_t.astype(jnp.float32), B_t.astype(jnp.float32)
    )
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), state
