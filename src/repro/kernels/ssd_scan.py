"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

TPU-native adaptation: the chunk dimension is the innermost grid axis (TPU
grids are sequential), so the inter-chunk SSM state (P x N, f32) lives in VMEM
scratch and is carried chunk-to-chunk — the HBM<->VMEM traffic per chunk is
exactly the chunk's inputs/outputs, and the quadratic intra-chunk work runs on
the MXU as (Q x N)(N x Q) and (Q x Q)(Q x P) matmuls. The in-kernel cumulative
sum over the chunk is computed as a lower-triangular (Q x Q) matmul — a TPU
idiom (MXU-friendly) instead of a sequential scan.

Layouts: x (B, H, S, P); dt (B, H, S); A (H,); Bm/Cm (B, S, N).
Chunk length Q must divide S. Output y (B, H, S, P) and final state
(B, H, P, N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,  # inputs
    y_ref, fs_ref,                        # outputs
    state_ref,                            # scratch: (P, N) f32 carried state
    *,
    chunk: int,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (Q,)
    a = a_ref[0].astype(jnp.float32)               # scalar
    bm = b_ref[0].astype(jnp.float32)              # (Q, N)
    cm = c_ref[0].astype(jnp.float32)              # (Q, N)

    q = chunk
    dA = dt * a                                    # (Q,)
    # cumulative sum as a lower-triangular matmul (MXU-friendly, no seq scan)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tril = (ii >= jj).astype(jnp.float32)          # includes diagonal
    cs = jax.lax.dot_general(
        tril, dA, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # cs_i = sum_{k<=i} dA_k

    # decay matrix L[i,j] = exp(cs_i - cs_j) for i>=j else 0
    L = jnp.where(ii >= jj, jnp.exp(cs[:, None] - cs[None, :]), 0.0)

    xdt = x * dt[:, None]                          # (Q, P)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * L                                          # (Q, Q)
    y_diag = jax.lax.dot_general(
        scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (Q, P)

    # inter-chunk: contribution of the entering state
    state = state_ref[...]                         # (P, N)
    c_state = jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (Q, P)
    y_off = c_state * jnp.exp(cs)[:, None]

    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: state' = state * exp(cs[-1]) + sum_j e^{cs[-1]-cs_j} dt_j x_j B_j^T
    decay_to_end = jnp.exp(cs[-1] - cs)            # (Q,)
    xw = xdt * decay_to_end[:, None]               # (Q, P)
    upd = jax.lax.dot_general(
        xw, bm, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (P, N)
    state_ref[...] = state * jnp.exp(cs[-1]) + upd

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        fs_ref[0, 0] = state_ref[...]


def ssd_scan_bhsp(
    x: jax.Array,   # (B, H, S, P)
    dt: jax.Array,  # (B, H, S)
    A: jax.Array,   # (H,)
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    b, h, s, p = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    grid = (b, h, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, fs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b_, h_, ci: (b_, h_, ci)),
            pl.BlockSpec((1,), lambda b_, h_, ci: (h_,)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ci: (b_, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, ci: (b_, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, fs


def _ssd_decode_kernel(s_ref, x_ref, dt_ref, a_ref, b_ref, c_ref,
                       y_ref, ns_ref):
    state = s_ref[0, 0]                              # (P, N) f32
    x = x_ref[0, 0].astype(jnp.float32)              # (P,)
    dt = dt_ref[0, 0].astype(jnp.float32)            # scalar
    a = a_ref[0].astype(jnp.float32)                 # scalar
    bm = b_ref[0].astype(jnp.float32)                # (N,)
    cm = c_ref[0].astype(jnp.float32)                # (N,)
    new = state * jnp.exp(dt * a) + (dt * x)[:, None] * bm[None, :]
    ns_ref[0, 0] = new
    y_ref[0, 0] = jax.lax.dot_general(
        new, cm, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(y_ref.dtype)


def ssd_decode_step_bh(
    state: jax.Array,  # (B, H, P, N) f32
    x_t: jax.Array,    # (B, H, P)
    dt_t: jax.Array,   # (B, H)
    A: jax.Array,      # (H,)
    B_t: jax.Array,    # (B, N)
    C_t: jax.Array,    # (B, N)
    *,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Single-token SSD recurrence, one (batch, head) cell per grid step.

    The decode-path analogue of :func:`ssd_scan_bhsp`: the rank-1 state
    update h' = e^{dt·a} h + (dt·x) B^T and the readout y = h' C run fused
    in VMEM. Returns (y (B,H,P) in x's dtype, new_state (B,H,P,N) f32).
    """
    b, h, p = x_t.shape
    n = B_t.shape[-1]
    y, ns = pl.pallas_call(
        _ssd_decode_kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, 1, p, n), lambda b_, h_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, p), lambda b_, h_: (b_, h_, 0)),
            pl.BlockSpec((1, 1), lambda b_, h_: (b_, h_)),
            pl.BlockSpec((1,), lambda b_, h_: (h_,)),
            pl.BlockSpec((1, n), lambda b_, h_: (b_, 0)),
            pl.BlockSpec((1, n), lambda b_, h_: (b_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, p), lambda b_, h_: (b_, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, p), x_t.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(state, x_t, dt_t, A, B_t, C_t)
    return y, ns
