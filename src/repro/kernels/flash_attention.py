"""Flash attention as a Pallas TPU kernel.

TPU-native adaptation (not a CUDA port): the grid walks (batch, q-head,
q-block, kv-block) with the kv-block dimension innermost — TPU grid steps are
sequential, so the online-softmax state (acc, m, l) lives in VMEM scratch and
carries across kv-blocks of the same q-block. GQA is expressed in the
BlockSpec index_map (q-head h reads kv-head h // group), so grouped heads
never materialize repeated K/V in HBM. MXU alignment: block_q x head_dim and
block_kv x head_dim tiles, f32 accumulation.

Layout: q (B, H, Sq, D); k/v (B, KVH, Skv, D). ``ops.flash_attention`` handles
the (B, S, H, D) <-> (B, H, S, D) transposes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128  # TPU vector lane count; scratch stats padded to it


def _flash_kernel(
    q_ref, k_ref, v_ref,  # VMEM blocks
    o_ref,
    acc_ref, m_ref, l_ref,  # VMEM scratch
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_kv: int,
    num_kv_blocks: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip kv blocks strictly after the last query of this q block
    first_q = qi * block_q + q_offset
    last_q = first_q + block_q - 1
    first_k = ki * block_kv
    run = jnp.logical_or(jnp.logical_not(causal), first_k <= last_q)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)
        if causal:
            qpos = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0) + first_q
            kpos = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1) + first_k
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, 0]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KVH, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 256,
    block_kv: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, block_q, skv, block_kv)
    nq, nkv = sq // block_q, skv // block_kv
    q_offset = skv - sq  # queries are the last sq of skv positions

    grid = (b, h, nq, nkv)
    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_kv=block_kv,
        num_kv_blocks=nkv,
        q_offset=q_offset,
    )
    scratch = [
        pltpu.VMEM((block_q, d), jnp.float32),   # acc
        pltpu.VMEM((block_q, _LANES), jnp.float32),  # m (col 0 used)
        pltpu.VMEM((block_q, _LANES), jnp.float32),  # l (col 0 used)
    ]

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, d), lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, d), lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
