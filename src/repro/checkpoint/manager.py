"""Distributed checkpointing: atomic, integrity-checked, elastic-reshardable.

Layout (one directory per step, one .npy per pytree leaf):

    <root>/step_000123/
        MANIFEST.json   — tree structure, shapes, dtypes, sha256 per leaf,
                          user metadata, "committed": true (written LAST)
        leaf_00000.npy ...

Fault-tolerance properties (the paper's C6, adapted — see DESIGN.md):
  * atomic commit: leaves are written into a ``.tmp`` dir which is fsynced
    and renamed; a crash mid-save never corrupts the latest checkpoint;
  * integrity: sha256 per leaf, verified on restore;
  * elastic reshard: ``restore(shardings=...)`` device_puts each leaf under
    an arbitrary target sharding — save on a 16x16 mesh, restore on 2x16x16
    (or 1 CPU device) with no format change;
  * async: ``save(..., sync=False)`` snapshots to host then writes in a
    background thread, so the train loop overlaps I/O with compute.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.compat import tree_leaves_with_path


def _tree_paths(tree: Any) -> list[tuple[str, Any]]:
    leaves = tree_leaves_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in leaves]


class CheckpointManager:
    def __init__(self, root: str | Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def steps(self) -> list[int]:
        out = []
        for d in self.root.glob("step_*"):
            mf = d / "MANIFEST.json"
            if mf.exists():
                try:
                    if json.loads(mf.read_text()).get("committed"):
                        out.append(int(d.name.split("_")[1]))
                except (json.JSONDecodeError, ValueError, IndexError):
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def wait(self):
        """Block until a pending async save completes (re-raises its error)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any, meta: dict | None = None, sync: bool = True):
        self.wait()
        # snapshot to host memory first (cheap on CPU; on TPU this is the
        # device->host transfer that the async thread must not race with)
        host = [(k, np.asarray(v)) for k, v in _tree_paths(tree)]
        structure = jax.tree.structure(tree)

        def write():
            try:
                self._write(step, host, structure, meta or {})
            except BaseException as e:  # surfaced on next wait()/save()
                self._error = e

        if sync:
            write()
            self.wait()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _write(self, step: int, host: list, structure, meta: dict):
        final = self._step_dir(step)
        tmp = final.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        entries = []
        for i, (keypath, arr) in enumerate(host):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, arr)
            digest = hashlib.sha256((tmp / fname).read_bytes()).hexdigest()
            entries.append(
                {
                    "key": keypath,
                    "file": fname,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": digest,
                }
            )
        manifest = {
            "step": step,
            "leaves": entries,
            "treedef": str(structure),
            "meta": meta,
            "committed": True,
        }
        (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
        fd = os.open(tmp, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(
        self,
        like: Any,
        step: int | None = None,
        shardings: Any = None,
        verify: bool = True,
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs). ``shardings``: matching pytree of NamedShardings
        for elastic placement; None -> plain host arrays.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {self.root}")
        d = self._step_dir(step)
        manifest = json.loads((d / "MANIFEST.json").read_text())
        by_key = {e["key"]: e for e in manifest["leaves"]}

        flat_like = _tree_paths(like)
        flat_shard = (
            [v for _, v in _tree_paths(shardings)] if shardings is not None else [None] * len(flat_like)
        )
        out = []
        for (key, ref), shd in zip(flat_like, flat_shard):
            e = by_key.get(key)
            if e is None:
                raise KeyError(f"checkpoint {d} missing leaf {key}")
            raw = (d / e["file"]).read_bytes()
            if verify:
                digest = hashlib.sha256(raw).hexdigest()
                if digest != e["sha256"]:
                    raise IOError(f"integrity failure for {key} in {d}")
            arr = np.load(d / e["file"])
            want_shape = tuple(ref.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"{key}: ckpt {arr.shape} vs expected {want_shape}")
            arr = arr.astype(ref.dtype) if str(arr.dtype) != str(ref.dtype) else arr
            out.append(jax.device_put(arr, shd) if shd is not None else arr)
        tree = jax.tree.unflatten(jax.tree.structure(like), out)
        return tree, manifest["meta"]
