"""Fault-tolerant training driver: the paper's pipeline, end to end.

The training run is expressed as a Jup2Kub workflow of four steps —

    prepare_data -> train (long-running, checkpointed) -> evaluate -> report

— scheduled by WorkflowScheduler with heartbeats, retries and (optionally)
chaos injection. The train step checkpoints every ``--ckpt-every`` steps and
resumes from the latest checkpoint after a pod death; the data pipeline
replays deterministically from the restored step.

CPU-runnable with reduced configs:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 60 --chaos
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def build_workflow(args, workdir: Path):
    from repro.configs import get_arch, reduced
    from repro.core.dag import Step, StepGraph
    from repro.data import DataConfig, SyntheticCorpus
    from repro.models import build_model
    from repro.checkpoint import CheckpointManager
    from repro.train import AdamWConfig, init_train_state, make_train_step

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    opt_cfg = AdamWConfig(
        lr=args.lr, warmup_steps=10, decay_steps=max(args.steps * 4, 100),
        weight_decay=0.0, moment_dtype="float32",
    )

    # ---------------- step fns ----------------
    def prepare_data(inputs):
        dc = DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq_len,
            global_batch=args.batch, seed=args.seed,
            vision_tokens=cfg.num_frontend_tokens if cfg.family == "vlm" else 0,
            frames=cfg.is_encoder_decoder, d_model=cfg.d_model, dtype=cfg.dtype,
        )
        return {"data_config": dc}

    def train(inputs, ctx):
        dc = inputs["data_config"]
        corpus = SyntheticCorpus(dc)
        model = build_model(cfg)
        step_fn = jax.jit(
            make_train_step(model, opt_cfg, ga=args.ga), donate_argnums=(0,)
        )
        ckpt = CheckpointManager(ctx.claim_path or workdir / "ckpt", keep=2)

        start = ckpt.latest_step()
        if start is not None:
            like = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                init_train_state(model, jax.random.key(args.seed), opt_cfg),
            )
            state, meta = ckpt.restore(like, step=start)
            state = jax.tree.map(jnp.asarray, state)
            losses = list(meta.get("losses", []))
            ctx.beat(progress=start, info="restored")
        else:
            state = init_train_state(model, jax.random.key(args.seed), opt_cfg)
            losses = []
            start = 0

        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in corpus.batch_at(i).items()}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            ctx.beat(progress=i + 1, loss=losses[-1])  # liveness + kill point
            if (i + 1) % args.ckpt_every == 0 or i + 1 == args.steps:
                ckpt.save(i + 1, state, meta={"losses": losses}, sync=True)
        return {"losses": losses, "final_step": args.steps,
                "ckpt_dir": str(ckpt.root)}

    def evaluate(inputs, ctx):
        from repro.train.step import make_eval_step
        dc = inputs["data_config"]
        corpus = SyntheticCorpus(dc)
        model = build_model(cfg)
        ckpt = CheckpointManager(inputs["ckpt_dir"])
        tmpl = init_train_state(model, jax.random.key(args.seed), opt_cfg)
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tmpl)
        state, _ = ckpt.restore(like)
        eval_fn = jax.jit(make_eval_step(model))
        tot = 0.0
        n_eval = 4
        for i in range(n_eval):
            batch = {k: jnp.asarray(v) for k, v in corpus.batch_at(10_000 + i).items()}
            tot += float(eval_fn(jax.tree.map(jnp.asarray, state["params"]), batch)["loss"])
        return {"eval_loss": tot / n_eval}

    def report(inputs):
        losses = inputs["losses"]
        rep = {
            "arch": cfg.name,
            "steps": inputs["final_step"],
            "first_loss": losses[0],
            "last_loss": losses[-1],
            "eval_loss": inputs["eval_loss"],
            "improved": bool(losses[-1] < losses[0]),
        }
        (workdir / "report.json").write_text(json.dumps(rep, indent=1))
        return {"report": rep}

    steps = {
        "prepare_data": Step("prepare_data", fn=prepare_data,
                             reads=set(), writes={"data_config"}, replicas=1),
        "train": Step("train", fn=train, reads={"data_config"},
                      writes={"losses", "final_step", "ckpt_dir"},
                      long_running=True, max_attempts=6),
        "evaluate": Step("evaluate", fn=evaluate,
                         reads={"data_config", "ckpt_dir"},
                         writes={"eval_loss"}, replicas=2),
        "report": Step("report", fn=report,
                       reads={"losses", "final_step", "eval_loss"},
                       writes={"report"}, replicas=1),
    }
    edges = {
        ("prepare_data", "train"): {"data_config"},
        ("prepare_data", "evaluate"): {"data_config"},
        ("train", "evaluate"): {"ckpt_dir"},
        ("train", "report"): {"losses", "final_step"},
        ("evaluate", "report"): {"eval_loss"},
    }
    return StepGraph(steps=steps, edges=edges).validate()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ga", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--chaos", action="store_true",
                    help="kill the train pod twice mid-run; FT must recover")
    ap.add_argument("--workdir", default="experiments/train_run")
    args = ap.parse_args()

    from repro.core import ArtifactStore, TopicBus, WorkflowScheduler
    from repro.core.faults import FaultInjector, KillRule
    from repro.core.scheduler import RetryPolicy

    workdir = Path(args.workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    graph = build_workflow(args, workdir)
    bus = TopicBus(workdir / "bus")
    store = ArtifactStore(workdir / "store")

    faults = None
    if args.chaos:
        faults = FaultInjector(
            [KillRule(step="train", after_s=1.0, times=2)]
        )
    claim = store.claim("train-ckpt", tier="shared")
    sched = WorkflowScheduler(
        graph, bus, store,
        workflow=f"train-{args.arch}",
        retry=RetryPolicy(max_attempts=6, backoff_s=0.1),
        liveness_window_s=30.0,
        fault_injector=faults,
        claim_paths={"train": str(claim.path)},
    )
    t0 = time.time()
    arts = sched.run(timeout_s=3600)
    rep = arts["report"]
    print(json.dumps(rep, indent=1))
    print(f"wall: {time.time()-t0:.1f}s")
    kinds = [e["kind"] for e in sched.events.history()]
    print("events:", {k: kinds.count(k) for k in sorted(set(kinds))})
    assert rep["improved"], "training did not reduce loss"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
