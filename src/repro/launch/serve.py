"""Serving driver: protocol engines behind a bus topic, streaming deltas.

Requests land on the ``requests`` topic (Kafka analogue). ONE engine-agnostic
worker loop drives any :class:`repro.serving.EngineCore` implementation —
paged continuous batching or the lockstep baseline — through the same
lifecycle: pull up to ``engine.capacity()`` messages, parse them with the
shared boundary parser (every sampling field survives; the old per-engine
parsers dropped ``temperature``), ``submit()``, and publish each
:class:`StreamEvent` to ``responses`` as it happens — per-token ``delta``
messages first, then one terminal ``finish`` message with the full output
and a typed ``finish_reason``, so consumers observe streaming output before
completion.

Admission order is pluggable (``--admission fifo|priority|deadline``).
Prompts prefill in fixed-size chunks interleaved with decode
(``--prefill-chunk``, 0 restores whole-prompt prefill) and identical prompt
prefixes are served from shared copy-on-write pages (``--no-prefix-sharing``
to disable; ``--shared-prefix N`` synthesizes the pipeline-rerun workload
that exercises it). By default the paged engine runs its *fused* step — the
step's prefill chunk and every decode slot go down in one mixed dispatch
(``--step-mode interleaved`` restores the two-dispatch step for A/B;
``--token-budget`` caps rows per fused step). The utilization line reports
the per-dispatch batch composition (decode/prefill/padded rows and the
fused-dispatch fraction) alongside the occupancy gauges.

With prefix sharing on, the paged engine also runs the tiered KV cache
(``serving/kv_tiers.py``): finished prompts' prefix pages park in a
reclaim-under-pressure LRU instead of freeing, optionally spill to host RAM
(``--host-pages N``) and persist through the artifact store
(``--persist-dir PATH``) so identical reruns skip their prefill, and
``--kv-quant int8`` stores pages quantized for ~2x KV capacity per byte.
The tier gauges and hit counters appear in the utilization line.

The paged engine's executor runs under ``shard_map`` on a ``("model",)``
mesh; ``--mesh auto`` (default) picks the largest tensor-parallel degree
the model's head counts allow over the local devices, ``--mesh N`` forces
an explicit size (1 disables sharding). The run prints p50/p90/p99
time-to-first-token and inter-token latency plus the per-step decode-slot
occupancy and page-pool utilization gauges. The HPA analogue watches
consumer lag and scales workers in [min,max]. CPU-runnable with reduced
configs:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --requests 24 --shared-prefix 32
"""

from __future__ import annotations

import argparse
import threading
import time
from pathlib import Path

import jax


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="lockstep micro-batch size / paged slot count")
    ap.add_argument("--engine", choices=["paged", "lockstep"], default="paged",
                    help="'paged' (default) picks the continuous-batching "
                         "engine for the config's family — page-pool KV for "
                         "dense/moe/vlm, the recurrent-state SSM engine for "
                         "ssm/hybrid — and fails loudly "
                         "(UnsupportedConfigError) when no continuous-"
                         "batching engine supports the config; 'lockstep' "
                         "forces the micro-batching baseline")
    ap.add_argument("--admission", choices=["fifo", "priority", "deadline"],
                    default="fifo", help="admission policy for every worker")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="paged engine: prefill chunk size; 0 restores the "
                         "whole-prompt bucketed prefill")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="paged engine: disable COW prefix-page sharing")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend a common N-token prefix to every request "
                         "(pipeline-rerun workload; exercises prefix sharing)")
    ap.add_argument("--mesh", default="auto",
                    help="paged engine: tensor-parallel mesh size for the "
                         "sharded executor — 'auto' picks the largest "
                         "feasible degree over local devices, an integer "
                         "forces that many (1 disables sharding)")
    ap.add_argument("--step-mode", default="fused",
                    choices=["fused", "interleaved"],
                    help="paged engine: 'fused' (default) runs every decode "
                         "slot and the step's prefill chunk in ONE mixed "
                         "dispatch; 'interleaved' keeps the two-dispatch "
                         "pre-fusion step for A/B comparison — streams are "
                         "byte-identical either way")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="paged engine, fused mode: cap decode rows + chunk "
                         "tokens per step (Sarathi-style); 0 disables the cap")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="paged engine: KV page precision — 'int8' stores "
                         "pages quantized with per-page-per-head scales "
                         "(~2x sequences per pool byte; dequantization is "
                         "fused into the paged attention kernels)")
    ap.add_argument("--host-pages", type=int, default=0, metavar="N",
                    help="paged engine: host-RAM spill tier capacity in "
                         "pages — cold parked prefix pages demote to host "
                         "buffers and prefetch back on prefix hits; 0 "
                         "disables the host tier")
    ap.add_argument("--persist-dir", default=None, metavar="PATH",
                    help="paged engine: ArtifactStore root for write-through "
                         "prefix-page persistence — spilled pages survive "
                         "restarts and re-serve identical prompt prefixes "
                         "across runs")
    ap.add_argument("--spec", default="off",
                    choices=["off", "ngram", "draft"],
                    help="paged engine: speculative decoding proposer — "
                         "'ngram' self-speculates from the request's own "
                         "prompt+output history (no second model), 'draft' "
                         "runs a small draft model (--draft-config) on its "
                         "own paged cache; streams are byte-identical to "
                         "'off' either way")
    ap.add_argument("--spec-k", type=int, default=4, metavar="K",
                    help="speculative decoding: drafted tokens per bundle "
                         "(the verify dispatch scores K drafts + 1 bonus "
                         "position in one call)")
    ap.add_argument("--draft-config", default=None, metavar="ARCH",
                    help="--spec draft: arch name for the draft model "
                         "(e.g. 'smollm-360m'; '-reduced' suffix honored, "
                         "and --reduced applies to the draft too); fresh "
                         "seed-derived draft weights are initialized at "
                         "startup")
    ap.add_argument("--attn-impl", default="auto",
                    choices=["auto", "pallas", "pallas_interpret",
                             "xla_chunked", "naive"],
                    help="paged engine: attention lowering for decode and "
                         "chunked prefill — 'auto' uses the Pallas kernels "
                         "on TPU and the XLA reference elsewhere; 'pallas' "
                         "on a non-TPU backend falls back to the reference "
                         "with a one-time warning")
    ap.add_argument("--ssd-impl", default="auto",
                    choices=["auto", "pallas", "pallas_interpret",
                             "xla_chunked", "naive"],
                    help="ssm engine: SSD scan/decode lowering — same "
                         "auto/fallback contract as --attn-impl")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="run the supervised fleet instead of the flat "
                         "worker pool: a FleetSupervisor with N initial "
                         "engine workers, heartbeat probes, crash-replay "
                         "recovery and lag/occupancy autoscaling "
                         "(serving/fleet.py); 0 keeps the legacy path")
    ap.add_argument("--role", choices=["driver", "worker"], default="driver",
                    help="'worker': run ONE fleet engine-worker loop against "
                         "an existing --workdir bus (a supervisor elsewhere "
                         "publishes fleet.work) and exit when the work topic "
                         "drains — the multi-process deployment shape")
    ap.add_argument("--worker-name", default="w0",
                    help="--role worker: this worker's pod name")
    ap.add_argument("--workdir", default="experiments/serve_run")
    args = ap.parse_args()

    from repro.configs import get_arch, reduced
    from repro.core import TopicBus
    from repro.core.autoscaler import Autoscaler, AutoscalerConfig
    from repro.core.events import EventLog
    from repro.core.registry import ServiceRegistry
    from repro.launch.mesh import describe_mesh, make_serving_mesh
    from repro.models import build_model
    from repro.serving import (
        ContinuousBatchingEngine,
        DeadlineAdmission,
        FIFOAdmission,
        GenerationEngine,
        PriorityAdmission,
        SSMEngine,
        UnsupportedConfigError,
        format_latency,
        request_from_message,
    )
    from repro.serving.executor import (
        default_serving_mesh,
        place_serving_params,
        set_default_serving_mesh,
    )
    from repro.serving.metrics import UtilizationMetrics

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    paged_ok = not cfg.is_encoder_decoder and cfg.family in ("dense", "moe", "vlm")
    ssm_ok = not cfg.is_encoder_decoder and cfg.family in ("ssm", "hybrid")
    use_paged = args.engine == "paged" and paged_ok
    use_ssm = args.engine == "paged" and ssm_ok
    if args.engine == "paged" and not (use_paged or use_ssm):
        # no silent lockstep downgrade: the caller asked for continuous
        # batching, and neither the page-pool nor the recurrent-state
        # engine can serve this config
        raise UnsupportedConfigError(
            f"no continuous-batching engine supports {cfg.name} "
            f"(family={cfg.family!r}, encoder_decoder="
            f"{cfg.is_encoder_decoder}); pass --engine lockstep to serve "
            f"it with the micro-batching baseline"
        )
    sharded = use_paged or use_ssm  # both executors run under shard_map
    if sharded and args.mesh != "auto":
        set_default_serving_mesh(make_serving_mesh(int(args.mesh)))
    mesh_desc = describe_mesh(default_serving_mesh(cfg)) if sharded else "n/a"
    workdir = Path(args.workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    bus = TopicBus(workdir / "bus")
    events = EventLog(bus, workflow=f"serve-{cfg.name}")
    registry = ServiceRegistry(bus)

    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    if sharded:
        # validates the mesh ONCE in the main thread (a bad --mesh N fails
        # fast here, not inside every worker) and pre-shards the weights so
        # all workers share one placed copy instead of each materializing
        # their own
        params = place_serving_params(cfg, params)
    shared = list(range(2, 2 + args.shared_prefix))
    max_len = 64 + args.shared_prefix + args.max_new

    # ---- producer: enqueue requests (mixed sampling params, so the full
    # Request surface travels through the bus, not just uid/prompt) ----
    if args.role == "driver":
        for i in range(args.requests):
            bus.publish(
                "requests",
                {"uid": f"r{i}",
                 "prompt": shared + [1 + (i % 30), 2, 3 + (i % 7)],
                 "max_new_tokens": args.max_new,
                 "temperature": 0.7 if i % 4 == 3 else 0.0,
                 "seed": i,
                 "priority": i % 3},
            )

    group = "servers"
    scaler = Autoscaler(
        bus, "requests", group,
        AutoscalerConfig(min_replicas=1, max_replicas=4,
                         target_lag_per_replica=args.max_batch * 2),
        events=events,
    )
    policies = {"fifo": FIFOAdmission, "priority": PriorityAdmission,
                "deadline": DeadlineAdmission}

    # --reduced shrinks the target; a draft arch named on the CLI must
    # shrink with it, or the "small" draft model is full-size on CPU
    draft_config = args.draft_config
    if draft_config and args.reduced and not draft_config.endswith("-reduced"):
        draft_config = f"{draft_config}-reduced"

    def make_engine():
        admission = policies[args.admission]()
        if use_paged:
            return ContinuousBatchingEngine(
                cfg, params, max_len=max_len,
                max_slots=max(args.max_batch, 2),
                prefill_chunk=args.prefill_chunk or None,
                prefix_sharing=not args.no_prefix_sharing,
                admission=admission,
                attn_impl=args.attn_impl,
                step_mode=args.step_mode,
                token_budget=args.token_budget or None,
                kv_quant=args.kv_quant,
                host_pages=args.host_pages,
                persist_dir=args.persist_dir,
                speculative=args.spec,
                spec_k=args.spec_k,
                draft_config=draft_config,
            )
        if use_ssm:
            return SSMEngine(
                cfg, params, max_len=max_len,
                max_slots=max(args.max_batch, 2),
                prefill_chunk=args.prefill_chunk or None,
                admission=admission,
                attn_impl=args.attn_impl,
                ssd_impl=args.ssd_impl,
            )
        return GenerationEngine(cfg, params, max_len=max_len,
                                max_batch=args.max_batch, admission=admission)

    if args.role == "worker":
        return _run_worker(args, bus, make_engine)
    if args.fleet:
        return _run_fleet(args, bus, events, make_engine)

    done: dict[str, list[int]] = {}
    latencies: list = []  # Results, for TTFT/ITL percentiles
    utilization = UtilizationMetrics()  # merged across workers
    lock = threading.Lock()

    def finish(uid: str, result) -> None:
        """Publish one terminal response and record it for the driver."""
        bus.publish("responses", {
            "uid": uid, "event": "finish",
            "tokens": result.tokens if result else [],
            "finish_reason": result.finish_reason.value if result else "rejected",
            "error": result.error if result else None,
        })
        with lock:
            done[uid] = result.tokens if result else []
            if result is not None:
                latencies.append(result)

    def worker(wid: int, stop: threading.Event):
        """THE worker loop: engine-agnostic, protocol-driven, streaming."""
        engine = make_engine()
        registry.register("generate", f"pod://server-{wid}", f"server-{wid}")
        handles = {}
        try:
            _worker_loop(engine, stop, handles)
        finally:
            cache = getattr(engine, "cache", None)
            if cache is not None and getattr(cache, "tiers", None) is not None:
                # drain parked prefixes to host/persist so a --persist-dir
                # rerun of the same prompts revives them across restarts
                cache.flush_tiers()
                engine._record_tiers()  # fold the flush into the gauges
            with lock:
                utilization.merge(engine.utilization)

    def _worker_loop(engine, stop, handles):
        while not stop.is_set():
            pulled = 0
            for m in bus.consume("requests", group, limit=engine.capacity()):
                try:
                    req = request_from_message(m.value)
                except (ValueError, KeyError, TypeError) as e:
                    v = m.value
                    uid = v.get("uid", "?") if isinstance(v, dict) else "?"
                    bus.publish("responses", {
                        "uid": str(uid), "event": "finish", "tokens": [],
                        "finish_reason": "rejected", "error": str(e),
                    })
                    with lock:
                        done[str(uid)] = []
                else:
                    h = engine.submit(req)
                    if h.done:  # rejected at the API boundary
                        finish(h.uid, h.result())
                    else:
                        handles[h.uid] = h
                        pulled += 1
                bus.commit("requests", group, m.offset + 1)
            if engine.idle:
                if not pulled and bus.lag("requests", group) == 0:
                    return
                time.sleep(0.01)
                continue
            for ev in engine.step():
                if ev.kind == "token":
                    bus.publish("responses", {
                        "uid": ev.uid, "event": "delta",
                        "token": ev.token, "index": ev.index,
                    })
                elif ev.kind == "finish":
                    h = handles.pop(ev.uid, None)
                    finish(ev.uid, h.result() if h else None)

    threads: list[threading.Thread] = []
    stop = threading.Event()
    t0 = time.time()
    desired, _ = scaler.observe()
    while len(done) < args.requests and time.time() - t0 < 600:
        desired, changed = scaler.observe()
        while len([t for t in threads if t.is_alive()]) < desired:
            wid = len(threads)
            t = threading.Thread(target=worker, args=(wid, stop), daemon=True)
            t.start()
            threads.append(t)
        time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join(timeout=5)

    wall = time.time() - t0
    print(f"served {len(done)}/{args.requests} requests in {wall:.1f}s "
          f"({len(done)*args.max_new/wall:.1f} tok/s), "
          f"engine={'paged' if use_paged else 'ssm' if use_ssm else 'lockstep'}, "
          f"admission={args.admission}, mesh={mesh_desc}, "
          f"peak workers={len(threads)}")
    summary = format_latency(latencies)
    if summary != "no_latency_data":
        print(summary)
    print("utilization:", utilization.format())
    autoscales = events.history("autoscale")
    print("autoscale events:", [(e["old"], e["new"]) for e in autoscales])
    assert len(done) == args.requests

    # streaming invariant: every served request's first delta is observable
    # on the bus BEFORE its terminal finish message
    first_delta: dict[str, int] = {}
    finish_at: dict[str, int] = {}
    for m in bus.read("responses"):
        uid, event = m.value["uid"], m.value["event"]
        if event == "delta":
            first_delta.setdefault(uid, m.offset)
        elif event == "finish":
            finish_at[uid] = m.offset
    streamed = [u for u, toks in done.items() if toks]
    assert all(first_delta[u] < finish_at[u] for u in streamed), \
        "deltas must precede completion on the bus"
    print(f"streaming: {sum(len(t) for t in done.values())} deltas published "
          f"before {len(finish_at)} completions")
    return 0


def _run_fleet(args, bus, events, make_engine) -> int:
    """Supervised-fleet driver: FleetSupervisor + N engine workers with
    probes, crash-replay recovery and autoscaling (``serving/fleet.py``)."""
    from repro.serving.fleet import FleetConfig, FleetSupervisor

    fcfg = FleetConfig(
        workers=args.fleet,
        min_replicas=1,
        max_replicas=max(args.fleet, 4),
        target_lag_per_replica=args.max_batch * 2,
    )
    sup = FleetSupervisor(bus, make_engine, fcfg, events=events)
    expected = [f"r{i}" for i in range(args.requests)]
    t0 = time.time()
    ok = sup.run(expected=expected, timeout_s=600)
    wall = time.time() - t0
    sup.shutdown()
    states = sup.results()
    n_tokens = sum(len(s.tokens) for s in states.values())
    print(f"fleet served {len(states)}/{args.requests} requests in "
          f"{wall:.1f}s ({n_tokens / wall:.1f} tok/s), "
          f"workers={args.fleet}+auto, "
          f"supervision: {sup.metrics.format()}")
    autoscales = events.history("autoscale")
    print("autoscale events:", [(e["old"], e["new"]) for e in autoscales])
    assert ok, "fleet run timed out with requests still in flight"

    # same streaming invariant as the flat pool: every streamed request's
    # first delta precedes its terminal finish on the responses topic
    first_delta: dict[str, int] = {}
    finish_at: dict[str, int] = {}
    for m in bus.read("responses"):
        uid, event = m.value["uid"], m.value["event"]
        if event == "delta":
            first_delta.setdefault(uid, m.offset)
        elif event == "finish":
            finish_at[uid] = m.offset
    streamed = [u for u, s in states.items() if s.tokens]
    assert all(first_delta[u] < finish_at[u] for u in streamed), \
        "deltas must precede completion on the bus"
    print(f"streaming: {n_tokens} deltas published before "
          f"{len(finish_at)} completions")
    return 0


def _run_worker(args, bus, make_engine) -> int:
    """Standalone fleet worker: the multi-process deployment shape. A
    supervisor in another process (same ``--workdir`` bus) publishes
    ``fleet.work``; this process serves it until the topic drains."""
    from repro.serving.fleet import (
        EngineWorker,
        FleetConfig,
        WORK_TOPIC,
        WORKER_GROUP,
    )

    w = EngineWorker(args.worker_name, 0, bus, make_engine,
                     threading.Lock(), FleetConfig(workers=1, autoscale=False))
    w.start()
    idle_since = None
    while w.thread.is_alive():
        busy = bus.lag(WORK_TOPIC, WORKER_GROUP) > 0 or w.inflight
        idle_since = None if busy else (idle_since or time.time())
        if idle_since is not None and time.time() - idle_since > 2.0:
            w.retire()
            break
        time.sleep(0.05)
    w.thread.join(timeout=30)
    print(f"worker {w.pod_id}: steps={w.steps_run} "
          f"tokens={w.tokens_emitted} clean={w.stopped_cleanly}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
