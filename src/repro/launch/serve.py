"""Serving driver: continuous-batching generation behind a bus topic.

Requests land on the ``requests`` topic (Kafka analogue); engine workers
admit them straight into in-flight decode slots (paged KV cache, one static
decode shape — see ``serving/engine.py``) and publish to ``responses``.
Prompts prefill in fixed-size chunks interleaved with decode
(``--prefill-chunk``, 0 restores whole-prompt prefill) and identical prompt
prefixes are served from shared copy-on-write pages (``--no-prefix-sharing``
to disable; ``--shared-prefix N`` synthesizes the pipeline-rerun workload
that exercises it). The run prints p50/p90/p99 time-to-first-token and
inter-token latency. The HPA analogue watches consumer lag and scales
workers in [min,max]. The old lockstep micro-batcher stays available via
``--engine lockstep`` (and is the fallback for families without a paged
decode path). CPU-runnable with reduced configs:

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --requests 24 --shared-prefix 32
"""

from __future__ import annotations

import argparse
import threading
import time
from pathlib import Path

import jax


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="lockstep micro-batch size / paged slot count")
    ap.add_argument("--engine", choices=["paged", "lockstep"], default="paged")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="paged engine: prefill chunk size; 0 restores the "
                         "whole-prompt bucketed prefill")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="paged engine: disable COW prefix-page sharing")
    ap.add_argument("--shared-prefix", type=int, default=0, metavar="N",
                    help="prepend a common N-token prefix to every request "
                         "(pipeline-rerun workload; exercises prefix sharing)")
    ap.add_argument("--workdir", default="experiments/serve_run")
    args = ap.parse_args()

    from repro.configs import get_arch, reduced
    from repro.core import TopicBus
    from repro.core.autoscaler import Autoscaler, AutoscalerConfig
    from repro.core.bus import Consumer
    from repro.core.events import EventLog
    from repro.core.registry import ServiceRegistry
    from repro.models import build_model
    from repro.serving import ContinuousBatchingEngine, GenerationEngine
    from repro.serving.engine import Request

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    paged_ok = not cfg.is_encoder_decoder and cfg.family in ("dense", "moe", "vlm")
    use_paged = args.engine == "paged" and paged_ok
    workdir = Path(args.workdir)
    workdir.mkdir(parents=True, exist_ok=True)
    bus = TopicBus(workdir / "bus")
    events = EventLog(bus, workflow=f"serve-{cfg.name}")
    registry = ServiceRegistry(bus)

    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    shared = list(range(2, 2 + args.shared_prefix))
    max_len = 64 + args.shared_prefix + args.max_new

    # ---- producer: enqueue requests ----
    for i in range(args.requests):
        bus.publish(
            "requests",
            {"uid": f"r{i}",
             "prompt": shared + [1 + (i % 30), 2, 3 + (i % 7)],
             "max_new_tokens": args.max_new},
        )

    group = "servers"
    scaler = Autoscaler(
        bus, "requests", group,
        AutoscalerConfig(min_replicas=1, max_replicas=4,
                         target_lag_per_replica=args.max_batch * 2),
        events=events,
    )
    done: dict[str, list[int]] = {}
    latencies: list = []  # Result objects, for TTFT/ITL percentiles
    lock = threading.Lock()

    def publish(results):
        for r in results:
            bus.publish("responses", {"uid": r.uid, "tokens": r.tokens})
            with lock:
                done[r.uid] = r.tokens
                latencies.append(r)

    def paged_worker(wid: int, stop: threading.Event):
        engine = ContinuousBatchingEngine(
            cfg, params, max_len=max_len, max_slots=max(args.max_batch, 2),
            prefill_chunk=args.prefill_chunk or None,
            prefix_sharing=not args.no_prefix_sharing,
        )
        registry.register("generate", f"pod://server-{wid}", f"server-{wid}")
        while not stop.is_set():
            # admit straight from the bus into free decode slots
            n = engine.admit_from_bus(
                bus, "requests", group, max_msgs=engine.cache.free_slot_count
            )
            for uid, err in engine.drain_rejections():
                bus.publish("responses", {"uid": uid, "error": err, "tokens": []})
                with lock:
                    done[uid] = []
            if engine.idle:
                if not n and bus.lag("requests", group) == 0:
                    return
                time.sleep(0.01)
                continue
            publish(engine.step())

    def lockstep_worker(wid: int, stop: threading.Event):
        engine = GenerationEngine(cfg, params, max_len=max_len)
        registry.register("generate", f"pod://server-{wid}", f"server-{wid}")
        consumer = Consumer(bus, "requests", group)
        while not stop.is_set():
            batch: list[Request] = []

            def collect(msg):
                v = msg.value
                batch.append(Request(v["uid"], list(v["prompt"]), v["max_new_tokens"]))

            n = consumer.poll(collect, max_msgs=args.max_batch)
            if not n:
                if bus.lag("requests", group) == 0:
                    return
                time.sleep(0.01)
                continue
            publish(engine.generate(batch))

    worker = paged_worker if use_paged else lockstep_worker

    threads: list[threading.Thread] = []
    stop = threading.Event()
    t0 = time.time()
    desired, _ = scaler.observe()
    while len(done) < args.requests and time.time() - t0 < 600:
        desired, changed = scaler.observe()
        while len([t for t in threads if t.is_alive()]) < desired:
            wid = len(threads)
            t = threading.Thread(target=worker, args=(wid, stop), daemon=True)
            t.start()
            threads.append(t)
        time.sleep(0.05)
    stop.set()
    for t in threads:
        t.join(timeout=5)

    wall = time.time() - t0
    print(f"served {len(done)}/{args.requests} requests in {wall:.1f}s "
          f"({len(done)*args.max_new/wall:.1f} tok/s), "
          f"engine={'paged' if use_paged else 'lockstep'}, "
          f"peak workers={len(threads)}")
    from repro.serving import format_latency

    summary = format_latency(latencies)
    if summary != "no_latency_data":  # paged engine records per-request latency
        print(summary)
    autoscales = events.history("autoscale")
    print("autoscale events:", [(e["old"], e["new"]) for e in autoscales])
    assert len(done) == args.requests
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
