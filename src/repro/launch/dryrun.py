import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count at first
init, and the production meshes need 512 placeholder host devices.

Per cell this prints ``compiled.memory_analysis()`` (fits-in-HBM proof) and
``compiled.cost_analysis()`` (XLA's FLOPs/bytes), runs the trip-count-
corrected HLO analyzer, derives the three roofline terms, and writes one
JSON record under ``experiments/dryrun/``. ``--all`` sweeps the full 40-cell
grid on both meshes (skips recorded explicitly).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--quick]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax


def parse_rules(spec: str | None) -> dict:
    """--rules "embed=none,vocab=model" -> {"embed": None, "vocab": "model"}."""
    if not spec:
        return {}
    out = {}
    for part in spec.split(","):
        k, _, v = part.partition("=")
        if v in ("none", "None", ""):
            out[k] = None
        elif "+" in v:
            out[k] = tuple(v.split("+"))
        else:
            out[k] = v
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             ga: int | None = None, rules_patch: dict | None = None,
             tag: str = "", pad_heads: str | None = None,
             remat: str | None = None) -> dict:
    from repro.analysis.hlo import analyze_hlo
    from repro.analysis.roofline import HW, model_flops_per_chip, roofline_terms
    from repro.configs import get_arch, get_shape, cell_supported
    from repro.launch.builders import lower_cell
    from repro.launch.mesh import describe_mesh, make_production_mesh
    from repro.parallel import DEFAULT_RULES

    import dataclasses

    cfg = get_arch(arch)
    if pad_heads:
        hq, _, hkv = pad_heads.partition(",")
        cfg = dataclasses.replace(cfg, num_heads_padded=int(hq),
                                  num_kv_heads_padded=int(hkv or 0))
    if remat:
        cfg = dataclasses.replace(cfg, remat_policy=remat)
    shape = get_shape(shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag}

    ok, reason = cell_supported(cfg, shape)
    if not ok:
        rec["status"] = "skip"
        rec["reason"] = reason
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: {reason}")
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        (out_dir / f"{arch}_{shape_name}_{mesh_name}{suffix}.json").write_text(
            json.dumps(rec, indent=1))
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    rules = dict(DEFAULT_RULES)
    if rules_patch:
        rules.update(rules_patch)
    try:
        t0 = time.time()
        plan = lower_cell(cfg, shape, mesh, rules=rules, ga=ga)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = plan.lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s)")
        print(f"  memory_analysis: {ma}")
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}  (while bodies counted once)")

        hlo_text = compiled.as_text()
        cost = analyze_hlo(hlo_text)
        mf = model_flops_per_chip(cfg, shape, n_chips)
        terms = roofline_terms(cost, HW(), model_flops_per_chip=mf)

        arg_b = ma.argument_size_in_bytes
        tmp_b = ma.temp_size_in_bytes
        out_b = ma.output_size_in_bytes
        alias_b = ma.alias_size_in_bytes
        hbm_need = arg_b + tmp_b + out_b - alias_b
        fits = hbm_need <= HW().hbm_per_chip
        print(f"  per-chip bytes: args={arg_b/2**30:.2f}GiB temp={tmp_b/2**30:.2f}GiB "
              f"out={out_b/2**30:.2f}GiB alias={alias_b/2**30:.2f}GiB "
              f"-> need {hbm_need/2**30:.2f}GiB / 16GiB {'OK' if fits else 'OVER'}")
        print(f"  roofline: compute={terms.compute_s*1e3:.2f}ms "
              f"memory={terms.memory_s*1e3:.2f}ms (xla-fallback {terms.memory_xla_s*1e3:.2f}ms) "
              f"collective={terms.collective_s*1e3:.2f}ms "
              f"dominant={terms.dominant} useful={terms.useful_flops_ratio:.2f}")

        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": arg_b,
                "output_bytes": out_b,
                "temp_bytes": tmp_b,
                "alias_bytes": alias_b,
                "hbm_needed_bytes": hbm_need,
                "fits_16gib": bool(fits),
            },
            xla_cost={
                "flops_body_once": ca.get("flops", 0.0),
                "bytes_body_once": ca.get("bytes accessed", 0.0),
            },
            analyzer={
                "flops": cost.flops,
                "hbm_bytes": cost.hbm_bytes,
                "collective_bytes": cost.collective_bytes,
                "collective_count": cost.collective_count,
                "while_trips": cost.while_trips,
            },
            roofline=terms.as_row(),
            meta=plan.meta,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["trace"] = traceback.format_exc()[-3000:]
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name} FAILED: {rec['error']}")

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fp = out_dir / f"{arch}_{shape_name}_{mesh_name}{suffix}.json"
    fp.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--ga", type=int, default=None)
    ap.add_argument("--rules", default=None,
                    help='rule patches, e.g. "embed=none" (drop FSDP)')
    ap.add_argument("--pad-heads", default=None,
                    help='pad head counts, e.g. "48,12" (q,kv)')
    ap.add_argument("--remat", default=None,
                    help='override remat policy, e.g. "group8"')
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    from repro.configs import ARCHS, SHAPES  # after XLA_FLAGS

    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    n_bad = 0
    t0 = time.time()
    for arch, shape in cells:
        for multi in meshes:
            rec = run_cell(arch, shape, multi, out_dir, ga=args.ga, tag=args.tag,
                           rules_patch=parse_rules(args.rules),
                           pad_heads=args.pad_heads, remat=args.remat)
            if rec["status"] == "error":
                n_bad += 1
    print(f"[dryrun] done in {time.time()-t0:.0f}s, {n_bad} failures")
    return 1 if n_bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
