"""Cell builders: (architecture x shape x mesh) -> lowered step function.

One entry point, ``lower_cell``, shared by the dry-run, the trainer and the
perf harness, so what we analyze is exactly what we'd run.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, cell_supported
from repro.models import build_model
from repro.models.api import BATCH_AXES, cache_len, input_specs
from repro.parallel import DEFAULT_RULES, make_shardings, sharding_context
from repro.train.optimizer import AdamWConfig
from repro.train.step import abstract_train_state, make_train_step, train_state_axes


@dataclass
class CellPlan:
    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    kind: str
    lowered: Any
    meta: dict


def default_ga(shape: ShapeConfig, cfg: ModelConfig | None = None) -> int:
    """Microbatch count heuristic: large-d models need smaller microbatches
    (activation bytes/token scale with d_model); floor at 16 sequences."""
    if shape.kind != "train":
        return 1
    per_micro = 16 if (cfg is not None and cfg.d_model >= 6144) else 32
    return max(1, min(16, shape.global_batch // per_micro))


def batch_shardings(specs: dict, mesh: Mesh, rules=None) -> dict:
    axes = {k: BATCH_AXES[k] for k in specs}
    return make_shardings(axes, mesh, rules=rules, shapes_tree=specs)


def lower_cell(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: Mesh,
    *,
    rules: dict | None = None,
    ga: int | None = None,
    opt_cfg: AdamWConfig | None = None,
    attn_impl: str = "xla_chunked",
    ssd_impl: str = "xla_chunked",
) -> CellPlan:
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape.name}: {reason}")
    rules = dict(rules or DEFAULT_RULES)
    opt_cfg = opt_cfg or AdamWConfig(moment_dtype="bfloat16")
    ga = ga if ga is not None else default_ga(shape, cfg)
    # the microbatch must not drop below the data-parallel width, or batch
    # sharding silently degrades (divisibility fallback) and per-chip
    # activations blow up by the lost factor
    batch_rule = rules.get("batch") or ()
    batch_axes = batch_rule if isinstance(batch_rule, tuple) else (batch_rule,)
    dp = 1
    for a in batch_axes:
        dp *= dict(zip(mesh.axis_names, mesh.shape.values())).get(a, 1)
    if shape.kind == "train":
        ga = max(1, min(ga, shape.global_batch // max(dp, 1)))
    model = build_model(cfg, attn_impl=attn_impl, ssd_impl=ssd_impl)
    meta: dict = {"ga": ga, "rules": {k: str(v) for k, v in rules.items()}}

    with sharding_context(mesh, rules):
        if shape.kind == "train":
            state = abstract_train_state(model, opt_cfg)
            state_sh = make_shardings(
                train_state_axes(model), mesh, rules=rules, shapes_tree=state
            )
            bspecs = input_specs(cfg, shape)
            bsh = batch_shardings(bspecs, mesh, rules)
            # bf16 accumulation: halves the grad buffer AND the cross-pod
            # gradient all-reduce bytes (wire compression); update math is
            # still fp32 inside the optimizer
            step = make_train_step(model, opt_cfg, ga=ga, accum_dtype="bfloat16")
            fn = jax.jit(
                step,
                in_shardings=(state_sh, bsh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = fn.lower(state, bspecs)
            meta["state_bytes_global"] = sum(
                v.size * v.dtype.itemsize for v in jax.tree.leaves(state)
            )

        elif shape.kind == "prefill":
            params = model.abstract()
            params_sh = make_shardings(model.axes(), mesh, rules=rules, shapes_tree=params)
            bspecs = input_specs(cfg, shape)
            bsh = batch_shardings(bspecs, mesh, rules)
            clen = cache_len(cfg, shape)
            cache_sh = make_shardings(
                model.cache_axes(), mesh, rules=rules,
                shapes_tree=model.abstract_cache(shape.global_batch, clen),
            )
            fn = jax.jit(
                lambda p, b: model.prefill(p, b, clen),
                in_shardings=(params_sh, bsh),
                out_shardings=(cache_sh, None),
            )
            lowered = fn.lower(params, bspecs)

        else:  # decode
            params = model.abstract()
            params_sh = make_shardings(model.axes(), mesh, rules=rules, shapes_tree=params)
            specs = input_specs(cfg, shape)
            cache = specs["cache"]
            tokens = specs["tokens"]
            cache_sh = make_shardings(
                model.cache_axes(), mesh, rules=rules, shapes_tree=cache
            )
            tok_sh = make_shardings(
                {"tokens": BATCH_AXES["tokens"]}, mesh, rules=rules,
                shapes_tree={"tokens": tokens},
            )["tokens"]
            fn = jax.jit(
                model.decode_step,
                in_shardings=(params_sh, cache_sh, tok_sh),
                out_shardings=(cache_sh, None),
                donate_argnums=(1,),
            )
            lowered = fn.lower(params, cache, tokens)
            meta["cache_bytes_global"] = sum(
                v.size * v.dtype.itemsize for v in jax.tree.leaves(cache)
            )

    return CellPlan(cfg=cfg, shape=shape, mesh=mesh, kind=shape.kind,
                    lowered=lowered, meta=meta)
