"""Production meshes.

Functions, not module-level constants — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 has explicit axis types; older releases default to Auto
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist locally (tests / examples): 1D data mesh."""
    n = jax.device_count()
    return _make_mesh((n,), ("data",))


def make_serving_mesh(size: int | None = None) -> Mesh:
    """1D ``("model",)`` mesh for the tensor-parallel serving executor.

    ``size`` caps/chooses the device count (None = all local devices).
    Built over the FIRST ``size`` devices with a plain :class:`Mesh` —
    unlike ``jax.make_mesh`` this permits a strict subset of the host's
    devices, which the executor needs when the model's head count only
    divides over part of a forced multi-device CPU host.
    """
    import numpy as np

    devices = jax.devices()
    n = len(devices) if size is None else size
    if not 1 <= n <= len(devices):
        raise ValueError(
            f"serving mesh size {n} out of range [1, {len(devices)}]"
        )
    return Mesh(np.asarray(devices[:n]), ("model",))


def describe_mesh(mesh: Mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())
