"""Unified decoder-only LM covering dense / moe / vlm / ssm / hybrid families.

One class, five lowered entry points:
  * ``loss_fn(params, batch)``       — training forward + chunked CE loss
  * ``prefill(params, batch, max_len)`` — full-seq forward, returns KV/SSM cache
  * ``decode_step(params, cache, tokens)`` — one token with cache update
  * ``decode_step_paged(params, pages, ...)`` — one token per serving slot
    against the shared paged KV pool (continuous batching)
  * ``prefill_chunk(params, pages, ...)`` — one fixed-size prompt chunk of
    one sequence scattered into its page set (chunked prefill)

The layer stack is a ``lax.scan`` over stacked per-layer params (compile time
O(1) in depth) with configurable ``jax.checkpoint`` policy. Vocab is padded to
a multiple of 256 for clean TP sharding (padded logits are masked to -inf in
the loss — exact math, standard Megatron practice).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ParamSpec,
    abstract_params,
    init_params,
    param_axes,
    rms_norm,
    swiglu,
)
from repro.parallel import constrain
from repro.parallel.collectives import all_gather_logits

VOCAB_PAD_MULTIPLE = 256


def mlp_param_specs(cfg: ModelConfig, stacked: int | None = None) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    pre = (stacked,) if stacked else ()
    pax = ("stack",) if stacked else ()
    return {
        "w_gate": ParamSpec(pre + (d, f), pax + ("embed", "ff")),
        "w_up": ParamSpec(pre + (d, f), pax + ("embed", "ff")),
        "w_down": ParamSpec(pre + (f, d), pax + ("ff", "embed")),
    }


def padded_vocab(cfg: ModelConfig) -> int:
    v = cfg.vocab_size
    return ((v + VOCAB_PAD_MULTIPLE - 1) // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


def chunked_cross_entropy(
    x: jax.Array,        # (B, S, D) final hidden states
    w_out: jax.Array,    # (D, Vp)
    targets: jax.Array,  # (B, S) int32
    real_vocab: int,
    chunk: int = 1024,
) -> jax.Array:
    """Mean next-token CE without materializing (B,S,V) logits.

    Scans sequence chunks; each chunk is wrapped in jax.checkpoint so the
    backward pass recomputes its logits instead of saving them.
    """
    b, s, d = x.shape
    vp = w_out.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)

    vocab_mask = (jnp.arange(vp) < real_vocab) if real_vocab != vp else None

    @jax.checkpoint
    def one(x_chunk, t_chunk):
        logits = jnp.einsum(
            "bsd,dv->bsv", x_chunk, w_out, preferred_element_type=jnp.float32
        )
        logits = constrain(logits, "batch", "seq", "vocab")
        if vocab_mask is not None:
            logits = jnp.where(vocab_mask[None, None, :], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, t_chunk[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - tgt)

    def body(tot, inp):
        x_chunk, t_chunk = inp
        return tot + one(x_chunk, t_chunk), ()

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, tc))
    return total / (b * s)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "names":
        # Megatron-style: save the post-all-reduce block outputs (tagged
        # with checkpoint_name below) so the backward recompute never
        # re-runs the TP collectives — trades 2 saved (B,S,D) tensors per
        # layer for ~1/3 of the activation all-reduce bytes
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out"
            ),
        )
    if policy == "nothing" or policy.startswith("group"):
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(f"unknown remat policy {policy!r}")


def scan_layers(body, carry, layers, policy: str):
    """Scan a layer stack with the configured checkpointing strategy.

    * "nothing"/"dots"/"none": plain scan of a (possibly remat'd) body —
      the scan still saves the carry at EVERY layer (L x microbatch bytes).
    * "groupG" (e.g. "group8"): sqrt-L checkpointing — outer scan over
      blocks of G layers, each block remat'd as a unit, so only L/G carries
      are saved and the block recomputes its layers in backward. Trades
      ~1 extra forward of the block for a G-fold cut in saved activations.
    """
    if policy.startswith("group"):
        spec = policy[len("group"):]
        inner_policy = "names" if spec.endswith("names") else "nothing"
        spec = spec.removesuffix("names")
        g = int(spec or 8)
        first = jax.tree.leaves(layers)[0]
        n_layers = first.shape[0]
        if n_layers % g != 0:
            g = next(d for d in range(g, 0, -1) if n_layers % d == 0)
        ng = n_layers // g
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, g) + a.shape[1:]), layers
        )
        # two-level checkpointing: the outer scan saves only group-boundary
        # carries; each layer inside is ALSO remat'd so the group backward
        # holds one layer's internals at a time, not the whole group's
        inner = _remat(body, inner_policy)

        def group_body(c, pg):
            c2, _ = jax.lax.scan(inner, c, pg)
            return c2, ()

        return jax.lax.scan(_remat(group_body, "nothing"), carry, grouped)
    return jax.lax.scan(_remat(body, policy), carry, layers)


class DecoderLM:
    """Families: dense, moe, vlm, ssm, hybrid."""

    def __init__(
        self,
        cfg: ModelConfig,
        *,
        attn_impl: str = "xla_chunked",
        ssd_impl: str = "xla_chunked",
    ):
        assert not cfg.is_encoder_decoder
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.ssd_impl = ssd_impl

    # ------------------------------------------------------------------
    # params
    # ------------------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        L, D = cfg.num_layers, cfg.d_model
        vp = padded_vocab(cfg)
        specs: dict[str, Any] = {
            "embed": ParamSpec((vp, D), ("vocab", None), init="embed", scale=0.02),
            "final_norm": ParamSpec((D,), (None,), init="ones"),
        }
        if not cfg.tie_embeddings:
            specs["unembed"] = ParamSpec((D, vp), (None, "vocab"))
        if cfg.family == "vlm":
            specs["vision_proj"] = ParamSpec((D, D), ("embed", None))

        if cfg.family == "ssm":
            specs["layers"] = {
                "ln": ParamSpec((L, D), ("stack", None), init="ones"),
                "mamba": ssm_mod.mamba_param_specs(cfg, stacked=L),
            }
        elif cfg.family == "hybrid":
            specs["layers"] = {
                "ln": ParamSpec((L, D), ("stack", None), init="ones"),
                "mamba": ssm_mod.mamba_param_specs(cfg, stacked=L),
            }
            specs["shared"] = {
                "ln1": ParamSpec((D,), (None,), init="ones"),
                "attn": attn.attn_param_specs(cfg),
                "ln2": ParamSpec((D,), (None,), init="ones"),
                "mlp": mlp_param_specs(cfg),
            }
        else:
            layer: dict[str, Any] = {
                "ln1": ParamSpec((L, D), ("stack", None), init="ones"),
                "attn": attn.attn_param_specs(cfg, stacked=L),
                "ln2": ParamSpec((L, D), ("stack", None), init="ones"),
            }
            if cfg.family == "moe":
                layer["moe"] = moe_mod.moe_param_specs(cfg, stacked=L)
            else:
                layer["mlp"] = mlp_param_specs(cfg, stacked=L)
            specs["layers"] = layer
        return specs

    def init(self, key):
        return init_params(self.param_specs(), key, self.cfg.dtype)

    def abstract(self):
        return abstract_params(self.param_specs(), self.cfg.dtype)

    def axes(self):
        return param_axes(self.param_specs())

    def _unembed_weight(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["unembed"]

    # ------------------------------------------------------------------
    # embedding / inputs
    # ------------------------------------------------------------------
    def embed_inputs(self, params, batch) -> jax.Array:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        if self.cfg.family == "vlm":
            vis = batch["vision_embeds"].astype(x.dtype)
            vis = jnp.einsum("bfd,de->bfe", vis, params["vision_proj"])
            x = jnp.concatenate([vis, x], axis=1)
        return constrain(x, "batch", "seq", None)

    # ------------------------------------------------------------------
    # layer stacks (train mode)
    # ------------------------------------------------------------------
    def _dense_layer(self, pl, x, aux, positions):
        cfg = self.cfg
        h = rms_norm(x, pl["ln1"], cfg.norm_eps)
        h = attn.self_attention(
            pl["attn"], h, cfg, positions=positions, attn_impl=self.attn_impl
        )
        h = checkpoint_name(h, "attn_out")  # post-AR (see _remat "names")
        x = x + h
        h = rms_norm(x, pl["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            h, a = moe_mod.moe_block(pl["moe"], h, cfg)
            aux = aux + a
        else:
            h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"],
                       constrain=lambda t, *ax: constrain(t, *ax))
        h = checkpoint_name(h, "mlp_out")  # post-AR
        x = constrain(x + h, "batch", "seq", None)
        return x, aux

    def _shared_attn_block(self, shared, x, positions):
        cfg = self.cfg
        h = rms_norm(x, shared["ln1"], cfg.norm_eps)
        h = attn.self_attention(
            shared["attn"], h, cfg, positions=positions, attn_impl=self.attn_impl
        )
        x = x + h
        h = rms_norm(x, shared["ln2"], cfg.norm_eps)
        h = swiglu(h, shared["mlp"]["w_gate"], shared["mlp"]["w_up"],
                   shared["mlp"]["w_down"],
                   constrain=lambda t, *ax: constrain(t, *ax))
        return x + h

    def backbone_train(self, params, x) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        positions = jnp.arange(x.shape[1])
        aux0 = jnp.zeros((), jnp.float32)
        policy = cfg.remat_policy

        if cfg.family in ("dense", "moe", "vlm"):
            def body(carry, pl):
                x, aux = carry
                x, aux = self._dense_layer(pl, x, aux, positions)
                return (x, aux), ()
            (x, aux), _ = scan_layers(body, (x, aux0), params["layers"], policy)
            return x, aux

        if cfg.family == "ssm":
            def body(carry, pl):
                x, aux = carry
                h = rms_norm(x, pl["ln"], cfg.norm_eps)
                h = ssm_mod.mamba_block(pl["mamba"], h, cfg, ssd_impl=self.ssd_impl)
                x = constrain(x + h, "batch", "seq", None)
                return (x, aux), ()
            (x, aux), _ = scan_layers(body, (x, aux0), params["layers"], policy)
            return x, aux

        if cfg.family == "hybrid":
            g = cfg.num_layers // cfg.attn_every
            grouped = jax.tree.map(
                lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]), params["layers"]
            )
            shared = params["shared"]

            def group_body(carry, pg):
                x, aux = carry
                x = self._shared_attn_block(shared, x, positions)

                def mbody(xc, pl):
                    h = rms_norm(xc, pl["ln"], cfg.norm_eps)
                    h = ssm_mod.mamba_block(pl["mamba"], h, cfg, ssd_impl=self.ssd_impl)
                    return constrain(xc + h, "batch", "seq", None), ()

                x, _ = jax.lax.scan(mbody, x, pg)
                return (x, aux), ()

            (x, aux), _ = jax.lax.scan(_remat(group_body, policy), (x, aux0), grouped)
            return x, aux

        raise ValueError(cfg.family)

    # ------------------------------------------------------------------
    # training loss
    # ------------------------------------------------------------------
    def loss_fn(self, params, batch) -> tuple[jax.Array, dict]:
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        x, aux = self.backbone_train(params, x)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cfg.family == "vlm":
            x = x[:, batch["vision_embeds"].shape[1]:, :]
        ce = chunked_cross_entropy(
            x, self._unembed_weight(params), batch["targets"], cfg.vocab_size
        )
        loss = ce + (0.01 * aux if cfg.family == "moe" else 0.0)
        return loss, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------------
    # cache
    # ------------------------------------------------------------------
    def cache_struct(self, batch: int, max_len: int, abstract: bool):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        L = cfg.num_layers

        def arr(shape, dtype):
            return (
                jax.ShapeDtypeStruct(shape, dtype)
                if abstract
                else jnp.zeros(shape, dtype)
            )

        pos = arr((), jnp.int32)
        if cfg.family in ("dense", "moe", "vlm"):
            kv = (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
            return {"k": arr(kv, dt), "v": arr(kv, dt), "pos": pos}
        if cfg.family == "ssm":
            mc = ssm_mod.init_mamba_cache(cfg, batch, dt, abstract=True)
            stacked = {
                k: arr((L,) + tuple(v.shape), v.dtype) for k, v in mc.items()
            }
            return {"mamba": stacked, "pos": pos}
        if cfg.family == "hybrid":
            g = cfg.num_layers // cfg.attn_every
            mc = ssm_mod.init_mamba_cache(cfg, batch, dt, abstract=True)
            stacked = {
                k: arr((L,) + tuple(v.shape), v.dtype) for k, v in mc.items()
            }
            kv = (g, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
            return {
                "mamba": stacked,
                "shared_k": arr(kv, dt),
                "shared_v": arr(kv, dt),
                "pos": pos,
            }
        raise ValueError(cfg.family)

    def init_cache(self, batch: int, max_len: int):
        return self.cache_struct(batch, max_len, abstract=False)

    def abstract_cache(self, batch: int, max_len: int):
        return self.cache_struct(batch, max_len, abstract=True)

    def cache_axes(self):
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            kv = ("stack", "cache_batch", "cache_seq", "kv_heads", "head_dim")
            return {"k": kv, "v": kv, "pos": None}
        mam = {
            k: ("stack",) + tuple(v) for k, v in ssm_mod.MAMBA_CACHE_AXES.items()
        }
        if cfg.family == "ssm":
            return {"mamba": mam, "pos": None}
        kv = ("stack", "cache_batch", "cache_seq", "kv_heads", "head_dim")
        return {"mamba": mam, "shared_k": kv, "shared_v": kv, "pos": None}

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    def prefill(self, params, batch, max_len: int, *, logits_index=None):
        """Full-sequence forward; returns (cache, one position's logits).

        ``logits_index`` (traced scalar ok) selects which position's logits to
        return — the continuous batcher right-pads prompts to a shape bucket,
        so the last *real* token is not the last padded position. Default:
        the final position (lockstep behaviour).
        """
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = jnp.arange(s)
        pad = max_len - s

        def pad_kv(k):
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            # shard into the cache layout INSIDE the layer scan — otherwise
            # the stacked (L, B, Smax, KVH, Dh) output materializes with
            # batch-only sharding before the final reshard (GiBs per chip)
            return constrain(k, "cache_batch", "cache_seq", "kv_heads", "head_dim")

        if cfg.family in ("dense", "moe", "vlm"):
            def body(carry, pl):
                x, aux = carry
                h = rms_norm(x, pl["ln1"], cfg.norm_eps)
                h, (k, v) = attn.self_attention_with_cache_write(
                    pl["attn"], h, cfg, positions=positions, attn_impl=self.attn_impl
                )
                x = x + h
                h = rms_norm(x, pl["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    h, a = moe_mod.moe_block(pl["moe"], h, cfg)
                    aux = aux + a
                else:
                    h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"],
                               pl["mlp"]["w_down"])
                x = constrain(x + h, "batch", "seq", None)
                return (x, aux), {"k": pad_kv(k), "v": pad_kv(v)}

            (x, _), kv = jax.lax.scan(
                body, (x, jnp.zeros((), jnp.float32)), params["layers"]
            )
            cache = {"k": kv["k"], "v": kv["v"], "pos": jnp.asarray(s, jnp.int32)}

        elif cfg.family == "ssm":
            def body(x, pl):
                h = rms_norm(x, pl["ln"], cfg.norm_eps)
                h, mc = ssm_mod.mamba_block(
                    pl["mamba"], h, cfg, ssd_impl=self.ssd_impl, return_cache=True
                )
                return constrain(x + h, "batch", "seq", None), mc

            x, mam = jax.lax.scan(body, x, params["layers"])
            cache = {"mamba": mam, "pos": jnp.asarray(s, jnp.int32)}

        elif cfg.family == "hybrid":
            g = cfg.num_layers // cfg.attn_every
            grouped = jax.tree.map(
                lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]),
                params["layers"],
            )
            shared = params["shared"]

            def group_body(x, pg):
                h = rms_norm(x, shared["ln1"], cfg.norm_eps)
                h, (k, v) = attn.self_attention_with_cache_write(
                    shared["attn"], h, cfg, positions=positions,
                    attn_impl=self.attn_impl,
                )
                x = x + h
                h = rms_norm(x, shared["ln2"], cfg.norm_eps)
                h = swiglu(h, shared["mlp"]["w_gate"], shared["mlp"]["w_up"],
                           shared["mlp"]["w_down"])
                x = x + h

                def mbody(xc, pl):
                    hh = rms_norm(xc, pl["ln"], cfg.norm_eps)
                    hh, mc = ssm_mod.mamba_block(
                        pl["mamba"], hh, cfg, ssd_impl=self.ssd_impl,
                        return_cache=True,
                    )
                    return constrain(xc + hh, "batch", "seq", None), mc

                x, mcs = jax.lax.scan(mbody, x, pg)
                return x, {"kv": {"k": pad_kv(k), "v": pad_kv(v)}, "mamba": mcs}

            x, ys = jax.lax.scan(group_body, x, grouped)
            mam = jax.tree.map(
                lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), ys["mamba"]
            )
            cache = {
                "mamba": mam,
                "shared_k": ys["kv"]["k"],
                "shared_v": ys["kv"]["v"],
                "pos": jnp.asarray(s, jnp.int32),
            }
        else:
            raise ValueError(cfg.family)

        if logits_index is None:
            x = x[:, -1:, :]
        else:
            x = jax.lax.dynamic_slice_in_dim(x, logits_index, 1, axis=1)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[:, 0]
        return cache, logits

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def decode_step(self, params, cache, tokens):
        """tokens (B, 1) -> (new_cache, logits (B, Vp) f32)."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)  # (B,1,D)
        pos = cache["pos"]

        if cfg.family in ("dense", "moe", "vlm"):
            def body(x, inp):
                pl, cl = inp
                h = rms_norm(x, pl["ln1"], cfg.norm_eps)
                h, new_cl = attn.decode_self_attention(pl["attn"], h, cl, pos, cfg)
                x = x + h
                h = rms_norm(x, pl["ln2"], cfg.norm_eps)
                if cfg.family == "moe":
                    h, _ = moe_mod.moe_block(pl["moe"], h, cfg)
                else:
                    h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"],
                               pl["mlp"]["w_down"])
                return x + h, new_cl

            x, kv = jax.lax.scan(
                body, x, (params["layers"], {"k": cache["k"], "v": cache["v"]})
            )
            new_cache = {"k": kv["k"], "v": kv["v"], "pos": pos + 1}

        elif cfg.family == "ssm":
            def body(x, inp):
                pl, cl = inp
                h = rms_norm(x, pl["ln"], cfg.norm_eps)
                h, new_cl = ssm_mod.mamba_decode(
                    pl["mamba"], h, cl, cfg, ssd_impl=self.ssd_impl
                )
                return x + h, new_cl

            x, mam = jax.lax.scan(body, x, (params["layers"], cache["mamba"]))
            new_cache = {"mamba": mam, "pos": pos + 1}

        elif cfg.family == "hybrid":
            g = cfg.num_layers // cfg.attn_every
            grouped = jax.tree.map(
                lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]),
                params["layers"],
            )
            gmam = jax.tree.map(
                lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]),
                cache["mamba"],
            )
            shared = params["shared"]

            def group_body(x, inp):
                pg, mcg, kc, vc = inp
                h = rms_norm(x, shared["ln1"], cfg.norm_eps)
                h, new_kv = attn.decode_self_attention(
                    shared["attn"], h, {"k": kc, "v": vc}, pos, cfg
                )
                x = x + h
                h = rms_norm(x, shared["ln2"], cfg.norm_eps)
                x = x + swiglu(h, shared["mlp"]["w_gate"], shared["mlp"]["w_up"],
                               shared["mlp"]["w_down"])

                def mbody(xc, inp2):
                    pl, cl = inp2
                    hh = rms_norm(xc, pl["ln"], cfg.norm_eps)
                    hh, new_cl = ssm_mod.mamba_decode(
                        pl["mamba"], hh, cl, cfg, ssd_impl=self.ssd_impl
                    )
                    return xc + hh, new_cl

                x, new_mcs = jax.lax.scan(mbody, x, (pg, mcg))
                return x, {"kv": new_kv, "mamba": new_mcs}

            x, ys = jax.lax.scan(
                group_body, x, (grouped, gmam, cache["shared_k"], cache["shared_v"])
            )
            mam = jax.tree.map(
                lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), ys["mamba"]
            )
            new_cache = {
                "mamba": mam,
                "shared_k": ys["kv"]["k"],
                "shared_v": ys["kv"]["v"],
                "pos": pos + 1,
            }
        else:
            raise ValueError(cfg.family)

        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[:, 0]
        return new_cache, logits

    # ------------------------------------------------------------------
    # paged decode (continuous batching)
    # ------------------------------------------------------------------
    def decode_step_paged(self, params, pages, block_tables, lengths, tokens):
        """One token per in-flight slot against the paged KV pool.

        pages: {"k": (L,P,page,KVH,Dh), "v": ...} — the shared page pool.
        block_tables (S, MP) int32, lengths (S,) int32 (tokens already
        cached per slot; idle slots are 0), tokens (S, 1) int32.
        Returns (new_pages, logits (S, Vp) f32). Shapes are static across
        admissions/evictions, so the jitted step never recompiles.
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "moe", "vlm"), cfg.family
        x = jnp.take(params["embed"], tokens, axis=0)  # (S,1,D)

        def body(x, inp):
            pl, cl = inp
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            h, new_cl = attn.decode_self_attention_paged(
                pl["attn"], h, cl, block_tables, lengths, cfg,
                attn_impl=self.attn_impl,
            )
            x = x + h
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                h, _ = moe_mod.moe_block(pl["moe"], h, cfg)
            else:
                h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"],
                           pl["mlp"]["w_down"])
            return x + h, new_cl

        x, new_pages = jax.lax.scan(
            body, x, (params["layers"], dict(pages))
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        # column-parallel unembed under TP serving: gather the vocab shards
        # so sampling sees the full distribution (identity when unsharded)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[:, 0]
        return new_pages, logits

    def mixed_step_paged(self, params, pages, block_tables, positions,
                         tokens, *, num_decode, chunk_valid):
        """Fused mixed step: ``num_decode`` decode rows plus one prefill
        chunk's rows in ONE forward pass over the paged KV pool.

        tokens (R, 1) int32 with R = num_decode + C: rows ``[0,
        num_decode)`` are the decode slots (their usual fixed width), rows
        ``[num_decode, R)`` are the chunk. block_tables (R, MP) int32 gives
        every row its own table (chunk rows repeat the chunk slot's row);
        positions (R,) int32 is each row's absolute position, -1 for dead
        rows (idle slots, chunk padding). ``num_decode`` is static;
        ``chunk_valid`` (scalar int32) selects the chunk's sampling row.

        Returns (new_pages, logits (num_decode + 1, Vp) f32): one logits
        row per decode slot plus the chunk's row ``chunk_valid - 1`` (the
        first-token sampling position — meaningful on the prompt's final
        chunk, garbage and ignored before that). Unembedding only touches
        those num_decode + 1 rows, so the fused step pays the chunk's extra
        rows in attention/MLP but not in the vocab projection.
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "moe"), cfg.family
        x = jnp.take(params["embed"], tokens, axis=0)  # (R,1,D)

        def body(x, inp):
            pl, cl = inp
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            h, new_cl = attn.mixed_step_attention_paged(
                pl["attn"], h, cl, block_tables, positions, cfg,
                attn_impl=self.attn_impl, num_decode=num_decode,
            )
            x = x + h
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                h, _ = moe_mod.moe_block(pl["moe"], h, cfg)
            else:
                h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"],
                           pl["mlp"]["w_down"])
            return x + h, new_cl

        x, new_pages = jax.lax.scan(
            body, x, (params["layers"], dict(pages))
        )
        # decode rows + the chunk's sampling row, then ONE unembed
        xc = jax.lax.dynamic_slice_in_dim(
            x, num_decode + jnp.maximum(chunk_valid - 1, 0), 1, axis=0
        )
        x = jnp.concatenate([x[:num_decode], xc], axis=0)  # (S+1, 1, D)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[:, 0]
        return new_pages, logits

    # ------------------------------------------------------------------
    # chunked prefill (continuous batching)
    # ------------------------------------------------------------------
    def prefill_chunk(self, params, pages, block_table, tokens, start, valid):
        """One fixed-size prefill chunk of ONE sequence, scattered into its
        existing page set.

        pages: {"k": (L,P,page,KVH,Dh), "v": ...} — the shared page pool.
        block_table (MP,) int32 is the sequence's row; tokens (C,) int32 is
        the chunk (C static — one compile covers every prompt); start
        (scalar int32) is how many positions are already resident (shared
        prefix pages + earlier chunks); valid (scalar int32) is the number
        of real tokens in this possibly-padded chunk.

        Returns (new_pages, logits (Vp,) f32) where logits belong to chunk
        position ``valid - 1`` — meaningful on the prompt's final chunk
        (the first sampling position), garbage (and ignored) before that.
        Token-embedding families only (dense/moe); vlm prompts carry vision
        embeds and keep the whole-prompt bucketed prefill path.

        The chunk attention lowers per ``self.attn_impl`` exactly like the
        paged decode step: the Pallas chunk-prefill kernel on TPU (sharded
        serving dispatches it per kv-head shard), the XLA oracle elsewhere
        — same contract either way, asserted by the differential fuzz sweep
        in ``tests/test_kernel_fuzz.py``.
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "moe"), cfg.family
        x = jnp.take(params["embed"], tokens[None], axis=0)  # (1,C,D)

        def body(x, inp):
            pl, cl = inp
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            h, new_cl = attn.prefill_chunk_attention_paged(
                pl["attn"], h, cl, block_table, start, valid, cfg,
                attn_impl=self.attn_impl,
            )
            x = x + h
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                h, _ = moe_mod.moe_block(pl["moe"], h, cfg)
            else:
                h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"],
                           pl["mlp"]["w_down"])
            return x + h, new_cl

        x, new_pages = jax.lax.scan(
            body, x, (params["layers"], dict(pages))
        )
        x = jax.lax.dynamic_slice_in_dim(x, valid - 1, 1, axis=1)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[0, 0]
        return new_pages, logits

    # ------------------------------------------------------------------
    # speculative verify (continuous batching)
    # ------------------------------------------------------------------
    def verify_step_paged(self, params, pages, block_table, tokens, start,
                          valid):
        """Score a speculation bundle: C chunk-style rows of ONE sequence —
        the last committed token followed by k drafted tokens — scattered
        and attended exactly like a prefill chunk, but unembedding ALL C
        rows instead of just the last.

        pages: {"k": (L,P,page,KVH,Dh), "v": ...} — the shared page pool.
        block_table (MP,) int32 is the sequence's row; tokens (C,) int32 is
        ``[t_last, d_1 .. d_k]`` padded to the static bundle width; start
        (scalar int32) is the sequence's cached length L (t_last's KV lands
        at position L, draft i at L+i); valid (scalar int32) is ``1 + k``
        for this bundle (padded rows past it write out of bounds and return
        garbage the caller ignores).

        Returns (new_pages, logits (C, Vp) f32): row i is the distribution
        over the token at index ``idx0 + i`` given the committed history
        plus drafts ``d_1 .. d_i`` — exactly what a sequential i-step
        decode loop would produce, which is why acceptance under the
        ``(seed, token_index)``-keyed sampler reproduces the spec-off
        stream byte-for-byte. Attention rides the SAME chunk path as
        ``prefill_chunk`` (``ops.paged_prefill_attention`` — the mixed
        kernel's chunk half), so one fused dispatch both writes the k+1
        candidate KV positions and scores them; rejection is a pure
        host-side length rewind."""
        cfg = self.cfg
        assert cfg.family in ("dense", "moe"), cfg.family
        x = jnp.take(params["embed"], tokens[None], axis=0)  # (1,C,D)

        def body(x, inp):
            pl, cl = inp
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            h, new_cl = attn.prefill_chunk_attention_paged(
                pl["attn"], h, cl, block_table, start, valid, cfg,
                attn_impl=self.attn_impl,
            )
            x = x + h
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                h, _ = moe_mod.moe_block(pl["moe"], h, cfg)
            else:
                h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"],
                           pl["mlp"]["w_down"])
            return x + h, new_cl

        x, new_pages = jax.lax.scan(
            body, x, (params["layers"], dict(pages))
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)  # (1,C,D)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[0]  # (C, Vp)
        return new_pages, logits

    # ------------------------------------------------------------------
    # recurrent-state serving (SSM / hybrid continuous batching)
    # ------------------------------------------------------------------
    def decode_step_ssm(self, params, state, tokens, active):
        """One token per in-flight slot against the per-slot state bank.

        state: the ``init_mamba_cache`` pytree stacked over layers and
        batched over slots — ssm (L,S,HN,PN,N) f32 plus conv tails. tokens
        (S, 1) int32 is each slot's last token; active (S,) int32 masks
        idle slots, whose state is left untouched (their rows still run —
        shapes stay static so the jitted step never recompiles — but the
        writeback is gated). Returns (new_state, logits (S, Vp) f32).
        """
        cfg = self.cfg
        assert cfg.family == "ssm", cfg.family
        x = jnp.take(params["embed"], tokens, axis=0)  # (S,1,D)

        def body(x, inp):
            pl, cl = inp
            h = rms_norm(x, pl["ln"], cfg.norm_eps)
            h, new_cl = ssm_mod.mamba_decode(
                pl["mamba"], h, cl, cfg, ssd_impl=self.ssd_impl
            )
            return x + h, new_cl

        x, new_state = jax.lax.scan(body, x, (params["layers"], dict(state)))
        new_state = self._mask_state(new_state, dict(state), active)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[:, 0]
        return new_state, logits

    def prefill_chunk_ssm(self, params, state_slot, tokens, valid):
        """One fixed-size prefill chunk of ONE sequence through the SSD
        scan, continuing from (and returning) the slot's carried state.

        state_slot: one slot's state with the slot axis kept singleton —
        ssm (L,1,HN,PN,N) f32 plus conv tails. tokens (C,) int32 (C
        static); valid (scalar int32) is the number of real tokens in this
        possibly-padded chunk (padded positions are exact identities on
        the recurrence — see ``mamba_prefill_chunk``). Returns
        (new_state_slot, logits (Vp,) f32) where logits belong to chunk
        position ``valid - 1`` — meaningful on the prompt's final chunk,
        garbage (and ignored) before that.
        """
        cfg = self.cfg
        assert cfg.family == "ssm", cfg.family
        x = jnp.take(params["embed"], tokens[None], axis=0)  # (1,C,D)

        def body(x, inp):
            pl, cl = inp
            h = rms_norm(x, pl["ln"], cfg.norm_eps)
            h, new_cl = ssm_mod.mamba_prefill_chunk(
                pl["mamba"], h, cl, cfg, valid=valid, ssd_impl=self.ssd_impl
            )
            return x + h, new_cl

        x, new_state = jax.lax.scan(body, x, (params["layers"], dict(state_slot)))
        x = jax.lax.dynamic_slice_in_dim(
            x, jnp.maximum(valid - 1, 0), 1, axis=1
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[0, 0]
        return new_state, logits

    def decode_step_hybrid(self, params, pages, state, block_tables,
                           lengths, tokens, active):
        """Hybrid (Zamba2) paged decode: the shared attention block reads
        and writes the g-layer paged KV pool (g = L // attn_every) while
        every Mamba layer steps the per-slot state bank — one fused pass.

        pages: {"k": (g,P,page,KVH,Dh), "v": ...}; state: the stacked
        mamba bank (slot axis second); block_tables (S, MP) / lengths (S,)
        index the attention pool exactly like ``decode_step_paged``.
        Returns (new_pages, new_state, logits (S, Vp) f32).
        """
        cfg = self.cfg
        assert cfg.family == "hybrid", cfg.family
        g = cfg.num_layers // cfg.attn_every
        x = jnp.take(params["embed"], tokens, axis=0)  # (S,1,D)
        grouped = jax.tree.map(
            lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]),
            params["layers"],
        )
        gstate = jax.tree.map(
            lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]), dict(state)
        )
        shared = params["shared"]

        def group_body(x, inp):
            pg, mcg, cl = inp
            h = rms_norm(x, shared["ln1"], cfg.norm_eps)
            h, new_cl = attn.decode_self_attention_paged(
                shared["attn"], h, cl, block_tables, lengths, cfg,
                attn_impl=self.attn_impl,
            )
            x = x + h
            h = rms_norm(x, shared["ln2"], cfg.norm_eps)
            x = x + swiglu(h, shared["mlp"]["w_gate"], shared["mlp"]["w_up"],
                           shared["mlp"]["w_down"])

            def mbody(xc, inp2):
                pl, cl2 = inp2
                hh = rms_norm(xc, pl["ln"], cfg.norm_eps)
                hh, new_cl2 = ssm_mod.mamba_decode(
                    pl["mamba"], hh, cl2, cfg, ssd_impl=self.ssd_impl
                )
                return xc + hh, new_cl2

            x, new_mcs = jax.lax.scan(mbody, x, (pg, mcg))
            return x, {"kv": new_cl, "mamba": new_mcs}

        x, ys = jax.lax.scan(group_body, x, (grouped, gstate, dict(pages)))
        new_state = jax.tree.map(
            lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), ys["mamba"]
        )
        new_state = self._mask_state(new_state, dict(state), active)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[:, 0]
        return ys["kv"], new_state, logits

    def prefill_chunk_hybrid(self, params, pages, state_slot, block_table,
                             tokens, start, valid):
        """Hybrid chunked prefill of ONE sequence: attention chunk rows
        scatter into the sequence's pages (positions ``start..start+valid``)
        while the Mamba layers continue from the slot's carried state.
        Returns (new_pages, new_state_slot, logits (Vp,) f32) with logits
        at chunk position ``valid - 1`` as in ``prefill_chunk``.
        """
        cfg = self.cfg
        assert cfg.family == "hybrid", cfg.family
        g = cfg.num_layers // cfg.attn_every
        x = jnp.take(params["embed"], tokens[None], axis=0)  # (1,C,D)
        grouped = jax.tree.map(
            lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]),
            params["layers"],
        )
        gstate = jax.tree.map(
            lambda a: a.reshape((g, cfg.attn_every) + a.shape[1:]),
            dict(state_slot),
        )
        shared = params["shared"]

        def group_body(x, inp):
            pg, mcg, cl = inp
            h = rms_norm(x, shared["ln1"], cfg.norm_eps)
            h, new_cl = attn.prefill_chunk_attention_paged(
                shared["attn"], h, cl, block_table, start, valid, cfg,
                attn_impl=self.attn_impl,
            )
            x = x + h
            h = rms_norm(x, shared["ln2"], cfg.norm_eps)
            x = x + swiglu(h, shared["mlp"]["w_gate"], shared["mlp"]["w_up"],
                           shared["mlp"]["w_down"])

            def mbody(xc, inp2):
                pl, cl2 = inp2
                hh = rms_norm(xc, pl["ln"], cfg.norm_eps)
                hh, new_cl2 = ssm_mod.mamba_prefill_chunk(
                    pl["mamba"], hh, cl2, cfg, valid=valid,
                    ssd_impl=self.ssd_impl,
                )
                return xc + hh, new_cl2

            x, new_mcs = jax.lax.scan(mbody, x, (pg, mcg))
            return x, {"kv": new_cl, "mamba": new_mcs}

        x, ys = jax.lax.scan(group_body, x, (grouped, gstate, dict(pages)))
        new_state = jax.tree.map(
            lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), ys["mamba"]
        )
        x = jax.lax.dynamic_slice_in_dim(
            x, jnp.maximum(valid - 1, 0), 1, axis=1
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = all_gather_logits(jnp.einsum(
            "bsd,dv->bsv", x, self._unembed_weight(params),
            preferred_element_type=jnp.float32,
        ))[0, 0]
        return ys["kv"], new_state, logits

    @staticmethod
    def _mask_state(new_state, old_state, active):
        """Gate the state-bank writeback on per-slot activity (slot axis
        is second — leaves are stacked (L, S, ...))."""
        keep = active.astype(bool)

        def leaf(new, old):
            m = keep.reshape((1, -1) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)

        return jax.tree.map(leaf, new_state, old_state)
