"""Shared model building blocks: declarative params, norms, RoPE.

Params are plain pytrees (nested dicts of arrays). Each model defines a
``param_specs(cfg)`` tree of :class:`ParamSpec`; from it we derive

* ``init_params``    — real arrays (deterministic per-path RNG folding),
* ``abstract_params``— ShapeDtypeStructs (dry-run: no allocation),
* ``param_axes``     — logical-axis tuples (sharding).
"""

from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import tree_leaves_with_path
from repro.parallel.collectives import psum_tp


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float | None = None
    dtype: str | None = None  # None -> model default

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _leaf_dtype(spec: ParamSpec, default: str):
    return jnp.dtype(spec.dtype or default)


def init_params(specs: Any, key: jax.Array, default_dtype: str) -> Any:
    """Materialize a ParamSpec tree into real arrays.

    RNG is folded per tree-path so adding a parameter never reshuffles the
    others (checkpoint/elastic stability).
    """
    leaves = tree_leaves_with_path(specs, is_leaf=_is_spec)

    def one(path, spec: ParamSpec):
        dt = _leaf_dtype(spec, default_dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        # deterministic across processes (hash() is PYTHONHASHSEED-random)
        seed = zlib.crc32(jax.tree_util.keystr(path).encode()) % (2**31)
        k = jax.random.fold_in(key, seed)
        if spec.init == "embed":
            scale = spec.scale if spec.scale is not None else 1.0
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(fan_in)
        return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dt)

    vals = [one(p, s) for p, s in leaves]
    return jax.tree.unflatten(jax.tree.structure(specs, is_leaf=_is_spec), vals)


def abstract_params(specs: Any, default_dtype: str) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, _leaf_dtype(s, default_dtype)),
        specs,
        is_leaf=_is_spec,
    )


def param_axes(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_bytes(specs: Any, default_dtype: str) -> int:
    return sum(
        int(np.prod(s.shape)) * _leaf_dtype(s, default_dtype).itemsize
        for s in jax.tree.leaves(specs, is_leaf=_is_spec)
    )


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope_table(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for given integer positions. Shapes (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2) (broadcast over heads)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half)
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


def swiglu(x, w_gate, w_up, w_down, constrain=None):
    """SwiGLU MLP. Weights: (D,F), (D,F), (F,D).

    Under a serving :func:`repro.parallel.tensor_parallel` context the ff
    dim is sharded (gate/up column-parallel, down row-parallel) and the
    down projection's partial sum is reduced here; outside it ``psum_tp``
    is identity.
    """
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    if constrain is not None:
        h = constrain(h, "batch", "seq", "ff")
    return psum_tp(jnp.einsum("bsf,fd->bsd", h, w_down))


# ---------------------------------------------------------------------------
# sampling (the fused sample step shared by every serving engine)
# ---------------------------------------------------------------------------


def sample_tokens(
    logits: jax.Array,   # (B, Vp) — padded vocab ok, sliced to `vocab`
    temps: jax.Array,    # (B,) f32; <= 0 means greedy (filters ignored)
    top_ks: jax.Array,   # (B,) int32; 0 disables top-k
    top_ps: jax.Array,   # (B,) f32; 1.0 disables top-p
    seeds: jax.Array,    # (B,) int32 per-request RNG seed
    idx: jax.Array,      # (B,) int32 token index within each request
    vocab: int,
) -> jax.Array:
    """Per-row temperature / top-k / top-p sampling, one fused dispatch.

    RNG is keyed off ``(seed, token_index)`` per row — NEVER off an
    engine-global step counter — so a request reproduces the same stream no
    matter which slot it lands in, how it is batched, or whether it was
    preempted and regenerated. Sampling uses the Gumbel-max trick over the
    filtered logits; greedy rows (``temps <= 0``) take the plain argmax.

    The top-k/top-p filters cost one vocab sort per row per step. That is
    fine for the CPU/reference path and small reduced vocabs; a
    Pallas-fused filter is future kernel work, not an API concern.
    """
    lg = logits[..., :vocab].astype(jnp.float32)
    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def one(row, temp, k, p, seed, i):
        v = row.shape[-1]
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        desc = jnp.sort(row)[::-1]
        # top-k: keep logits >= the k-th largest (k=0 -> keep all)
        kth = desc[jnp.clip(jnp.where(k > 0, k, v) - 1, 0, v - 1)]
        row = jnp.where(row < kth, -jnp.inf, row)
        t = jnp.maximum(temp, 1e-6)
        # top-p (nucleus) over the top-k-filtered distribution: keep the
        # smallest prefix of descending probabilities whose mass reaches p
        probs = jax.nn.softmax(row / t)
        p_desc = jnp.sort(probs)[::-1]
        csum = jnp.cumsum(p_desc)
        cutoff = jnp.where(p >= 1.0, 0.0, p_desc[jnp.argmax(csum >= p)])
        row = jnp.where(probs < cutoff, -jnp.inf, row)
        g = jax.random.gumbel(key, row.shape, jnp.float32)
        return jnp.argmax(row / t + g).astype(jnp.int32)

    sampled = jax.vmap(one)(lg, temps, top_ks, top_ps, seeds, idx)
    return jnp.where(temps > 0.0, sampled, greedy)
