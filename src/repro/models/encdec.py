"""Encoder-decoder LM (Whisper backbone). Conv frontend is a STUB per the
assignment: the batch provides precomputed (B, frames, d_model) embeddings.

Deviations noted in DESIGN.md: sinusoidal (non-learned) position encodings on
both stacks (Whisper uses learned on the decoder) so parameters stay
independent of sequence length; RMSNorm instead of LayerNorm+bias for
consistency with the rest of the zoo.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.common import (
    ParamSpec,
    abstract_params,
    init_params,
    param_axes,
    rms_norm,
    swiglu,
)
from repro.models.lm import chunked_cross_entropy, mlp_param_specs, padded_vocab
from repro.parallel import constrain


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDecLM:
    def __init__(self, cfg: ModelConfig, *, attn_impl: str = "xla_chunked", **_):
        assert cfg.is_encoder_decoder
        self.cfg = cfg
        self.attn_impl = attn_impl

    # ------------------------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        L, D = cfg.num_layers, cfg.d_model
        vp = padded_vocab(cfg)
        enc_layer = {
            "ln1": ParamSpec((L, D), ("stack", None), init="ones"),
            "attn": attn.attn_param_specs(cfg, stacked=L),
            "ln2": ParamSpec((L, D), ("stack", None), init="ones"),
            "mlp": mlp_param_specs(cfg, stacked=L),
        }
        dec_layer = {
            "ln1": ParamSpec((L, D), ("stack", None), init="ones"),
            "attn": attn.attn_param_specs(cfg, stacked=L),
            "ln_x": ParamSpec((L, D), ("stack", None), init="ones"),
            "xattn": attn.attn_param_specs(cfg, stacked=L),
            "ln2": ParamSpec((L, D), ("stack", None), init="ones"),
            "mlp": mlp_param_specs(cfg, stacked=L),
        }
        return {
            "embed": ParamSpec((vp, D), ("vocab", None), init="embed", scale=0.02),
            "unembed": ParamSpec((D, vp), (None, "vocab")),
            "enc_norm": ParamSpec((D,), (None,), init="ones"),
            "final_norm": ParamSpec((D,), (None,), init="ones"),
            "encoder": enc_layer,
            "decoder": dec_layer,
        }

    def init(self, key):
        return init_params(self.param_specs(), key, self.cfg.dtype)

    def abstract(self):
        return abstract_params(self.param_specs(), self.cfg.dtype)

    def axes(self):
        return param_axes(self.param_specs())

    # ------------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames (B, Senc, D) precomputed embeddings -> encoder states."""
        cfg = self.cfg
        pos = sinusoidal(jnp.arange(frames.shape[1]), cfg.d_model)
        x = frames.astype(jnp.dtype(cfg.dtype)) + pos[None].astype(jnp.dtype(cfg.dtype))
        x = constrain(x, "batch", "seq", None)

        def body(x, pl):
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            h = attn.self_attention(
                pl["attn"], h, cfg, causal=False, rope=False, attn_impl=self.attn_impl
            )
            x = x + h
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
            return constrain(x + h, "batch", "seq", None), ()

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _decode_stack_train(self, params, x, enc):
        cfg = self.cfg

        def body(x, pl):
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            h = attn.self_attention(
                pl["attn"], h, cfg, causal=True, rope=False, attn_impl=self.attn_impl
            )
            x = x + h
            h = rms_norm(x, pl["ln_x"], cfg.norm_eps)
            kv = attn.cross_attention_kv(pl["xattn"], enc)
            h = attn.cross_attention(pl["xattn"], h, kv, cfg, attn_impl=self.attn_impl)
            x = x + h
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
            return constrain(x + h, "batch", "seq", None), ()

        x, _ = jax.lax.scan(body, x, params["decoder"])
        return x

    def _embed_tokens(self, params, tokens):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)
        pos = sinusoidal(jnp.arange(tokens.shape[1]), cfg.d_model)
        return x + pos[None].astype(x.dtype)

    def loss_fn(self, params, batch):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        x = self._embed_tokens(params, batch["tokens"])
        x = self._decode_stack_train(params, x, enc)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        ce = chunked_cross_entropy(x, params["unembed"], batch["targets"], cfg.vocab_size)
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    # ------------------------------------------------------------------
    # serving: cache = decoder self-KV + precomputed cross-KV
    # ------------------------------------------------------------------
    def cache_struct(self, batch: int, max_len: int, abstract: bool):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        L = cfg.num_layers

        def arr(shape, dtype):
            return jax.ShapeDtypeStruct(shape, dtype) if abstract else jnp.zeros(shape, dtype)

        kv = (L, batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        return {
            "k": arr(kv, dt),
            "v": arr(kv, dt),
            "xk": arr(kv, dt),
            "xv": arr(kv, dt),
            "pos": arr((), jnp.int32),
            "enc_len": arr((), jnp.int32),  # true (unpadded) encoder length
        }

    def init_cache(self, batch, max_len):
        return self.cache_struct(batch, max_len, abstract=False)

    def abstract_cache(self, batch, max_len):
        return self.cache_struct(batch, max_len, abstract=True)

    def cache_axes(self):
        kv = ("stack", "cache_batch", "cache_seq", "kv_heads", "head_dim")
        return {"k": kv, "v": kv, "xk": kv, "xv": kv, "pos": None,
                "enc_len": None}

    def prefill(self, params, batch, max_len: int):
        """Encode frames, prefill decoder with the given tokens."""
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        x = self._embed_tokens(params, batch["tokens"])
        b, s, _ = x.shape
        pad = max_len - s

        def pad_kv(k):
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            return constrain(k, "cache_batch", "cache_seq", "kv_heads", "head_dim")

        def pad_xkv(k):
            p = max_len - k.shape[1]
            k = jnp.pad(k, ((0, 0), (0, p), (0, 0), (0, 0)))
            return constrain(k, "cache_batch", "cache_seq", "kv_heads", "head_dim")

        def body(x, pl):
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            h, (k, v) = attn.self_attention_with_cache_write(
                pl["attn"], h, cfg, attn_impl=self.attn_impl, rope=False
            )
            x = x + h
            h = rms_norm(x, pl["ln_x"], cfg.norm_eps)
            xkv = attn.cross_attention_kv(pl["xattn"], enc)
            h = attn.cross_attention(pl["xattn"], h, xkv, cfg, attn_impl=self.attn_impl)
            x = x + h
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
            return x + h, {
                "k": pad_kv(k), "v": pad_kv(v),
                "xk": pad_xkv(xkv[0]), "xv": pad_xkv(xkv[1]),
            }

        x, kv = jax.lax.scan(body, x, params["decoder"])
        cache = {**kv, "pos": jnp.asarray(s, jnp.int32),
                 "enc_len": jnp.asarray(enc.shape[1], jnp.int32)}
        x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum(
            "bsd,dv->bsv", x, params["unembed"], preferred_element_type=jnp.float32
        )[:, 0]
        return cache, logits

    def decode_step(self, params, cache, tokens):
        """One decoder token. NOTE rope=False family: positions via sinusoid."""
        cfg = self.cfg
        pos = cache["pos"]
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x + sinusoidal(pos[None], cfg.d_model)[None].astype(x.dtype)

        def body(x, inp):
            pl, cl = inp
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            h, new_kv = attn.decode_self_attention(
                pl["attn"], h, {"k": cl["k"], "v": cl["v"]}, pos, cfg, rope=False
            )
            x = x + h
            h = rms_norm(x, pl["ln_x"], cfg.norm_eps)
            # xk/xv are zero-padded to max_len: mask to the true enc length
            h = attn.decode_cross_attention(
                pl["xattn"], h, (cl["xk"], cl["xv"]), cfg,
                enc_len=cache["enc_len"],
            )
            x = x + h
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            h = swiglu(h, pl["mlp"]["w_gate"], pl["mlp"]["w_up"], pl["mlp"]["w_down"])
            return x + h, {**new_kv, "xk": cl["xk"], "xv": cl["xv"]}

        layer_caches = {k: cache[k] for k in ("k", "v", "xk", "xv")}
        x, kv = jax.lax.scan(body, x, (params["decoder"], layer_caches))
        new_cache = {**kv, "pos": pos + 1, "enc_len": cache["enc_len"]}
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum(
            "bsd,dv->bsv", x, params["unembed"], preferred_element_type=jnp.float32
        )[:, 0]
        return new_cache, logits
