"""Mixture-of-Experts block: top-k routing, per-example capacity dispatch.

Dispatch strategy (TPU/SPMD-native): tokens are grouped **per example** (the
batch dim is the data-parallel axis), so dispatch/combine are local scatters/
gathers within each data shard — no cross-shard scatter traffic. Expert FFN
weights (E, D, F) are sharded D->FSDP("embed"), F->TP("ff"): every chip holds
a slice of *every* expert, so no all-to-all is required at all (a deliberate
departure from GShard-style EP; see DESIGN.md and the EP-vs-TP perf note).

Decode path (S==1): dense dispatch over experts with one-hot gates — at
batch x 1 token the step is HBM-bound on expert weights either way.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamSpec
from repro.parallel import constrain
from repro.parallel.collectives import psum_tp


def moe_param_specs(cfg: ModelConfig, stacked: int | None = None) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    pre = (stacked,) if stacked else ()
    pax = ("stack",) if stacked else ()
    return {
        "router": ParamSpec(pre + (d, e), pax + ("embed", None)),
        "w_gate": ParamSpec(pre + (e, d, f), pax + ("experts", "embed", "ff")),
        "w_up": ParamSpec(pre + (e, d, f), pax + ("experts", "embed", "ff")),
        "w_down": ParamSpec(pre + (e, f, d), pax + ("experts", "ff", "embed")),
    }


def capacity(cfg: ModelConfig, seq_len: int) -> int:
    c = math.ceil(seq_len * cfg.experts_per_token * cfg.capacity_factor / cfg.num_experts)
    return max(cfg.experts_per_token, min(c, seq_len))


def route(p, x, cfg: ModelConfig):
    """Router logits -> (gates (B,S,k), idx (B,S,k), aux_loss scalar)."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load balancing loss
    e = cfg.num_experts
    frac_tokens = jnp.mean(
        jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return gates, idx, aux


def _expert_ffn(p, x_exp):
    """x_exp (B, E, C, D) -> (B, E, C, D); SwiGLU per expert.

    The serving executor shards the expert ff dim over the model axis
    (every TP shard holds a slice of every expert, same layout as
    training); the down projection's partial sum reduces here — identity
    outside a ``tensor_parallel`` context.
    """
    g = jnp.einsum("becd,edf->becf", x_exp, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", x_exp, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x_exp.dtype) * u
    h = constrain(h, "batch", "experts", "expert_capacity", "ff")
    return psum_tp(jnp.einsum("becf,efd->becd", h, p["w_down"]))


MOE_SEQ_CHUNK = 4096  # dispatch-buffer bound: B x k x chunk x cf x D


def moe_block(p, x, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x (B,S,D) -> (y, aux_loss). Long sequences are dispatched in seq
    chunks so the (B,E,C,D) buffers stay ~2 x chunk x k x cf x D bytes per
    example instead of scaling with the full 32k+ sequence."""
    b, s, d = x.shape
    if s > MOE_SEQ_CHUNK:
        nc = s // MOE_SEQ_CHUNK
        assert s % MOE_SEQ_CHUNK == 0, (s, MOE_SEQ_CHUNK)
        xc = jnp.moveaxis(x.reshape(b, nc, MOE_SEQ_CHUNK, d), 1, 0)

        def body(aux_sum, x_chunk):
            y_chunk, aux = _moe_block_chunk(p, x_chunk, cfg)
            return aux_sum + aux, y_chunk

        aux_total, yc = jax.lax.scan(body, jnp.zeros((), jnp.float32), xc)
        y = jnp.moveaxis(yc, 0, 1).reshape(b, s, d)
        return y, aux_total / nc
    return _moe_block_chunk(p, x, cfg)


def _moe_block_chunk(p, x, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    k, e = cfg.experts_per_token, cfg.num_experts
    gates, idx, aux = route(p, x, cfg)

    if s == 1:
        # decode: dense one-hot combine (HBM-bound on weights regardless)
        onehot = jnp.sum(
            jax.nn.one_hot(idx, e, dtype=jnp.float32) * gates[..., None], axis=2
        )  # (B, 1, E)
        xe = jnp.broadcast_to(x[:, None, :, :], (b, e, 1, d))  # (B,E,1,D)
        ye = _expert_ffn(p, xe)  # (B,E,1,D)
        y = jnp.einsum("beqd,bqe->bqd", ye.astype(jnp.float32), onehot)
        return y.astype(x.dtype), aux

    cap = capacity(cfg, s)
    # position of each (s, k) assignment within its expert's capacity buffer,
    # computed per example in token order
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)          # (B,S,k,E)
    flat = onehot.reshape(b, s * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - 1               # (B,S*k,E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(b, s, k)  # (B,S,k)
    keep = pos < cap                                            # (B,S,k)

    # ---- dispatch: scatter tokens into (E, C) buffers, per example ----
    def dispatch_one(xb, idxb, posb, keepb):
        # xb (S,D); idxb/posb/keepb (S,k)
        buf = jnp.zeros((e, cap, d), xb.dtype)
        xs = jnp.repeat(xb, k, axis=0)                          # (S*k, D)
        ei = idxb.reshape(-1)
        pi = jnp.where(keepb.reshape(-1), posb.reshape(-1), cap)  # dropped -> OOB
        return buf.at[ei, pi].add(xs, mode="drop")

    x_exp = jax.vmap(dispatch_one)(x, idx, pos, keep)           # (B,E,C,D)
    x_exp = constrain(x_exp, "batch", "experts", "expert_capacity", "embed_tp")

    y_exp = _expert_ffn(p, x_exp)                               # (B,E,C,D)

    # ---- combine: gather back and weight by gates ----
    def combine_one(yb, idxb, posb, keepb, gb):
        pi = jnp.where(keepb, posb, 0)
        got = yb[idxb.reshape(-1), pi.reshape(-1)].reshape(s, k, d)
        w = (gb * keepb).astype(jnp.float32)[..., None]
        return jnp.sum(got.astype(jnp.float32) * w, axis=1)

    y = jax.vmap(combine_one)(y_exp, idx, pos, keep, gates)
    return y.astype(x.dtype), aux
