"""Attention blocks: GQA + RoPE (+ optional qk-norm), train/prefill/decode paths.

Sharding: q heads -> "model" (when divisible), kv heads -> "model" (usually
replicated since kv_heads < 16), decode KV cache seq -> "model"
(flash-decoding-style sequence parallelism; see DESIGN.md).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.kernels.ref import quantize_kv
from repro.models import common
from repro.models.common import ParamSpec, apply_rope, rms_norm, rope_table
from repro.parallel import constrain
from repro.parallel.collectives import psum_tp

NEG_INF = -1e30


def attn_param_specs(cfg: ModelConfig, stacked: int | None = None) -> dict:
    """QKV/O projections (+ qk-norm scales). ``stacked``: leading scan dim.

    Uses the *effective* (possibly padded) head counts; padded o-proj rows
    are zero-init so padding is output-identical at init.
    """
    d, h, kvh, hd = cfg.d_model, cfg.eff_heads, cfg.eff_kv_heads, cfg.head_dim
    pre = (stacked,) if stacked else ()
    pax = ("stack",) if stacked else ()
    wo_init = "zeros" if cfg.num_heads_padded else "normal"
    specs = {
        "wq": ParamSpec(pre + (d, h, hd), pax + ("embed", "heads", "head_dim")),
        "wk": ParamSpec(pre + (d, kvh, hd), pax + ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec(pre + (d, kvh, hd), pax + ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec(pre + (h, hd, d), pax + ("heads", "head_dim", "embed"),
                        init=wo_init),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec(pre + (hd,), pax + (None,), init="ones")
        specs["k_norm"] = ParamSpec(pre + (hd,), pax + (None,), init="ones")
    return specs


def _project_qkv(p, x, cfg: ModelConfig, positions: jax.Array | None, rope: bool):
    """x (B,S,D) -> q (B,S,H,Dh), k/v (B,S,KVH,Dh), rope-rotated."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        assert positions is not None
        cos, sin = rope_table(positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def self_attention(
    p: dict,
    x: jax.Array,           # (B, S, D)
    cfg: ModelConfig,
    *,
    causal: bool = True,
    rope: bool = True,
    positions: jax.Array | None = None,  # (S,) int32
    attn_impl: str = "xla_chunked",
) -> jax.Array:
    """Full-sequence self-attention (train / prefill)."""
    if positions is None:
        positions = jnp.arange(x.shape[1])
    q, k, v = _project_qkv(p, x, cfg, positions, rope)
    out = ops.flash_attention(q, k, v, causal=causal, impl=attn_impl)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    # row-parallel wo: partial sum per TP shard, reduced here (identity
    # outside a tensor_parallel context)
    return psum_tp(jnp.einsum("bshk,hkd->bsd", out, p["wo"]))


def self_attention_with_cache_write(
    p, x, cfg: ModelConfig, *, positions=None, attn_impl="xla_chunked",
    rope: bool = True,
):
    """Prefill: attention output AND the K/V to seed the cache."""
    if positions is None:
        positions = jnp.arange(x.shape[1])
    q, k, v = _project_qkv(p, x, cfg, positions, rope=rope)
    out = ops.flash_attention(q, k, v, causal=True, impl=attn_impl)
    out = constrain(out, "batch", "seq", "heads", "head_dim")
    return psum_tp(jnp.einsum("bshk,hkd->bsd", out, p["wo"])), (k, v)


def decode_attention_raw(
    q: jax.Array,        # (B, 1, H, Dh)
    k_cache: jax.Array,  # (B, Smax, KVH, Dh)
    v_cache: jax.Array,
    cache_len: jax.Array,  # scalar int32: number of valid positions (incl. current)
    scale: float,
) -> jax.Array:
    """One-token attention over a (possibly seq-sharded) KV cache."""
    b, _, h, hd = q.shape
    smax, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd).astype(jnp.float32) * scale
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32)
    )  # (B, KVH, G, Smax)
    valid = jnp.arange(smax)[None, None, None, :] < cache_len
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd)


def decode_self_attention(
    p: dict,
    x: jax.Array,          # (B, 1, D)
    layer_cache: dict,     # {"k": (B,Smax,KVH,Dh), "v": ...}
    pos: jax.Array,        # scalar int32: index of the current token
    cfg: ModelConfig,
    *,
    rope: bool = True,
) -> tuple[jax.Array, dict]:
    positions = pos[None] if pos.ndim == 0 else pos
    q, k, v = _project_qkv(p, x, cfg, positions, rope)
    kc = jax.lax.dynamic_update_slice(layer_cache["k"], k.astype(layer_cache["k"].dtype), (0, pos, 0, 0))
    vc = jax.lax.dynamic_update_slice(layer_cache["v"], v.astype(layer_cache["v"].dtype), (0, pos, 0, 0))
    kc = constrain(kc, "cache_batch", "cache_seq", "kv_heads", "head_dim")
    vc = constrain(vc, "cache_batch", "cache_seq", "kv_heads", "head_dim")
    out = decode_attention_raw(q, kc, vc, pos + 1, cfg.head_dim ** -0.5)
    out = out.astype(x.dtype)
    o = psum_tp(jnp.einsum("bshk,hkd->bsd", out, p["wo"]))
    return o, {"k": kc, "v": vc}


def _paged_scatter(
    layer_pages: dict, k_rows: jax.Array, v_rows: jax.Array,
    phys: jax.Array, off: jax.Array,
) -> dict:
    """Scatter per-row K/V into this layer's page pool (indices (rows,)).

    int8 pools (``k_scale`` present) quantize on the way in — one f32 scale
    per (row, kv head) over head_dim — and scatter the scales alongside, so
    the paged kernels can fuse the dequant. Out-of-bounds rows are dropped
    for values and scales alike."""
    out = dict(layer_pages)
    if "k_scale" in layer_pages:
        k_rows, k_sc = quantize_kv(k_rows)
        v_rows, v_sc = quantize_kv(v_rows)
        out["k_scale"] = layer_pages["k_scale"].at[phys, off].set(
            k_sc, mode="drop")
        out["v_scale"] = layer_pages["v_scale"].at[phys, off].set(
            v_sc, mode="drop")
    out["k"] = layer_pages["k"].at[phys, off].set(
        k_rows.astype(layer_pages["k"].dtype), mode="drop")
    out["v"] = layer_pages["v"].at[phys, off].set(
        v_rows.astype(layer_pages["v"].dtype), mode="drop")
    return out


def decode_self_attention_paged(
    p: dict,
    x: jax.Array,            # (S, 1, D) one token per in-flight slot
    layer_pages: dict,       # {"k": (P,page,KVH,Dh), "v": ...} this layer's pool
    block_tables: jax.Array,  # (S, MP) int32
    lengths: jax.Array,      # (S,) int32 tokens already cached per slot
    cfg: ModelConfig,
    *,
    rope: bool = True,
    attn_impl: str = "xla_chunked",
) -> tuple[jax.Array, dict]:
    """Continuous-batching decode: write the new K/V into each slot's current
    page, then attend over the block table. Per-slot positions (= lengths)
    drive RoPE, so slots at different depths coexist in one batch."""
    positions = lengths[:, None]  # (S, 1) absolute position of the new token
    q, k, v = _project_qkv(p, x, cfg, positions, rope)
    num_pages, page = layer_pages["k"].shape[:2]
    phys = jnp.take_along_axis(
        block_tables, (lengths // page)[:, None], axis=1
    )[:, 0]
    # idle slots (block-table entry 0 = the reserved null page) write out of
    # bounds and are DROPPED: every surviving scatter index is unique, so the
    # update order is well-defined (duplicate-index scatter is not)
    phys = jnp.where(phys == 0, num_pages, phys)
    off = lengths % page
    cache = _paged_scatter(layer_pages, k[:, 0], v[:, 0], phys, off)
    out = ops.paged_attention(
        q[:, 0], cache["k"], cache["v"], block_tables, lengths + 1,
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
        scale=cfg.head_dim ** -0.5, impl=attn_impl,
    ).astype(x.dtype)  # (S, H_local, Dh)
    # under the serving executor's shard_map, q/kv heads and the page pool
    # are head-sharded: each shard attends its own head slice against its
    # own KV shard (block tables are replicated), and the row-parallel wo
    # partial sums are reduced here
    o = psum_tp(jnp.einsum("bhk,hkd->bd", out, p["wo"]))[:, None, :]
    return o, cache


def prefill_chunk_attention_paged(
    p: dict,
    x: jax.Array,            # (1, C, D) one chunk of ONE sequence's prompt
    layer_pages: dict,       # {"k": (P,page,KVH,Dh), "v": ...} this layer's pool
    block_table: jax.Array,  # (MP,) int32 the sequence's block-table row
    start: jax.Array,        # scalar int32: positions already in the pages
    valid: jax.Array,        # scalar int32: real (non-padded) chunk tokens
    cfg: ModelConfig,
    *,
    rope: bool = True,
    attn_impl: str = "xla_chunked",
) -> tuple[jax.Array, dict]:
    """Chunked prefill: scatter the chunk's K/V into the sequence's pages,
    then attend each chunk position over the paged prefix + the chunk itself
    (causal). RoPE uses absolute positions ``start + i``, so a chunk never
    knows (or re-pads to) the full prompt length. Padded positions
    (>= valid) write out of bounds (dropped) and return garbage outputs the
    caller discards.

    ``attn_impl`` selects the attention lowering exactly like decode:
    "pallas"/"auto"-on-TPU dispatches the Pallas chunk-prefill kernel
    (shard-map compatible — each TP shard attends its own head slice of the
    page pool against its grouped-q slice), everything else lowers through
    ``ref.paged_prefill_attention_ref``."""
    c = x.shape[1]
    positions = start + jnp.arange(c)
    q, k, v = _project_qkv(p, x, cfg, positions, rope)
    num_pages, page = layer_pages["k"].shape[:2]
    phys = jnp.where(
        jnp.arange(c) < valid, block_table[positions // page], num_pages
    )
    off = positions % page
    cache = _paged_scatter(layer_pages, k[0], v[0], phys, off)
    out = ops.paged_prefill_attention(
        q[0], cache["k"], cache["v"], block_table, start, valid,
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
        scale=cfg.head_dim ** -0.5, impl=attn_impl,
    ).astype(x.dtype)  # (C, H_local, Dh)
    o = psum_tp(jnp.einsum("chk,hkd->cd", out, p["wo"]))[None]
    return o, cache


def mixed_step_attention_paged(
    p: dict,
    x: jax.Array,             # (R, 1, D) one token per row (decode + chunk)
    layer_pages: dict,        # {"k": (P,page,KVH,Dh), "v": ...} this layer's pool
    block_tables: jax.Array,  # (R, MP) int32, one block-table row per row
    positions: jax.Array,     # (R,) int32 absolute position per row, -1 = dead
    cfg: ModelConfig,
    *,
    rope: bool = True,
    attn_impl: str = "xla_chunked",
    num_decode: int | None = None,
) -> tuple[jax.Array, dict]:
    """Fused mixed step: decode rows AND one prefill chunk's rows scatter
    their K/V into the page pool in ONE functional update, then every row
    attends its own block table up to its own position (``<= positions[r]``)
    through ``ops.paged_mixed_attention``.

    A decode slot contributes one row at ``positions[r] = length``; chunk
    token i contributes a row at ``positions[r] = start + i`` sharing the
    chunk slot's block-table row — because the combined scatter lands
    before any row reads, chunk row i sees chunk rows ``< i`` exactly as
    the unfused chunk path does. Dead rows (idle slots, chunk padding) use
    ``positions[r] = -1``: their write is dropped out of bounds and their
    attention output is exact zeros (discarded by the caller).

    Scatter-index uniqueness (the same argument as decode): live rows write
    distinct (page, offset) pairs — decode rows own their writable page
    (``ensure_append_capacity`` COWs shared pages first), chunk rows write
    the chunk slot's exclusively-owned fresh pages (prefix pages are only
    published AFTER the chunk covering them dispatched), and dead rows are
    dropped — so decode/chunk fusion never creates a read-write hazard and
    the dispatch order of the two halves is immaterial.

    ``num_decode`` (static) forwards the mixed-batch structure hint to
    :func:`repro.kernels.ops.paged_mixed_attention`: rows past it are one
    chunk sharing a block-table row, which lets the XLA fallback gather
    the chunk's K/V once instead of per row (the Pallas path ignores it).
    """
    live = positions >= 0
    pos = jnp.maximum(positions, 0)
    q, k, v = _project_qkv(p, x, cfg, pos[:, None], rope)
    num_pages, page = layer_pages["k"].shape[:2]
    phys = jnp.take_along_axis(
        block_tables, (pos // page)[:, None], axis=1
    )[:, 0]
    # dead rows and null-page entries write out of bounds and are DROPPED
    phys = jnp.where(live & (phys != 0), phys, num_pages)
    off = pos % page
    cache = _paged_scatter(layer_pages, k[:, 0], v[:, 0], phys, off)
    out = ops.paged_mixed_attention(
        q[:, 0], cache["k"], cache["v"], block_tables, positions,
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
        scale=cfg.head_dim ** -0.5, impl=attn_impl, num_decode=num_decode,
    ).astype(x.dtype)  # (R, H_local, Dh)
    # same sharding contract as decode: per-shard head slice of q/kv and the
    # page pool, tables/positions replicated, row-parallel wo reduced here
    o = psum_tp(jnp.einsum("bhk,hkd->bd", out, p["wo"]))[:, None, :]
    return o, cache


def cross_attention(
    p: dict,
    x: jax.Array,          # (B, Sq, D) decoder states
    kv: tuple[jax.Array, jax.Array] | None,  # precomputed enc (k, v)
    cfg: ModelConfig,
    *,
    attn_impl: str = "xla_chunked",
) -> jax.Array:
    """Encoder-decoder cross attention (no rope, non-causal)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = kv
    out = ops.flash_attention(q, k, v, causal=False, impl=attn_impl)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def cross_attention_kv(p: dict, enc: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Precompute cross-attention K/V from encoder output (B, Senc, D)."""
    k = jnp.einsum("bsd,dhk->bshk", enc, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, p["wv"])
    return k, v


def decode_cross_attention(p, x, kv, cfg: ModelConfig, enc_len=None):
    """One-token cross attention over precomputed encoder K/V.

    ``enc_len`` (scalar int32) masks K/V that was zero-padded past the true
    encoder length (the serving cache pads to max_len) — attending over the
    pad would pollute the softmax and diverge from the prefill path. None
    means the K/V is unpadded (use its full length)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k, v = kv
    n = jnp.asarray(k.shape[1], jnp.int32) if enc_len is None else enc_len
    out = decode_attention_raw(
        q, k, v, n, cfg.head_dim ** -0.5
    ).astype(x.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])
