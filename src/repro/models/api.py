"""Unified model facade: one object per architecture with a stable surface
used by the trainer, server, dry-run, and tests.

    model = build_model(cfg)
    params = model.init(key)            # real arrays
    aparams = model.abstract()          # ShapeDtypeStructs (dry-run)
    axes    = model.axes()              # logical-axis tuples (sharding)
    loss, metrics = model.loss_fn(params, batch)
    cache, logits = model.prefill(params, batch, max_len)
    cache, logits = model.decode_step(params, cache, tokens)
    specs  = model.input_specs(shape)   # dry-run inputs per shape cell
    batch  = model.make_batch(seed, shape)  # real synthetic batch (smoke)
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecLM
from repro.models.lm import DecoderLM

Model = Any  # DecoderLM | EncDecLM


def build_model(cfg: ModelConfig, **kw) -> Model:
    if cfg.is_encoder_decoder:
        return EncDecLM(cfg, **kw)
    return DecoderLM(cfg, **kw)


# ---------------------------------------------------------------------------
# inputs: abstract specs (dry-run) and synthetic batches (smoke tests)
# ---------------------------------------------------------------------------

# logical axes of each batch field
BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "targets": ("batch", "seq"),
    "vision_embeds": ("batch", "seq", None),
    "frames": ("batch", "seq", None),
}


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_frontend_tokens, cfg.d_model), dt
        )
    if cfg.is_encoder_decoder:
        specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    return specs


def cache_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Total context length: VLM carries its vision prefix in the cache."""
    return shape.seq_len + (cfg.num_frontend_tokens if cfg.family == "vlm" else 0)


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Inputs for one decode step: tokens + the cache as an argument."""
    b = shape.global_batch
    model = build_model(cfg)
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "cache": model.abstract_cache(b, cache_len(cfg, shape)),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    if shape.kind in ("train", "prefill"):
        return train_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Real synthetic batch for smoke tests / examples (reduced configs)."""
    rng = np.random.default_rng(seed)
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.num_frontend_tokens, cfg.d_model)), dt
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), dt)
    return batch
