"""Mamba2 (SSD) block: in_proj -> causal depthwise conv -> SSD scan -> gated norm -> out_proj.

Sharding: the inner width d_inner (and its head view H = d_inner / P) is
tensor-parallel over "model"; the SSD state (B, H, P, N) therefore shards on
H. B/C projections (state dim N) are small and replicated. The depthwise conv
is split into separate x / B / C convolutions so each stream keeps a clean
sharding (mathematically identical to the fused conv — it is depthwise).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.common import ParamSpec
from repro.parallel import constrain
from repro.parallel.collectives import pmean_tp, psum_tp


def mamba_param_specs(cfg: ModelConfig, stacked: int | None = None) -> dict:
    d, din, n, h, w = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width
    pre = (stacked,) if stacked else ()
    pax = ("stack",) if stacked else ()
    return {
        "w_z": ParamSpec(pre + (d, din), pax + ("embed", "ff")),
        "w_x": ParamSpec(pre + (d, din), pax + ("embed", "ff")),
        "w_b": ParamSpec(pre + (d, n), pax + ("embed", None)),
        "w_c": ParamSpec(pre + (d, n), pax + ("embed", None)),
        "w_dt": ParamSpec(pre + (d, h), pax + ("embed", "ssm_heads")),
        "conv_x": ParamSpec(pre + (w, din), pax + (None, "ff"), scale=0.5),
        "conv_b": ParamSpec(pre + (w, n), pax + (None, None), scale=0.5),
        "conv_c": ParamSpec(pre + (w, n), pax + (None, None), scale=0.5),
        "a_log": ParamSpec(pre + (h,), pax + ("ssm_heads",), init="ones"),
        "d_skip": ParamSpec(pre + (h,), pax + ("ssm_heads",), init="ones"),
        "dt_bias": ParamSpec(pre + (h,), pax + ("ssm_heads",), init="zeros"),
        "norm": ParamSpec(pre + (din,), pax + ("ff",), init="ones"),
        "w_out": ParamSpec(pre + (din, d), pax + ("ff", "embed")),
    }


def _causal_conv(
    x: jax.Array, w: jax.Array, tail: jax.Array | None = None, valid=None
):
    """Depthwise causal conv. x (B,S,C), w (W,C), tail (B,W-1,C) carry-in.

    Returns (y (B,S,C), new_tail (B,W-1,C)). ``valid`` (scalar, traced ok)
    marks how many leading positions of ``x`` are real tokens: the carried
    tail then ends at position ``valid`` instead of S, so a partially
    filled prefill chunk hands the next chunk the right conv window.
    """
    width = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+W-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    if width > 1:
        if valid is None:
            new_tail = xp[:, -(width - 1):, :]
        else:
            # tokens occupy xp[:, W-1 : W-1+valid]; the (W-1)-wide window
            # ending at the last valid token starts at xp[:, valid]
            new_tail = jax.lax.dynamic_slice_in_dim(xp, valid, width - 1, axis=1)
    else:
        new_tail = tail
    return y.astype(x.dtype), new_tail


def _gated_norm(p, gated, cfg: ModelConfig):
    """RMS norm over d_inner. d_inner is ff-sharded under tensor parallelism,
    so the mean of squares is averaged across shards (equal-size slices make
    the mean-of-local-means exact); identity reduction when unsharded."""
    dt = gated.dtype
    g32 = gated.astype(jnp.float32)
    var = pmean_tp(jnp.mean(jnp.square(g32), axis=-1, keepdims=True))
    g32 = g32 * jax.lax.rsqrt(var + cfg.norm_eps)
    return (g32 * p["norm"].astype(jnp.float32)).astype(dt)


def _pre_ssd(p, x, cfg: ModelConfig, conv_tails=None, valid=None):
    """Shared projection + conv path. Returns SSD inputs and conv tails."""
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xs = jnp.einsum("bsd,de->bse", x, p["w_x"])
    bm = jnp.einsum("bsd,dn->bsn", x, p["w_b"])
    cm = jnp.einsum("bsd,dn->bsn", x, p["w_c"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    xs = constrain(xs, "batch", "seq", "ff")
    tails_in = conv_tails or {"x": None, "b": None, "c": None}
    xs, tx = _causal_conv(xs, p["conv_x"], tails_in["x"], valid)
    bm, tb = _causal_conv(bm, p["conv_b"], tails_in["b"], valid)
    cm, tc = _causal_conv(cm, p["conv_c"], tails_in["c"], valid)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    bm = jax.nn.silu(bm.astype(jnp.float32)).astype(x.dtype)
    cm = jax.nn.silu(cm.astype(jnp.float32)).astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    return z, xs, bm, cm, dt, {"x": tx, "b": tb, "c": tc}


def _post_ssd(p, y, xs_heads, z, cfg: ModelConfig):
    """D-skip, gated RMS norm, out projection. y/xs_heads (B,S,H,P)."""
    b, s, h, pdim = y.shape
    d_skip = p["d_skip"].astype(jnp.float32)
    y = y.astype(jnp.float32) + d_skip[None, None, :, None] * xs_heads.astype(jnp.float32)
    y = y.reshape(b, s, h * pdim)
    gated = y * jax.nn.silu(z.astype(jnp.float32))
    gated = _gated_norm(p, gated.astype(z.dtype), cfg)
    gated = constrain(gated, "batch", "seq", "ff")
    # w_out is row-parallel (d_inner sharded): each shard holds a partial sum
    return psum_tp(jnp.einsum("bse,ed->bsd", gated, p["w_out"]))


def mamba_block(
    p, x, cfg: ModelConfig, *, ssd_impl: str = "xla_chunked", return_cache: bool = False
):
    """Full-sequence Mamba2 block. x (B,S,D) -> y (B,S,D) [, cache]."""
    b, s, d = x.shape
    # head count from the runtime width: under shard_map the block sees the
    # LOCAL d_inner shard, so cfg.ssm_heads would over-count by tp
    pn = cfg.ssm_head_dim
    z, xs, bm, cm, dt, tails = _pre_ssd(p, x, cfg)
    xs_h = xs.reshape(b, s, xs.shape[-1] // pn, pn)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, state = ops.ssd_scan(xs_h, dt, a, bm, cm, chunk=cfg.ssm_chunk, impl=ssd_impl)
    out = _post_ssd(p, y, xs_h, z, cfg)
    if return_cache:
        cache = {"ssm": state, "conv_x": tails["x"], "conv_b": tails["b"], "conv_c": tails["c"]}
        return out, cache
    return out


def mamba_decode(p, x, cache, cfg: ModelConfig, *, ssd_impl: str = "xla_chunked"):
    """One-token Mamba2 step. x (B,1,D); cache {ssm, conv_x, conv_b, conv_c}."""
    b = x.shape[0]
    pn = cfg.ssm_head_dim  # local head count derived below (shard_map-safe)
    tails = {"x": cache["conv_x"], "b": cache["conv_b"], "c": cache["conv_c"]}
    z, xs, bm, cm, dt, tails = _pre_ssd(p, x, cfg, conv_tails=tails)
    xs_h = xs.reshape(b, 1, xs.shape[-1] // pn, pn)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y_t, state = ops.ssd_decode_step(
        cache["ssm"], xs_h[:, 0], dt[:, 0], a, bm[:, 0], cm[:, 0], impl=ssd_impl
    )
    out = _post_ssd(p, y_t[:, None], xs_h, z, cfg)
    new_cache = {
        "ssm": state,
        "conv_x": tails["x"],
        "conv_b": tails["b"],
        "conv_c": tails["c"],
    }
    return out, new_cache


def mamba_prefill_chunk(
    p, x, cache, cfg: ModelConfig, *, valid, ssd_impl: str = "xla_chunked"
):
    """Chunked-prefill Mamba2 block: continue from a carried cache.

    x (B,C,D) is one fixed-size prompt chunk, of which only the first
    ``valid`` positions (scalar, traced ok) are real tokens. The SSD scan
    starts from ``cache["ssm"]`` and the conv streams from the carried
    tails; padded positions are neutralized by forcing their dt to zero —
    exp(0·a) = 1 decay and 0·x update make them exact identities on the
    recurrence — so the returned cache is the state *after the last valid
    token*, ready for the next chunk or the first decode step.
    """
    b, c, _ = x.shape
    pn = cfg.ssm_head_dim  # local head count derived below (shard_map-safe)
    tails = {"x": cache["conv_x"], "b": cache["conv_b"], "c": cache["conv_c"]}
    z, xs, bm, cm, dt, tails = _pre_ssd(p, x, cfg, conv_tails=tails, valid=valid)
    mask = (jnp.arange(c) < valid).astype(dt.dtype)
    dt = dt * mask[None, :, None]
    xs_h = xs.reshape(b, c, xs.shape[-1] // pn, pn)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, state = ops.ssd_scan(
        xs_h, dt, a, bm, cm,
        chunk=cfg.ssm_chunk, impl=ssd_impl, init_state=cache["ssm"],
    )
    out = _post_ssd(p, y, xs_h, z, cfg)
    new_cache = {
        "ssm": state,
        "conv_x": tails["x"],
        "conv_b": tails["b"],
        "conv_c": tails["c"],
    }
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype, abstract: bool = False):
    hn, pn, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    w = cfg.conv_width
    shapes = {
        "ssm": ((batch, hn, pn, n), jnp.float32),
        "conv_x": ((batch, w - 1, cfg.d_inner), dtype),
        "conv_b": ((batch, w - 1, n), dtype),
        "conv_c": ((batch, w - 1, n), dtype),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


MAMBA_CACHE_AXES = {
    "ssm": ("cache_batch", "ssm_heads", None, None),
    "conv_x": ("cache_batch", None, "ff"),
    "conv_b": ("cache_batch", None, None),
    "conv_c": ("cache_batch", None, None),
}
