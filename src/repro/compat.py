"""Small shims over JAX API differences across installed versions.

The repo targets recent JAX, but the container may carry an older release
(e.g. no ``jax.tree.leaves_with_path``, no ``jax.sharding.AxisType``).
Everything here degrades gracefully instead of crashing at import time.
"""

from __future__ import annotations

import jax
import jax.tree_util


def _get_shard_map():
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map.shard_map``."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map

    return shard_map


shard_map = _get_shard_map()


def tree_leaves_with_path(tree, is_leaf=None):
    """``jax.tree.leaves_with_path`` with a tree_util fallback for old JAX."""
    fn = getattr(jax.tree, "leaves_with_path", None)
    if fn is not None:
        return fn(tree, is_leaf=is_leaf)
    return jax.tree_util.tree_leaves_with_path(tree, is_leaf=is_leaf)
