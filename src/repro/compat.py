"""Small shims over JAX API differences across installed versions.

The repo targets recent JAX, but the container may carry an older release
(e.g. no ``jax.tree.leaves_with_path``, no ``jax.sharding.AxisType``).
Everything here degrades gracefully instead of crashing at import time.
"""

from __future__ import annotations

import jax
import jax.tree_util


def _get_shard_map():
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map.shard_map``."""
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map

    return shard_map


shard_map = _get_shard_map()


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication checking off, across jax versions.

    The serving executor's step functions claim replicated (``P()``)
    outputs that the checker cannot always prove replicated (scatters,
    gathered logits); the kwarg disabling the check was renamed
    ``check_rep`` -> ``check_vma`` across jax releases, so probe both.
    """
    for kw in ({"check_rep": False}, {"check_vma": False}):
        try:
            return shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
        except TypeError:
            continue
    # deliberately NO bare fallback: a checker-enabled shard_map would fail
    # later at trace time with an opaque replication error — fail clearly here
    raise TypeError(
        "installed jax accepts neither check_rep nor check_vma on shard_map"
    )


def tree_leaves_with_path(tree, is_leaf=None):
    """``jax.tree.leaves_with_path`` with a tree_util fallback for old JAX."""
    fn = getattr(jax.tree, "leaves_with_path", None)
    if fn is not None:
        return fn(tree, is_leaf=is_leaf)
    return jax.tree_util.tree_leaves_with_path(tree, is_leaf=is_leaf)
