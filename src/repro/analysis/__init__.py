from repro.analysis.hlo import HloCost, analyze_hlo
from repro.analysis.roofline import HW, RooflineTerms, roofline_terms

__all__ = ["HloCost", "analyze_hlo", "HW", "RooflineTerms", "roofline_terms"]
