"""HLO-text cost analyzer with while-loop trip-count correction.

``compiled.cost_analysis()`` counts a while body ONCE (verified in
DESIGN.md §7) — useless for scanned layer stacks. This module parses
``compiled.as_text()`` instead:

  * builds the computation call graph (while body/cond, fusion calls,
    conditionals, to_apply reducers),
  * extracts while trip counts from the loop-condition compare constant,
  * propagates execution multipliers from ENTRY down,
  * sums dot FLOPs (2*O*K from shapes + contracting dims),
  * sums per-op HBM traffic with op-specific rules (DUS counts the slice,
    not the buffer; gathers count output, not the table — see _op_bytes),
  * sums collective bytes by kind (all-reduce / all-gather / reduce-scatter
    / all-to-all / collective-permute), multiplier-corrected.

The numbers are per-DEVICE (SPMD modules are per-device programs).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALL_RE = re.compile(r"(?<![\w\"/])([a-zA-Z][\w\-]*)\(")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*([a-z0-9]+\[[\d,]*\])")


def _parse_op_line(line: str):
    """Split an HLO instruction into (name, type, kind, args, attrs).

    Handles tuple-typed results (parenthesized types) and attrs containing
    parens/quotes by depth-scanning the op's argument list instead of
    trusting a single greedy regex.
    """
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2)
    mc = _CALL_RE.search(rest)
    if not mc:
        return None
    kind = mc.group(1)
    type_str = rest[: mc.start()].strip()
    depth = 0
    end = None
    for i in range(mc.end() - 1, len(rest)):
        ch = rest[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    if end is None:
        return None
    args = rest[mc.end(): end]
    attrs = rest[end + 1:]
    return _Op(name, type_str, kind, args, attrs)


def _shape_bytes(type_str: str) -> float:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []


@dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    args_str: str
    attrs: str


@dataclass
class _Computation:
    name: str
    is_entry: bool
    ops: list[_Op] = field(default_factory=list)
    symbols: dict[str, str] = field(default_factory=dict)  # opname -> type str


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    hbm_bytes_kernelized: float = 0.0
    collective_bytes: dict[str, float] = field(default_factory=dict)
    while_trips: dict[str, int] = field(default_factory=dict)
    dot_flops_by_comp: dict[str, float] = field(default_factory=dict)
    bytes_by_comp: dict[str, float] = field(default_factory=dict)
    collective_count: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def parse_computations(hlo_text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: _Computation | None = None
    for line in hlo_text.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            # op lines have " = "; header param lists only have /*index=N*/
            if m and " = " not in line.split("{")[0]:
                cur = _Computation(name=m.group(2), is_entry=bool(m.group(1)))
                # parameters declared in the header carry shapes
                for pname, ptype in _PARAM_RE.findall(line):
                    cur.symbols[pname] = ptype
                continue
        else:
            if line.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            op = _parse_op_line(line)
            if op is not None:
                cur.ops.append(op)
                cur.symbols[op.name] = op.type_str.strip()
    return comps


def _callees(op: _Op) -> list[tuple[str, str]]:
    """(callee_name, relation) pairs referenced by an op's attrs."""
    out = []
    for rel in ("body", "condition", "calls", "to_apply"):
        for m in re.finditer(rel + r"=%?([\w.\-]+)", op.attrs):
            out.append((m.group(1), rel))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
    if m:
        for name in m.group(1).split(","):
            out.append((name.strip().lstrip("%"), "branch"))
    return out


def _trip_count(cond: _Computation) -> int:
    """Extract the loop bound from the condition's compare-with-constant."""
    consts: dict[str, int] = {}
    for op in cond.ops:
        if op.kind == "constant":
            m = re.fullmatch(r"\s*(-?\d+)\s*", op.args_str)
            if m:
                consts[op.name] = int(m.group(1))
    best = 0
    for op in cond.ops:
        # the bound constant feeds either a compare or a fusion wrapping one
        if op.kind in ("compare", "fusion"):
            for ref in re.findall(r"%([\w.\-]+)", op.args_str):
                if ref in consts:
                    best = max(best, consts[ref])
    if best == 0 and consts:
        best = max(consts.values())
    return max(best, 1)


def _split_args(s: str) -> list[str]:
    """Split an operand list at top-level commas only — commas inside
    brackets/braces/parens (shape dims, layout annotations like
    ``f32[64,128]{1,0}``, nested tuples) don't separate operands."""
    out, cur, depth = [], [], 0
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        out.append(tail)
    return out


def _operand_types(op: _Op, comp: _Computation) -> list[str]:
    """Types of an op's operands (inline-typed or via the symbol table)."""
    out = []
    for a in _split_args(op.args_str):
        m = re.match(r"^([a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+%?[\w.\-]+$", a)
        if m:
            out.append(m.group(1))
            continue
        m = re.match(r"^%?([\w.\-]+)$", a)
        if m and m.group(1) in comp.symbols:
            out.append(comp.symbols[m.group(1)])
    return out


def _dot_flops(op: _Op, comp: _Computation) -> float:
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    ops_types = _operand_types(op, comp)
    if m and ops_types:
        lhs_dims = _shape_dims(ops_types[0])
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


_ZERO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "iota",
}


def _param_effective_bytes(comp: _Computation) -> dict[str, float]:
    """Effective read bytes per parameter of a fusion computation.

    XLA fuses ``dynamic-slice``/``gather`` of the big stacked scan operands
    INTO consumers, so a naive "operand bytes" model charges the full
    stacked array once per loop iteration (~1000x overcount). If every use
    of a parameter inside the fusion is a slice of it, the fusion only
    reads the slice; if a parameter is only the destination of the ROOT
    dynamic-update-slice, it isn't read at all (in-place accumulator).
    """
    # alias chains: bitcast/reshape/copy of a param behave like the param
    alias_of: dict[str, str] = {}
    for op in comp.ops:
        if op.kind in ("bitcast", "reshape", "copy", "transpose"):
            refs = re.findall(r"%([\w.\-]+)", op.args_str)
            if len(refs) == 1:
                alias_of[op.name] = refs[0]

    def base(name: str) -> str:
        seen = set()
        while name in alias_of and name not in seen:
            seen.add(name)
            name = alias_of[name]
        return name

    params = {op.name: op.type_str for op in comp.ops if op.kind == "parameter"}
    uses: dict[str, list[tuple[_Op, int]]] = {p: [] for p in params}
    for op in comp.ops:
        if op.kind == "parameter":
            continue
        refs = re.findall(r"%([\w.\-]+)", op.args_str)
        for pos, r in enumerate(refs):
            b = base(r)
            if b in uses:
                uses[b].append((op, pos))

    out: dict[str, float] = {}
    for pname, ptype in params.items():
        ulist = [u for u in uses[pname] if u[0].kind not in ("bitcast", "reshape", "copy", "transpose")]
        if ulist and all(
            (u.kind == "dynamic-slice" and pos == 0)
            or (u.kind == "gather" and pos == 0)
            for u, pos in ulist
        ):
            out[pname] = sum(_shape_bytes(u.type_str) for u, _ in ulist)
        elif ulist and all(
            u.kind == "dynamic-update-slice" and pos == 0 for u, pos in ulist
        ):
            out[pname] = 0.0  # pure in-place accumulator destination
        else:
            out[pname] = _shape_bytes(ptype)
    return out


def _fusion_output_bytes(comp: _Computation) -> float:
    """Output bytes of a fusion: DUS roots write the slice, not the buffer."""
    root = next((op for op in reversed(comp.ops)), None)
    if root is None:
        return 0.0

    def op_write_bytes(op: _Op) -> float:
        if op.kind == "dynamic-update-slice":
            in_types = _operand_types(op, comp)
            return _shape_bytes(in_types[1]) if len(in_types) > 1 else _shape_bytes(op.type_str)
        return _shape_bytes(op.type_str)

    if root.kind == "tuple":
        by_name = {op.name: op for op in comp.ops}
        total = 0.0
        for r in re.findall(r"%([\w.\-]+)", root.args_str):
            total += op_write_bytes(by_name[r]) if r in by_name else 0.0
        return total
    return op_write_bytes(root)


def _fusion_bytes(op: _Op, comps: dict[str, _Computation]) -> float | None:
    callee = next((n for n, r in _callees(op) if r == "calls"), None)
    if callee is None or callee not in comps:
        return None
    called = comps[callee]
    reads = sum(_param_effective_bytes(called).values())
    writes = _fusion_output_bytes(called)
    return reads + writes


def _op_bytes(op: _Op, comp: _Computation, comps: dict[str, _Computation] | None = None) -> float:
    """Op-specific HBM traffic model (see module docstring)."""
    kind = op.kind
    if kind in _ZERO_TRAFFIC:
        return 0.0
    if kind == "fusion" and comps is not None:
        fb = _fusion_bytes(op, comps)
        if fb is not None:
            return fb
    out_b = _shape_bytes(op.type_str)
    in_types = _operand_types(op, comp)
    in_b = sum(_shape_bytes(t) for t in in_types)
    if kind == "dynamic-update-slice":
        upd = _shape_bytes(in_types[1]) if len(in_types) > 1 else out_b
        return 2.0 * upd
    if kind == "dynamic-slice":
        return 2.0 * out_b
    if kind == "gather":
        idx = _shape_bytes(in_types[1]) if len(in_types) > 1 else 0.0
        return 2.0 * out_b + idx
    if kind == "scatter":
        upd = _shape_bytes(in_types[2]) if len(in_types) > 2 else out_b
        idx = _shape_bytes(in_types[1]) if len(in_types) > 1 else 0.0
        return 2.0 * upd + idx + out_b
    if kind in ("broadcast", "copy", "transpose", "convert", "slice", "pad"):
        return in_b + out_b
    if kind in ("while", "call", "conditional"):
        return 0.0  # bodies are counted via multipliers
    return in_b + out_b


def analyze_hlo(hlo_text: str) -> HloCost:
    comps = parse_computations(hlo_text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # ---- multipliers ----
    mult: dict[str, float] = {entry.name: 1.0}
    fusion_called: set[str] = set()
    trips: dict[str, int] = {}
    order = [entry.name]
    seen = {entry.name}
    while order:
        cname = order.pop(0)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 1.0)
        for op in comp.ops:
            callees = _callees(op)
            trip = 1
            if op.kind == "while":
                cond_name = next((n for n, r in callees if r == "condition"), None)
                if cond_name and cond_name in comps:
                    trip = _trip_count(comps[cond_name])
                    trips[op.name] = trip
            for callee, rel in callees:
                factor = trip if (op.kind == "while" and rel in ("body", "condition")) else 1
                newm = m * factor
                mult[callee] = max(mult.get(callee, 0.0), newm)
                if op.kind == "fusion" and rel == "calls":
                    fusion_called.add(callee)
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)
                elif newm > mult.get(callee, 0.0) - 1e-9:
                    order.append(callee)  # propagate larger multiplier

    cost = HloCost(while_trips=trips)
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None:
            continue  # unreachable (dead) computation
        comp_flops = 0.0
        comp_bytes = 0.0
        for op in comp.ops:
            if op.kind in ("dot", "convolution"):
                comp_flops += _dot_flops(op, comp) * m
            if op.kind in COLLECTIVES:
                b = sum(_shape_bytes(t) for t in _operand_types(op, comp))
                if b == 0.0:
                    b = _shape_bytes(op.type_str)
                cost.collective_bytes[op.kind] = (
                    cost.collective_bytes.get(op.kind, 0.0) + b * m
                )
                cost.collective_count += 1
            if cname not in fusion_called:
                comp_bytes += _op_bytes(op, comp, comps) * m
        cost.bytes_by_comp[cname] = comp_bytes
        cost.hbm_bytes += comp_bytes
        if comp_flops:
            cost.dot_flops_by_comp[cname] = comp_flops
            cost.flops += comp_flops

    # ---- kernelized traffic: innermost scans charged as fused kernels ----
    # An innermost while (no nested while in its body subtree) maps exactly
    # onto a VMEM-resident Pallas kernel: carries/loop-invariants stay in
    # VMEM, so the loop's true HBM traffic is its operands + outputs ONCE
    # (per execution), not per-iteration re-reads. This is the number the
    # TPU target achieves with kernels/flash_attention.py + ssd_scan.py;
    # `hbm_bytes` (as-lowered) is the pure-XLA fallback.
    def subtree(comp_name: str, acc: set[str]):
        if comp_name in acc or comp_name not in comps:
            return
        acc.add(comp_name)
        for op in comps[comp_name].ops:
            for callee, _rel in _callees(op):
                subtree(callee, acc)

    kernelized_delta = 0.0
    for cname, comp in comps.items():
        m = mult.get(cname)
        if m is None or cname in fusion_called:
            continue
        for op in comp.ops:
            if op.kind != "while":
                continue
            body = next((n for n, r in _callees(op) if r == "body"), None)
            cond = next((n for n, r in _callees(op) if r == "condition"), None)
            if body is None:
                continue
            sub: set[str] = set()
            subtree(body, sub)
            if cond:
                subtree(cond, sub)
            if any(o.kind == "while" for s in sub if s in comps for o in comps[s].ops):
                continue  # not innermost
            inside = sum(cost.bytes_by_comp.get(s, 0.0) for s in sub)
            once = (
                sum(_shape_bytes(t) for t in _operand_types(op, comp))
                + _shape_bytes(op.type_str)
            ) * m
            kernelized_delta += inside - min(once, inside)
    cost.hbm_bytes_kernelized = cost.hbm_bytes - kernelized_delta
    return cost
