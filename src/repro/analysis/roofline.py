"""Roofline terms for TPU v5e from an analyzed HLO module.

    compute    = FLOPs_per_chip / peak_flops
    memory     = HBM_bytes_per_chip / hbm_bw
    collective = sum_k coll_bytes_k * ring_factor_k / ici_bw

Hardware constants per the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI. Ring factors: all-reduce moves ~2x its payload on a
ring reduce-scatter+all-gather schedule; the others ~1x. The dominant term
approximates step time at perfect overlap; their sum bounds it without.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.analysis.hlo import HloCost


@dataclass(frozen=True)
class HW:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12       # bf16
    hbm_bw: float = 819e9            # bytes/s
    ici_bw: float = 50e9             # bytes/s per link
    hbm_per_chip: float = 16 * 2**30


RING_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
    "collective-broadcast": 1.0,
}


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float            # with Pallas-kernelized innermost scans (TPU target)
    collective_s: float
    flops: float
    hbm_bytes: float           # kernelized bytes
    collective_bytes: dict[str, float]
    model_flops: float = 0.0   # analytic 6*N*D (per chip), for the waste ratio
    memory_xla_s: float = 0.0  # as-lowered pure-XLA fallback (no kernels)

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-work time / bound time, vs the dominant resource."""
        if self.bound_s == 0:
            return 0.0
        return min(1.0, (self.model_flops / self.flops if self.flops else 0.0)) * (
            self.compute_s / self.bound_s
        )

    def as_row(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_xla_s": self.memory_xla_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def roofline_terms(cost: HloCost, hw: HW = HW(), model_flops_per_chip: float = 0.0) -> RooflineTerms:
    coll_s = sum(
        bytes_ * RING_FACTOR.get(kind, 1.0) / hw.ici_bw
        for kind, bytes_ in cost.collective_bytes.items()
    )
    kb = cost.hbm_bytes_kernelized or cost.hbm_bytes
    return RooflineTerms(
        compute_s=cost.flops / hw.peak_flops,
        memory_s=kb / hw.hbm_bw,
        collective_s=coll_s,
        flops=cost.flops,
        hbm_bytes=kb,
        collective_bytes=dict(cost.collective_bytes),
        model_flops=model_flops_per_chip,
        memory_xla_s=cost.hbm_bytes / hw.hbm_bw,
    )


def model_flops_per_chip(cfg, shape, n_chips: int) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (3x for fwd+bwd), 2*N*D inference;
    MoE uses N_active. D = tokens processed in the step."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens / n_chips
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens / n_chips
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch / n_chips
