"""Logical-axis sharding (MaxText-style rules).

Model code annotates tensors with *logical* axis names ("batch", "embed",
"heads", ...). A rule table maps logical names to mesh axes. The same model
code therefore runs unsharded on one CPU device (smoke tests), on the
single-pod 16x16 mesh, and on the 2x16x16 multi-pod mesh — only the rules and
the mesh change.

Design notes
------------
* ``sharding_context`` is a thread-local context manager; ``constrain`` is a
  no-op outside of it so model code never needs a mesh to run.
* Rules map a logical name to a mesh axis, a tuple of mesh axes (a logical
  dim sharded over several physical axes, e.g. batch over (pod, data)), or
  ``None`` (replicated).
* Unknown logical names are replicated — a deliberate fail-soft so new model
  code works before its rule is tuned (the roofline pass catches the cost).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# The production rule table (see DESIGN.md "Distribution design").
# batch        -> fully data-parallel over both pod and data axes
# embed        -> FSDP (ZeRO-3): weight dims sharded over the data axes
# heads/ff/... -> tensor parallel over the model axis
# experts      -> expert parallel over the model axis
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": ("pod", "data"),      # FSDP shard dim of weights
    "embed_tp": "model",           # activation d_model dim when TP-sharding acts
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "expert_capacity": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "stack": None,                 # scan-stacked layer dim, never sharded
    "cache_batch": ("pod", "data"),
    # flash-decoding-style sequence parallelism: the KV cache shards over
    # "model" on its seq dim (kv_heads rarely divide the model axis); the
    # softmax over sharded seq costs only tiny max/sum all-reduces
    "cache_seq": "model",
}

_CTX = threading.local()


def _get(name: str, default=None):
    return getattr(_CTX, name, default)


@contextmanager
def sharding_context(mesh: Mesh | None, rules: Mapping[str, Any] | None = None):
    """Activate (mesh, rules) for ``constrain`` within model code."""
    prev_mesh, prev_rules = _get("mesh"), _get("rules")
    _CTX.mesh = mesh
    _CTX.rules = dict(rules) if rules is not None else dict(DEFAULT_RULES)
    try:
        yield
    finally:
        _CTX.mesh = prev_mesh
        _CTX.rules = prev_rules


def current_mesh() -> Mesh | None:
    return _get("mesh")


def current_rules() -> dict[str, Any]:
    r = _get("rules")
    return dict(r) if r is not None else dict(DEFAULT_RULES)


def logical_to_spec(
    logical_axes: Sequence[str | None],
    rules: Mapping[str, Any] | None = None,
    mesh: Mesh | None = None,
    dim_sizes: Sequence[int] | None = None,
) -> P:
    """Map a tuple of logical axis names to a PartitionSpec.

    * Drops mesh axes that do not exist on ``mesh`` (so the same rules work
      for the 2D single-pod mesh, the 3D multi-pod mesh, and a 1-device test
      mesh).
    * Never assigns one mesh axis to two tensor dims.
    * If ``dim_sizes`` is given, drops mesh axes that do not divide the dim
      evenly (e.g. kv_heads=8 cannot shard over model=16 -> replicated).
      For multi-axis entries it keeps the longest divisible prefix, so
      batch=32 over ("pod","data")=(2,16) shards fully while batch=1 falls
      back to replicated instead of erroring.
    """
    rules = rules if rules is not None else (_get("rules") or DEFAULT_RULES)
    mesh = mesh if mesh is not None else _get("mesh")
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    axis_size = dict(zip(mesh.axis_names, mesh.shape.values())) if mesh is not None else {}
    used: set[str] = set()
    out = []
    for i, name in enumerate(logical_axes):
        entry = rules.get(name) if name is not None else None
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(
            a for a in axes
            if (mesh_axes is None or a in mesh_axes) and a not in used
        )
        if dim_sizes is not None and mesh is not None and axes:
            dim = dim_sizes[i]
            kept: list[str] = []
            prod = 1
            for a in axes:
                if dim % (prod * axis_size[a]) == 0:
                    kept.append(a)
                    prod *= axis_size[a]
                else:
                    break
            axes = tuple(kept)
        used.update(axes)
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(axes)
    return P(*out)


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint under the ambient (mesh, rules); no-op outside."""
    mesh = _get("mesh")
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes, mesh=mesh, dim_sizes=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def make_shardings(
    axes_tree: Any,
    mesh: Mesh,
    rules: Mapping[str, Any] | None = None,
    shapes_tree: Any = None,
):
    """Map a pytree of logical-axes tuples to a pytree of NamedShardings.

    ``shapes_tree`` (same structure, leaves = shape tuples or arrays /
    ShapeDtypeStructs) enables divisibility-aware dropping.
    """
    rules = rules if rules is not None else DEFAULT_RULES
    is_leaf = lambda v: v is None or (
        isinstance(v, tuple) and all(isinstance(e, (str, type(None))) for e in v)
    )

    def one(axes, shape=None):
        if axes is None:
            return NamedSharding(mesh, P())
        dims = getattr(shape, "shape", shape)
        return NamedSharding(
            mesh, logical_to_spec(axes, rules=rules, mesh=mesh, dim_sizes=dims)
        )

    if shapes_tree is None:
        return jax.tree.map(one, axes_tree, is_leaf=is_leaf)
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_leaf)
