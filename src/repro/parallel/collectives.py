"""Explicit collectives via shard_map: tensor-parallel serving + gradients.

GSPMD inserts collectives implicitly everywhere else in this repo; this
module is where we drop to ``jax.shard_map`` for collectives the compiler
cannot (or should not) synthesize:

* **Tensor-parallel serving reductions.** The serving executor
  (``serving/executor.py``) runs the fused decode/prefill steps under
  ``shard_map`` on a ``("model",)`` mesh with attention heads, MLP ff and
  (untied) unembed columns sharded Megatron-style. Model code marks the
  reduction points with :func:`psum_tp` (row-parallel output projections:
  attention ``wo``, MLP ``w_down``) and :func:`all_gather_logits`
  (column-parallel unembed -> full-vocab logits for sampling). Both are
  IDENTITY outside a :func:`tensor_parallel` context, so the same model
  code runs unsharded (training, lockstep engine, 1-device serving)
  without change.
* **Error-feedback int8-compressed gradient all-reduce**
  (1-bit-Adam-family trick, here at 8 bits).

    g_compressed = quantize_int8(g + error_carry)
    all-reduce(g_compressed)            # 4x fewer wire bytes than fp32
    error_carry = (g + error_carry) - dequant(g_compressed)

The error carry makes the quantization *unbiased over time* — the residual
of step t is re-injected at t+1, so long-run drift vanishes (standard error
feedback / EF-SGD result). Used for the cross-pod (DCN-ish) reduction where
wire bytes hurt most; the carry lives in the train state.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

# ---------------------------------------------------------------------------
# tensor-parallel context (serving executor)
# ---------------------------------------------------------------------------

_TP = threading.local()


@contextmanager
def tensor_parallel(axis: str | None, *, vocab_sharded: bool = False):
    """Declare that the enclosed model code is being traced inside a
    ``shard_map`` over mesh axis ``axis`` with Megatron-style weight
    sharding (heads/kv_heads/ff -> ``axis``; unembed columns too when
    ``vocab_sharded``). :func:`psum_tp` / :func:`all_gather_logits` become
    real collectives inside this context and stay identity outside it.

    ``axis=None`` is an explicit no-op (1-device mesh / unsharded runs
    share the code path). Thread-local, so concurrent serving workers with
    different meshes don't interfere.
    """
    prev = (getattr(_TP, "axis", None), getattr(_TP, "vocab", False))
    _TP.axis, _TP.vocab = axis, vocab_sharded and axis is not None
    try:
        yield
    finally:
        _TP.axis, _TP.vocab = prev


def tp_axis() -> str | None:
    """Mesh axis of the ambient :func:`tensor_parallel` context (or None)."""
    return getattr(_TP, "axis", None)


def psum_tp(x: jax.Array) -> jax.Array:
    """Sum partial products over the tensor-parallel axis.

    Model code calls this exactly where a row-parallel matmul leaves a
    partial sum on each shard (attention output projection, MLP down
    projection, MoE expert down projection). Identity outside a
    :func:`tensor_parallel` context.
    """
    ax = tp_axis()
    return jax.lax.psum(x, ax) if ax is not None else x


def pmean_tp(x: jax.Array) -> jax.Array:
    """Mean over the tensor-parallel axis.

    Normalization layers whose reduction axis is sharded (the Mamba gated
    RMSNorm runs over the ff-sharded ``d_inner`` dim) need the *global*
    mean of squares; since every shard holds an equal-size slice, the
    global mean is exactly the mean of the shard-local means. Identity
    outside a :func:`tensor_parallel` context.
    """
    ax = tp_axis()
    return jax.lax.pmean(x, ax) if ax is not None else x


def all_gather_logits(x: jax.Array) -> jax.Array:
    """Reassemble full-vocab logits from a column-parallel unembed.

    Sampling (greedy argmax / top-k / top-p) needs the whole vocab row, so
    the shard-local logits slice is gathered (tiled) along the last axis.
    Identity outside a :func:`tensor_parallel` context and when the vocab
    dim is replicated (tied embeddings keep the embedding table — and thus
    the logits — replicated; gathering replicated logits would wrongly
    tile them).
    """
    ax = tp_axis()
    if ax is None or not getattr(_TP, "vocab", False):
        return x
    return jax.lax.all_gather(x, ax, axis=x.ndim - 1, tiled=True)


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed psum: quantize locally, sum int32, dequant.

    Wire bytes: 1 byte/elt for the payload (+1 scalar) vs 4 for fp32.
    Scales are max-combined so dequantization is conservative (no overflow:
    the int32 accumulator holds up to 2^23 shards of int8 exactly).
    """
    q, scale = quantize_int8(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is coherent
    q2 = jnp.clip(jnp.round(x / scale_max), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(jnp.float32) * scale_max


def make_compressed_grad_allreduce(mesh: Mesh, axis: str = "data"):
    """Returns ef_allreduce(grads, error_carry) -> (mean_grads, new_carry).

    grads are expected replicated along ``axis``'s orthogonal dims per the
    usual DP layout; each leaf is reduced over ``axis`` with int8 payloads
    and an error-feedback carry of the same shape.
    """
    n = dict(zip(mesh.axis_names, mesh.shape.values()))[axis]

    def _leaf(g, carry, n_shards):
        corrected = g.astype(jnp.float32) + carry
        summed = compressed_psum(corrected, axis)
        mean = summed / n_shards
        # what this shard actually contributed after quantization
        q, scale = quantize_int8(corrected)
        sent = dequantize_int8(q, scale)
        new_carry = corrected - sent
        return mean.astype(g.dtype), new_carry

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(), P(axis)),
    )
    def _reduce_flat(gs, carries):
        # gs: this shard's stacked flat grads (1, N); carries same
        g = gs[0]
        c = carries[0]
        mean, new_c = _leaf(g, c, float(n))
        return mean, new_c[None]

    def ef_allreduce(grad_shards: jax.Array, error_carry: jax.Array):
        """grad_shards: (n_shards, N) — per-DP-shard flat gradients."""
        return _reduce_flat(grad_shards, error_carry)

    return ef_allreduce


def flatten_grads(grads) -> tuple[jax.Array, any]:
    leaves, treedef = jax.tree.flatten(grads)
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return flat, (treedef, [(l.shape, l.dtype) for l in leaves])


def unflatten_grads(flat: jax.Array, meta) -> any:
    treedef, shapes = meta
    out, off = [], 0
    for shape, dtype in shapes:
        n = 1
        for d in shape:
            n *= d
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
