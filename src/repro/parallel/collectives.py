"""Explicit collectives via shard_map: compressed gradient all-reduce.

GSPMD inserts collectives implicitly everywhere else in this repo; this
module is the one place we drop to ``jax.shard_map`` for a collective the
compiler cannot synthesize: **error-feedback int8-compressed gradient
all-reduce** (1-bit-Adam-family trick, here at 8 bits).

    g_compressed = quantize_int8(g + error_carry)
    all-reduce(g_compressed)            # 4x fewer wire bytes than fp32
    error_carry = (g + error_carry) - dequant(g_compressed)

The error carry makes the quantization *unbiased over time* — the residual
of step t is re-injected at t+1, so long-run drift vanishes (standard error
feedback / EF-SGD result). Used for the cross-pod (DCN-ish) reduction where
wire bytes hurt most; the carry lives in the train state.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed psum: quantize locally, sum int32, dequant.

    Wire bytes: 1 byte/elt for the payload (+1 scalar) vs 4 for fp32.
    Scales are max-combined so dequantization is conservative (no overflow:
    the int32 accumulator holds up to 2^23 shards of int8 exactly).
    """
    q, scale = quantize_int8(x)
    scale_max = jax.lax.pmax(scale, axis_name)
    # requantize against the shared scale so the integer sum is coherent
    q2 = jnp.clip(jnp.round(x / scale_max), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q2, axis_name)
    return total.astype(jnp.float32) * scale_max


def make_compressed_grad_allreduce(mesh: Mesh, axis: str = "data"):
    """Returns ef_allreduce(grads, error_carry) -> (mean_grads, new_carry).

    grads are expected replicated along ``axis``'s orthogonal dims per the
    usual DP layout; each leaf is reduced over ``axis`` with int8 payloads
    and an error-feedback carry of the same shape.
    """
    n = dict(zip(mesh.axis_names, mesh.shape.values()))[axis]

    def _leaf(g, carry, n_shards):
        corrected = g.astype(jnp.float32) + carry
        summed = compressed_psum(corrected, axis)
        mean = summed / n_shards
        # what this shard actually contributed after quantization
        q, scale = quantize_int8(corrected)
        sent = dequantize_int8(q, scale)
        new_carry = corrected - sent
        return mean.astype(g.dtype), new_carry

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(), P(axis)),
    )
    def _reduce_flat(gs, carries):
        # gs: this shard's stacked flat grads (1, N); carries same
        g = gs[0]
        c = carries[0]
        mean, new_c = _leaf(g, c, float(n))
        return mean, new_c[None]

    def ef_allreduce(grad_shards: jax.Array, error_carry: jax.Array):
        """grad_shards: (n_shards, N) — per-DP-shard flat gradients."""
        return _reduce_flat(grad_shards, error_carry)

    return ef_allreduce


def flatten_grads(grads) -> tuple[jax.Array, any]:
    leaves, treedef = jax.tree.flatten(grads)
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return flat, (treedef, [(l.shape, l.dtype) for l in leaves])


def unflatten_grads(flat: jax.Array, meta) -> any:
    treedef, shapes = meta
    out, off = [], 0
    for shape, dtype in shapes:
        n = 1
        for d in shape:
            n *= d
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)
