from repro.parallel.axes import (
    DEFAULT_RULES,
    constrain,
    logical_to_spec,
    make_shardings,
    sharding_context,
    current_mesh,
    current_rules,
)

__all__ = [
    "DEFAULT_RULES",
    "constrain",
    "logical_to_spec",
    "make_shardings",
    "sharding_context",
    "current_mesh",
    "current_rules",
]
