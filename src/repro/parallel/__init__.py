from repro.parallel.axes import (
    DEFAULT_RULES,
    constrain,
    logical_to_spec,
    make_shardings,
    sharding_context,
    current_mesh,
    current_rules,
)
from repro.parallel.collectives import (
    all_gather_logits,
    psum_tp,
    tensor_parallel,
    tp_axis,
)

__all__ = [
    "DEFAULT_RULES",
    "all_gather_logits",
    "constrain",
    "logical_to_spec",
    "make_shardings",
    "psum_tp",
    "sharding_context",
    "current_mesh",
    "current_rules",
    "tensor_parallel",
    "tp_axis",
]
