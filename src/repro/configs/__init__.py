"""Registry of the 10 assigned architectures (+ shape suite)."""

from __future__ import annotations

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ShapeConfig,
    cell_supported,
    describe,
    reduced,
)

from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.dbrx_132b import CONFIG as _dbrx
from repro.configs.qwen3_32b import CONFIG as _qwen3
from repro.configs.phi3_medium_14b import CONFIG as _phi3
from repro.configs.smollm_360m import CONFIG as _smollm
from repro.configs.llama3_8b import CONFIG as _llama3
from repro.configs.whisper_tiny import CONFIG as _whisper
from repro.configs.internvl2_26b import CONFIG as _internvl2
from repro.configs.zamba2_2p7b import CONFIG as _zamba2
from repro.configs.mamba2_1p3b import CONFIG as _mamba2

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _grok,
        _dbrx,
        _qwen3,
        _phi3,
        _smollm,
        _llama3,
        _whisper,
        _internvl2,
        _zamba2,
        _mamba2,
    ]
}


def get_arch(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    # tolerate -reduced suffix and _ vs -
    base = name.replace("_", "-").removesuffix("-reduced")
    if base in ARCHS:
        cfg = ARCHS[base]
        return reduced(cfg) if name.endswith("-reduced") else cfg
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ARCHS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "cell_supported",
    "describe",
    "get_arch",
    "get_shape",
    "reduced",
]
