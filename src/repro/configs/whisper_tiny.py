"""whisper-tiny — enc-dec, conv frontend STUB [arXiv:2212.04356; unverified].

The conv frontend is a stub per the assignment: input_specs() provides
precomputed (batch, frames, d_model) frame embeddings to the encoder.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    is_encoder_decoder=True,
    frontend="audio_frames",
    rope_theta=10000.0,
)
