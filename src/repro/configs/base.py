"""Architecture + shape configuration system.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py``; all are registered in ``configs/__init__``.
``reduced()`` derives the smoke-test config for any architecture (same family,
tiny dims). ``ShapeConfig`` defines the four assigned input-shape cells.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    expand: int = 2

    # --- hybrid (Zamba2): shared attention block applied every k SSM layers ---
    attn_every: int = 0

    # --- encoder/decoder ---
    is_encoder_decoder: bool = False

    # --- modality frontend (STUB: input_specs provides embeddings) ---
    frontend: Literal["none", "audio_frames", "vision_patches"] = "none"
    num_frontend_tokens: int = 0

    # --- head padding (perf): pad q/kv heads so they shard over the model
    # axis; extra heads are zero-init in o_proj (output-identical at init).
    # Constraint: padded group size must equal the original (mapping-preserving)
    num_heads_padded: int = 0
    num_kv_heads_padded: int = 0

    # --- misc ---
    qk_norm: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat_policy: str = "nothing"  # nothing|dots|full  (see train/step.py)

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the 512k-context decode cell?"""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def eff_heads(self) -> int:
        if self.num_heads_padded:
            assert self.num_heads_padded % max(self.num_kv_heads_padded or self.num_kv_heads, 1) == 0
            if self.num_kv_heads:
                assert (self.num_heads_padded // (self.num_kv_heads_padded or self.num_kv_heads)
                        == self.num_heads // self.num_kv_heads), "padding must preserve GQA mapping"
            return self.num_heads_padded
        return self.num_heads

    @property
    def eff_kv_heads(self) -> int:
        return self.num_kv_heads_padded or self.num_kv_heads

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic total parameter count (embedding + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp = 3 * d * f  # SwiGLU: gate, up, down
        if self.family == "moe":
            per_layer = attn + self.num_experts * mlp + d * self.num_experts
        elif self.family == "ssm":
            din, n, h = self.d_inner, self.ssm_state, self.ssm_heads
            # in_proj (z,x,B,C,dt) + conv + out_proj (Mamba2)
            per_layer = d * (2 * din + 2 * n + h) + (din + 2 * n) * self.conv_width + din * d
        elif self.family == "hybrid":
            din, n, h = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer = d * (2 * din + 2 * n + h) + (din + 2 * n) * self.conv_width + din * d
        else:
            per_layer = attn + mlp
        total = emb + self.num_layers * per_layer
        if self.family == "hybrid" and self.attn_every:
            # one SHARED attention+mlp block (weights shared across applications)
            total += (attn + mlp)
        if self.is_encoder_decoder:
            # encoder stack (same dims) + cross-attention in decoder
            total += self.num_layers * (attn + mlp)  # encoder layers
            total += self.num_layers * attn          # cross-attn blocks
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        mlp = 3 * d * f
        inactive = self.num_layers * (self.num_experts - self.experts_per_token) * mlp
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


# The assigned shape suite (identical for all 10 LM-family architectures).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Is (arch x shape) runnable? Returns (ok, reason-if-not)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attention: 512k context is quadratic)"
    return True, ""


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test config: same family/topology, tiny dims, CPU-runnable."""
    kw = dict(
        name=cfg.name + "-reduced",
        num_layers=2 if not cfg.attn_every else 2 * max(1, min(cfg.attn_every, 2)),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        dtype="float32",
        rope_theta=cfg.rope_theta,
    )
    if cfg.family in ("moe",):
        kw.update(num_experts=4, experts_per_token=min(2, cfg.experts_per_token))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32, expand=2)
    if cfg.attn_every:
        kw.update(attn_every=2)
    if cfg.num_frontend_tokens:
        kw.update(num_frontend_tokens=8)
    return replace(cfg, **kw)


def describe(cfg: ModelConfig) -> str:
    n = cfg.param_count()
    na = cfg.active_param_count()
    extra = f" (active {na/1e9:.1f}B)" if na != n else ""
    return f"{cfg.name}: {cfg.family}, {cfg.num_layers}L d={cfg.d_model} N={n/1e9:.1f}B{extra}"
