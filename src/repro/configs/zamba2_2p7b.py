"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf].

54 Mamba2 layers; ONE shared transformer (attn+MLP) block whose weights are
re-used at every `attn_every`-th layer (Zamba2's weight-shared global block).
MHA: 32 heads, kv=32, head_dim 80.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    attn_every=6,
    rope_theta=10000.0,
)
