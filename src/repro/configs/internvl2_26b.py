"""internvl2-26b — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

Vision frontend (InternViT) is a STUB per the assignment: input_specs()
provides precomputed (batch, num_frontend_tokens, d_model) patch embeddings,
prepended to the token embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    frontend="vision_patches",
    num_frontend_tokens=256,
    rope_theta=1000000.0,
)
