"""Deterministic chaos harness for the supervised serving fleet.

Drives ``core/faults.py`` worker-kill rules against a live 2–3 worker
:class:`FleetSupervisor` on a mixed submit/cancel/shared-prefix trace and
asserts, across three distinct crash schedules (mid-prefill, mid-decode,
during cancel), the recovery contract from ``serving/fleet.py``:

(a) every request reaches a typed terminal finish reason,
(b) every completed stream is byte-identical to an unperturbed
    single-engine oracle replay of the same trace — no token re-emitted
    or skipped across the crash boundary (the ``responses`` topic carries
    each ``(uid, index)`` exactly once, in order),
(c) requests cancelled around a crash finish ``cancelled``, never hang,
(d) the autoscaler's replica decisions stay inside [min, max] under the
    crash-induced lag spike.

Kills are keyed on each worker's OWN progress counters, checked
synchronously inside the worker loop (``FaultInjector.check_worker``), so
a schedule pins the crash at an exact point in the victim's execution and
every assertion here is independent of thread scheduling.
"""

import time

import numpy as np
import pytest

import jax

from repro.configs import ARCHS, reduced
from repro.core import TopicBus
from repro.core.faults import FaultInjector, WorkerKillRule
from repro.models import build_model
from repro.serving import (
    ContinuousBatchingEngine,
    FleetConfig,
    FleetSupervisor,
    fleet_seed,
    request_from_message,
)

SEED_BASE = 777
ENGINE_KW = dict(max_len=96, max_slots=3, page_size=8, prefill_chunk=8,
                 prefix_sharing=True, seed=0)
TERMINAL = {"length", "stop", "cancelled", "rejected"}


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg)
    return cfg, model.init(jax.random.key(0))


# ---------------------------------------------------------------------------
# trace + oracle
# ---------------------------------------------------------------------------


def _trace(seed: int, n: int = 9) -> list[dict]:
    """Bus-schema payloads: shared 16-token prefix on half, mixed greedy and
    seeded-sampled rows, a few with ``seed=None`` (the supervisor stamps
    those), prompts long enough that prefill spans several chunk-8 steps,
    plus one long-running stream for the mid-decode/cancel arms."""
    rng = np.random.default_rng(seed)
    prefix = [int(x) for x in rng.integers(1, 250, 16)]
    payloads = []
    for i in range(n):
        body = [int(x) for x in rng.integers(1, 250, int(rng.integers(18, 30)))]
        payloads.append({
            "uid": f"c{i}",
            "prompt": (prefix if i % 2 == 0 else []) + body,
            "max_new_tokens": int(rng.integers(3, 7)),
            "temperature": 0.7 if i % 3 == 2 else 0.0,
            "top_k": 8 if i % 3 == 2 else 0,
            "seed": 1000 + i if i % 4 else None,
        })
    payloads.append({
        "uid": "long", "prompt": prefix + [7, 8, 9], "max_new_tokens": 18,
        "temperature": 0.7, "top_k": 8, "seed": 4242,
    })
    return payloads


def _stamped(payloads: list[dict]) -> list[dict]:
    """What the supervisor forwards: unseeded payloads get the deterministic
    ingress-order seed, exactly as ``FleetSupervisor._ingress`` stamps it."""
    out = []
    for i, p in enumerate(payloads):
        q = dict(p)
        if q.get("seed") is None:
            q["seed"] = fleet_seed(SEED_BASE, i)
        out.append(q)
    return out


def _oracle(cfg, params, payloads: list[dict]) -> dict[str, list[int]]:
    """Unperturbed single-engine replay — the byte-identity reference."""
    eng = ContinuousBatchingEngine(cfg, params, **ENGINE_KW)
    handles = {}
    for q in _stamped(payloads):
        h = eng.submit(request_from_message(q))
        assert not h.done, (q["uid"], h.error)
        handles[q["uid"]] = h
    while not eng.idle:
        eng.step()
    return {u: list(h.tokens) for u, h in handles.items()}


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------


def _fleet_cfg() -> FleetConfig:
    return FleetConfig(
        workers=2, min_replicas=1, max_replicas=3,
        target_lag_per_replica=4.0, scale_down_grace_s=0.3,
        beat_interval_s=0.01, seed_base=SEED_BASE, max_restarts=3,
    )


def _make_sup(tmp_path, cfg, params, injector) -> FleetSupervisor:
    bus = TopicBus(tmp_path / "bus")
    return FleetSupervisor(
        bus, lambda: ContinuousBatchingEngine(cfg, params, **ENGINE_KW),
        _fleet_cfg(), injector=injector)


def _poll_until(sup: FleetSupervisor, cond, timeout_s: float = 90.0) -> None:
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        sup.poll()
        if cond():
            return
        time.sleep(0.002)
    raise AssertionError("condition not reached before timeout")


def _owner_name(sup: FleetSupervisor, uid: str) -> str:
    pod_id = sup.states[uid].owner
    assert pod_id is not None
    return pod_id.rsplit("-a", 1)[0]


def _assert_recovered(sup: FleetSupervisor, bus: TopicBus,
                      oracle: dict[str, list[int]],
                      cancelled: set[str] = frozenset()) -> None:
    """The full post-crash invariant sweep: typed terminals, byte-identity
    vs the oracle, exactly-once per-index delivery on ``responses``, zero
    mismatched/gapped deltas, autoscale decisions in bounds."""
    states = sup.results()
    assert set(states) == set(oracle)
    for uid, st in states.items():
        assert st.finish_reason in TERMINAL, (uid, st.finish_reason)
        if uid in cancelled:
            assert st.finish_reason == "cancelled", uid
            assert st.tokens == oracle[uid][:len(st.tokens)], uid
        else:
            assert st.finish_reason in ("length", "stop"), (uid, st.error)
            assert st.tokens == oracle[uid], uid

    # replay-identical recovery: a regenerated token never differed from
    # what was already delivered, and no index was ever skipped
    assert sup.metrics.mismatched_deltas == 0
    assert sup.metrics.gapped_deltas == 0

    # the client-visible stream: per uid, delta indices are exactly
    # range(n), each index exactly once, all before the single finish
    deltas: dict[str, list] = {}
    finishes: dict[str, tuple] = {}
    for m in bus.read("responses"):
        v = m.value
        if v["event"] == "delta":
            deltas.setdefault(v["uid"], []).append(
                (v["index"], v["token"], m.offset))
        else:
            assert v["uid"] not in finishes, f"{v['uid']}: duplicate finish"
            finishes[v["uid"]] = (v, m.offset)
    for uid, st in states.items():
        got = deltas.get(uid, [])
        assert [i for i, _, _ in got] == list(range(len(st.tokens))), uid
        assert [t for _, t, _ in got] == st.tokens, uid
        v, fin_off = finishes[uid]
        assert v["tokens"] == st.tokens, uid
        assert v["finish_reason"] == st.finish_reason, uid
        if got:
            assert max(o for _, _, o in got) < fin_off, (
                f"{uid}: delta published after finish")

    for e in sup.events.history("autoscale"):
        assert 1 <= e["new"] <= sup.cfg.max_replicas, e
        assert 1 <= e["old"] <= sup.cfg.max_replicas, e


# ---------------------------------------------------------------------------
# the three crash schedules
# ---------------------------------------------------------------------------


def test_crash_mid_prefill(smollm, tmp_path):
    """First worker to complete one engine step dies: prompts are 30+
    tokens against a chunk of 8, so one step in the victim has prefilled
    at most one chunk and emitted zero output tokens — a pure mid-prefill
    crash. Its accepted requests replay elsewhere from token 0."""
    cfg, params = smollm
    payloads = _trace(0)
    injector = FaultInjector(
        worker_rules=[WorkerKillRule(after_steps=1, times=1)])
    sup = _make_sup(tmp_path, cfg, params, injector)
    try:
        for p in payloads:
            sup.submit(p)
        assert sup.run(expected=[p["uid"] for p in payloads], timeout_s=180)
    finally:
        sup.shutdown()
    assert injector.kills_armed() == 1
    assert sup.metrics.crashes >= 1
    assert sup.metrics.resubmitted >= 1, "victim owned nothing: no recovery"
    assert any(st.resubmits > 0 for st in sup.states.values())
    _assert_recovered(sup, sup.bus, _oracle(cfg, params, payloads))


def test_crash_mid_decode(smollm, tmp_path):
    """Kill the worker that owns the long-running stream once at least two
    of its tokens have been DELIVERED to the client: recovery must resume
    at exactly the next undelivered index, and the supervisor's dedupe
    must silently absorb the regenerated prefix."""
    cfg, params = smollm
    payloads = _trace(1)
    injector = FaultInjector()  # rule appended once the victim is known
    sup = _make_sup(tmp_path, cfg, params, injector)
    try:
        for p in payloads:
            sup.submit(p)
        sup.start()
        _poll_until(sup, lambda: (
            "long" in sup.states
            and sup.states["long"].owner is not None
            and len(sup.states["long"].tokens) >= 2
            and sup.states["long"].finish_reason is None))
        delivered_at_kill = len(sup.states["long"].tokens)
        injector.worker_rules.append(
            WorkerKillRule(worker=_owner_name(sup, "long"), after_steps=0,
                           times=1))
        assert sup.run(expected=[p["uid"] for p in payloads], timeout_s=180)
    finally:
        sup.shutdown()
    assert injector.kills_armed() == 1
    assert sup.metrics.crashes >= 1
    long = sup.states["long"]
    assert long.resubmits >= 1, "owner survived: kill rule never fired"
    assert long.resume_from >= delivered_at_kill >= 2
    assert long.recovery_s is not None and long.recovery_s >= 0.0
    assert sup.metrics.recovery_s, "resumption latency not recorded"
    # the replacement regenerated the already-delivered prefix and the
    # supervisor dropped every regenerated token
    assert sup.metrics.duplicate_deltas >= delivered_at_kill
    _assert_recovered(sup, sup.bus, _oracle(cfg, params, payloads))


def test_crash_during_cancel(smollm, tmp_path):
    """Cancel the long stream, then immediately kill its owner: whether the
    victim processed the cancel before dying or the supervisor finished
    the orphaned cancel directly, the request must terminate ``cancelled``
    with an oracle-prefix stream — and must never be resurrected by the
    resubmit path or hang."""
    cfg, params = smollm
    payloads = _trace(2)
    injector = FaultInjector()
    sup = _make_sup(tmp_path, cfg, params, injector)
    try:
        for p in payloads:
            sup.submit(p)
        sup.start()
        _poll_until(sup, lambda: (
            "long" in sup.states
            and sup.states["long"].owner is not None
            and sup.states["long"].finish_reason is None))
        assert sup.cancel("long")
        injector.worker_rules.append(
            WorkerKillRule(worker=_owner_name(sup, "long"), after_steps=0,
                           times=1))
        assert sup.run(expected=[p["uid"] for p in payloads], timeout_s=180)
    finally:
        sup.shutdown()
    assert injector.kills_armed() == 1
    assert sup.metrics.crashes >= 1
    assert sup.states["long"].resubmits == 0, "cancelled request resubmitted"
    _assert_recovered(sup, sup.bus, _oracle(cfg, params, payloads),
                      cancelled={"long"})
