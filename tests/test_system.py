"""End-to-end behaviour of the full Jup2Kub system (paper pipeline).

The notebook -> split -> deploy -> schedule -> recover loop, and the
fault-tolerant training workflow with chaos injection — compressed versions
of examples/ so the suite stays fast.
"""

import argparse
import json
from pathlib import Path

import pytest

from repro.core import (
    ArtifactStore, Notebook, TopicBus, WorkflowScheduler, split_pipeline,
)
from repro.core.autoscaler import Autoscaler, AutoscalerConfig
from repro.core.faults import FaultInjector, KillRule
from repro.core.scheduler import RetryPolicy


SCI_NOTEBOOK = [
    "import math\nraw = [i * 0.5 for i in range(200)]",
    "clean = [v for v in raw if v % 7 != 0]",
    "# %%pipe\nstats = (sum(clean), len(clean))",
    "norm = [v / stats[0] for v in clean]",
    "report = ('mean', stats[0] / stats[1])",
]


def test_notebook_to_k8s_end_to_end(tmp_path):
    """The paper's full promise: linear notebook in, fault-tolerant
    distributed execution out, same results, k8s manifests rendered."""
    nb = Notebook.from_sources(SCI_NOTEBOOK, name="sci")
    linear = nb.run_linear()
    g = split_pipeline(nb)
    assert len(g.steps) >= 3  # actually distributed

    bus = TopicBus(tmp_path / "bus")
    store = ArtifactStore(tmp_path / "store")
    first = sorted(g.steps)[0]
    faults = FaultInjector([KillRule(step=first, after_s=0.0, times=1)])
    sched = WorkflowScheduler(
        g, bus, store, retry=RetryPolicy(max_attempts=4, backoff_s=0.01),
        fault_injector=faults)
    arts = sched.run(timeout_s=60)
    assert arts["report"] == linear["report"]

    from repro.core.deployer import DynamicPodDeployer, PodManager
    dep = DynamicPodDeployer(PodManager(g), out_dir=tmp_path / "k8s")
    specs = dep.deploy_all()
    assert len(list((tmp_path / "k8s").glob("*-deployment.yaml"))) == len(g.steps)
    roles = {s.role for s in specs}
    assert "producer" in roles and "consumer" in roles


@pytest.mark.slow
def test_fault_tolerant_training_with_chaos(tmp_path):
    """Chaos kills the train pod twice; checkpoint/restart must finish the
    run and the loss must improve (learnable synthetic corpus)."""
    from repro.launch.train import build_workflow

    args = argparse.Namespace(
        arch="smollm-360m", reduced=True, steps=30, batch=8, seq_len=32,
        ga=1, lr=3e-3, seed=0, ckpt_every=6,
    )
    workdir = tmp_path / "run"
    workdir.mkdir()
    graph = build_workflow(args, workdir)
    bus = TopicBus(tmp_path / "bus")
    store = ArtifactStore(tmp_path / "store")
    claim = store.claim("ckpt")
    faults = FaultInjector([KillRule(step="train", after_s=0.8, times=2)])
    sched = WorkflowScheduler(
        graph, bus, store, workflow="ft-train",
        retry=RetryPolicy(max_attempts=6, backoff_s=0.05),
        liveness_window_s=30.0, fault_injector=faults,
        claim_paths={"train": str(claim.path)},
    )
    arts = sched.run(timeout_s=600)
    rep = arts["report"]
    assert rep["improved"], rep
    # the train step was actually killed and retried
    kinds = [e["kind"] for e in sched.events.history()]
    assert kinds.count("step_retry_scheduled") >= 1
    # checkpoints exist in the claimed volume (PVC analogue)
    assert any(claim.path.glob("step_*/MANIFEST.json"))


def test_autoscaler_scales_with_lag(tmp_path):
    bus = TopicBus(tmp_path)
    scaler = Autoscaler(
        bus, "reqs", "g",
        AutoscalerConfig(min_replicas=1, max_replicas=4,
                         target_lag_per_replica=5, scale_down_grace_s=0.0))
    assert scaler.observe() == (1, False)
    for i in range(20):
        bus.publish("reqs", i)
    desired, changed = scaler.observe()
    assert (desired, changed) == (4, True)
    bus.commit("reqs", "g", 20)  # consumers caught up
    desired, changed = scaler.observe()
    assert desired == 1 and changed


def test_heartbeat_liveness_cycle(tmp_path):
    import time

    from repro.core.probes import HealthMonitor, HeartbeatWriter

    bus = TopicBus(tmp_path)
    mon = HealthMonitor(bus, liveness_window_s=0.2)
    hb = HeartbeatWriter(bus, "pod1")
    assert mon.status("pod1") == "unknown"
    hb.ready()
    hb.beat(progress=1)
    assert mon.status("pod1") == "live"
    time.sleep(0.3)
    assert mon.status("pod1") == "dead"
    assert mon.dead_pods() == ["pod1"]
    hb.beat(progress=2)
    assert mon.status("pod1") == "live"
    assert mon.progress("pod1") == 2


def test_serving_api_surface_matches_snapshot():
    """The public serving surface must match the reviewed snapshot
    (``tools/serving_api.txt``) — the kernel-dispatch rework must add ZERO
    drift, since ``attn_impl`` was already on the engine signature and the
    ops layer is not part of ``repro.serving``. Intentional changes go
    through ``tools/check_api.py --update`` in the same PR."""
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "check_api", Path(__file__).parent.parent / "tools" / "check_api.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.render() == mod.SNAPSHOT.read_text(), (
        "public serving surface drifted from tools/serving_api.txt; "
        "run tools/check_api.py --update and review the diff"
    )
