"""Trainer: learning works, ga is equivalence-preserving, resume is exact."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.data import DataConfig, PrefetchLoader, SyntheticCorpus
from repro.models import build_model
from repro.train import AdamWConfig, init_train_state, make_train_step

CFG = dataclasses.replace(
    reduced(ARCHS["smollm-360m"]), num_layers=2, d_model=128, num_heads=4,
    num_kv_heads=2, head_dim=32, d_ff=256,
)
# lr 6e-3: at 3e-3 this 2-layer toy model's 150-step loss drop sat right at
# the 0.5 threshold and flaked with backend/version float drift
OPT = AdamWConfig(lr=6e-3, warmup_steps=5, decay_steps=5000,
                  weight_decay=0.0, moment_dtype="float32")


def data(batch=16, seq=64, seed=1):
    return SyntheticCorpus(DataConfig(vocab_size=CFG.vocab_size, seq_len=seq,
                                      global_batch=batch, seed=seed))


def test_loss_decreases_within_150_steps():
    model = build_model(CFG)
    state = init_train_state(model, jax.random.key(0), OPT)
    step = jax.jit(make_train_step(model, OPT, ga=1), donate_argnums=(0,))
    corpus = data()
    losses = []
    for i in range(150):
        state, m = step(state, {k: jnp.asarray(v) for k, v in corpus.batch_at(i).items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_ga_equivalence():
    """ga=2 must produce (nearly) the same update as ga=1 on the same data."""
    model = build_model(CFG)
    corpus = data(batch=8)
    batch = {k: jnp.asarray(v) for k, v in corpus.batch_at(0).items()}
    s1 = init_train_state(model, jax.random.key(0), OPT)
    s2 = jax.tree.map(jnp.copy, s1)
    st1, m1 = jax.jit(make_train_step(model, OPT, ga=1))(s1, batch)
    st2, m2 = jax.jit(make_train_step(model, OPT, ga=2))(s2, batch)
    # microbatch statistics differ slightly (loss is mean-of-means) but the
    # resulting params must agree to float tolerance
    for a, b in zip(jax.tree.leaves(st1["params"]), jax.tree.leaves(st2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5, rtol=5e-4)


def test_resume_determinism(tmp_path):
    """train(10) == train(5) -> ckpt -> restore -> train(5)."""
    from repro.checkpoint import CheckpointManager

    model = build_model(CFG)
    corpus = data(batch=4)
    step = jax.jit(make_train_step(model, OPT, ga=1))

    def batches(i):
        return {k: jnp.asarray(v) for k, v in corpus.batch_at(i).items()}

    sA = init_train_state(model, jax.random.key(0), OPT)
    for i in range(10):
        sA, _ = step(sA, batches(i))

    sB = init_train_state(model, jax.random.key(0), OPT)
    for i in range(5):
        sB, _ = step(sB, batches(i))
    ck = CheckpointManager(tmp_path)
    ck.save(5, sB)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), sB)
    sB, _ = ck.restore(like)
    sB = jax.tree.map(jnp.asarray, sB)
    for i in range(5, 10):
        sB, _ = step(sB, batches(i))

    for a, b in zip(jax.tree.leaves(sA), jax.tree.leaves(sB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_clipping_caps_update():
    from repro.train.optimizer import global_norm, make_optimizer

    opt_init, opt_update = make_optimizer(
        AdamWConfig(lr=1.0, clip_norm=1e-3, warmup_steps=0, weight_decay=0.0,
                    moment_dtype="float32"))
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.full((4, 4), 100.0)}
    st = opt_init(params)
    newp, _, metrics = opt_update(grads, st, params, jnp.asarray(0))
    assert float(metrics["grad_norm"]) == pytest.approx(400.0)
    assert np.abs(np.asarray(newp["w"]) - 1.0).max() < 1.1  # clipped step


def test_adamw_matches_reference_math():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      warmup_steps=0, decay_steps=10**9, moment_dtype="float32",
                      clip_norm=1e9)
    from repro.train.optimizer import make_optimizer
    opt_init, opt_update = make_optimizer(cfg)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.25])}
    st = opt_init(p)
    newp, newst, _ = opt_update(g, st, p, jnp.asarray(0))
    m = 0.1 * np.asarray([0.5, 0.25])
    v = 0.01 * np.asarray([0.25, 0.0625])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.asarray([1.0, -2.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)


def test_prefetch_loader_resumes():
    corpus = data(batch=2, seq=16)
    loader = PrefetchLoader(corpus, start_step=3, depth=2)
    step, batch = next(loader)
    assert step == 3
    np.testing.assert_array_equal(batch["tokens"], corpus.batch_at(3)["tokens"])
    step2, _ = next(loader)
    assert step2 == 4
    loader.close()


def test_data_deterministic_across_instances():
    c1, c2 = data(seed=9), data(seed=9)
    b1, b2 = c1.batch_at(17), c2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = data(seed=10).batch_at(17)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
