"""Admission policies: FIFO / priority / deadline ordering + engine plumbing.

Unit tests exercise the policies directly (push/pop/requeue/remove/expiry);
the integration tests plug them into a real engine and observe completion
order through the protocol event stream.
"""

import jax
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serving import (
    ContinuousBatchingEngine,
    DeadlineAdmission,
    FIFOAdmission,
    FinishReason,
    PriorityAdmission,
    Request,
)


def _req(uid, priority=0, deadline_s=None):
    return Request(uid, [1, 2, 3], max_new_tokens=4, priority=priority,
                   deadline_s=deadline_s)


# ---------------------------------------------------------------------------
# policy units
# ---------------------------------------------------------------------------


def test_fifo_orders_by_arrival_and_requeues_front():
    p = FIFOAdmission()
    p.push(_req("a"), 1.0)
    p.push(_req("b"), 2.0)
    assert p.peek(9.0).uid == "a"
    a = p.pop(9.0)
    p.requeue(a, 1.0)  # preempted: back to the front, not the tail
    assert [p.pop(9.0).uid for _ in range(len(p))] == ["a", "b"]


def test_fifo_remove_supports_queued_cancellation():
    p = FIFOAdmission()
    for u in ("a", "b", "c"):
        p.push(_req(u), 0.0)
    assert p.remove("b").uid == "b"
    assert p.remove("b") is None
    assert [p.pop(0.0).uid for _ in range(len(p))] == ["a", "c"]


def test_priority_orders_by_priority_then_arrival():
    p = PriorityAdmission()
    p.push(_req("low1", priority=0), 0.0)
    p.push(_req("high", priority=5), 0.0)
    p.push(_req("low2", priority=0), 0.0)
    assert [p.pop(0.0).uid for _ in range(len(p))] == ["high", "low1", "low2"]


def test_priority_requeue_beats_equal_priority_arrivals():
    p = PriorityAdmission()
    p.push(_req("a", priority=1), 0.0)
    p.push(_req("b", priority=1), 0.0)
    a = p.pop(0.0)
    p.requeue(a, 0.0)  # preempted: ahead of b despite equal priority
    assert p.peek(0.0).uid == "a"


def test_priority_lazy_removal():
    p = PriorityAdmission()
    p.push(_req("a", priority=9), 0.0)
    p.push(_req("b", priority=1), 0.0)
    assert p.remove("a").uid == "a"
    assert len(p) == 1
    assert p.peek(0.0).uid == "b"
    assert p.remove("zzz") is None


def test_deadline_edf_order_and_expiry():
    p = DeadlineAdmission()
    p.push(_req("slack", deadline_s=100.0), 0.0)
    p.push(_req("tight", deadline_s=1.0), 0.0)
    p.push(_req("whenever"), 0.0)  # no deadline: sorts last
    assert p.peek(0.5).uid == "tight"
    expired = p.take_expired(5.0)  # tight's deadline (t=1.0) has lapsed
    assert [r.uid for r in expired] == ["tight"]
    assert [p.pop(5.0).uid for _ in range(len(p))] == ["slack", "whenever"]


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, params


def test_priority_admission_schedules_high_first(smollm):
    """With one decode slot busy, a later high-priority request overtakes
    earlier queued low-priority ones."""
    cfg, params = smollm
    eng = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=1,
                                   page_size=8, admission=PriorityAdmission())
    eng.submit(Request("busy", [1, 2, 3], max_new_tokens=4))
    eng.step()  # occupies the only slot
    eng.submit(Request("low", [4, 5, 6], max_new_tokens=2, priority=0))
    eng.submit(Request("high", [7, 8, 9], max_new_tokens=2, priority=5))
    order = []
    while not eng.idle:
        order.extend(ev.uid for ev in eng.step() if ev.kind == "finish")
    assert order.index("high") < order.index("low")


def test_deadline_admission_rejects_lapsed_requests(smollm):
    """A queued request whose deadline lapses before admission finishes
    ``rejected`` (typed) instead of wasting a decode slot."""
    cfg, params = smollm
    eng = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=1,
                                   page_size=8, admission=DeadlineAdmission())
    eng.submit(Request("busy", [1, 2, 3], max_new_tokens=8))
    eng.step()  # slot taken; queued work must wait
    doomed = eng.submit(Request("doomed", [4, 5, 6], max_new_tokens=2,
                                deadline_s=0.0))
    patient = eng.submit(Request("patient", [7, 8, 9], max_new_tokens=2))
    while not eng.idle:
        eng.step()
    assert doomed.finish_reason == FinishReason.REJECTED
    assert "deadline" in doomed.error
    assert patient.finish_reason == FinishReason.LENGTH
    # deadline drops are recorded like every other rejection
    assert eng.stats["rejected"] == 1
    assert ("doomed", doomed.error) in eng.drain_rejections()
