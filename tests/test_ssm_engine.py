"""SSM/hybrid engine unit suite: slot-state lifecycle and SSD op parity.

Complements the protocol-conformance suite (which runs the SSM engine
through the engine-agnostic contract) with the recurrent-state specifics:
the SlotStateBank's alloc/free/snapshot/restore lifecycle, byte-identical
streams across both preemption flavors (discard + re-prefill, and
snapshot + resume), the hybrid engine's paged-attention/state-bank split,
and single-token equivalence between the fused ``ops.ssd_decode_step``
recurrence and the chunked ``ops.ssd_scan`` it must agree with.

CI also runs this file under the forced 4-device mesh job: the engines
pick their tensor-parallel degree from the visible devices, so the same
assertions cover the sharded executor (state bank sharded on ssm_heads,
replicated tables) without any test changes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.kernels import ops
from repro.models import build_model
from repro.serving import (
    FinishReason,
    GenerationEngine,
    Request,
    SamplingParams,
    SlotStateBank,
    SSMEngine,
)


@pytest.fixture(scope="module")
def mamba2():
    cfg = reduced(ARCHS["mamba2-1.3b"])
    model = build_model(cfg)
    return cfg, model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def zamba2():
    cfg = reduced(ARCHS["zamba2-2.7b"])
    model = build_model(cfg)
    return cfg, model.init(jax.random.key(0))


def drain(engine):
    while not engine.idle:
        engine.step()


SAMPLED = SamplingParams(temperature=0.9, seed=7, max_new_tokens=10, top_k=30)


# ---------------------------------------------------------------------------
# SlotStateBank lifecycle
# ---------------------------------------------------------------------------


def test_bank_snapshot_restore_roundtrip(mamba2):
    """snapshot() then restore() is exact (bit-level) and touches only the
    target slot."""
    cfg, _ = mamba2
    bank = SlotStateBank(cfg, max_slots=4, dtype=jnp.dtype(cfg.dtype))
    rng = np.random.default_rng(0)
    bank.commit({
        k: jnp.asarray(rng.normal(size=v.shape), v.dtype)
        for k, v in bank.state.items()
    })
    before = {k: np.asarray(v) for k, v in bank.state.items()}
    snap = bank.snapshot(2)
    for k, v in snap.items():
        assert v.shape == before[k][:, 2].shape
        np.testing.assert_array_equal(v, before[k][:, 2])

    # clobber slot 2, restore, and compare the WHOLE bank bit-for-bit
    bank.commit({k: v.at[:, 2].set(0) for k, v in bank.state.items()})
    bank.restore(2, snap)
    for k, v in bank.state.items():
        np.testing.assert_array_equal(np.asarray(v), before[k])


def test_slot_alloc_release_cycle(mamba2):
    """Slots recycle through admission pressure: more requests than slots
    all finish, and every slot returns to the free list at drain."""
    cfg, params = mamba2
    eng = SSMEngine(cfg, params, max_len=64, max_slots=2)
    assert eng.capacity() == 2
    hs = [eng.submit(Request(f"r{i}", [1 + i, 2, 3], max_new_tokens=4))
          for i in range(5)]
    drain(eng)
    assert all(h.finish_reason == FinishReason.LENGTH for h in hs)
    assert sorted(eng._free) == [0, 1]
    assert eng.capacity() == 2 and not eng.slots and not eng._snapshots


def test_fresh_slot_never_leaks_previous_state(mamba2):
    """A recycled slot's prefill starts from zero state: the same request
    streams identically whether it runs on a fresh engine or on a slot
    that previously served a different sequence."""
    cfg, params = mamba2
    fresh = SSMEngine(cfg, params, max_len=64, max_slots=1)
    want = fresh.generate([Request("w", [5, 6, 7], max_new_tokens=6)])[0]
    eng = SSMEngine(cfg, params, max_len=64, max_slots=1)
    eng.generate([Request("dirty", [200, 201, 202, 203], max_new_tokens=8)])
    got = eng.generate([Request("w", [5, 6, 7], max_new_tokens=6)])[0]
    assert got.tokens == want.tokens


# ---------------------------------------------------------------------------
# preemption flavors: byte-identical streams
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sampling", ["greedy", "seeded"])
def test_discard_preemption_reprefills_byte_identical(mamba2, sampling):
    cfg, params = mamba2
    sp = (SamplingParams(max_new_tokens=10) if sampling == "greedy"
          else SAMPLED)
    eng = SSMEngine(cfg, params, max_len=64, max_slots=2)
    oracle = eng.generate([Request("o", [9, 8, 7, 6], sampling=sp)])[0]
    h = eng.submit(Request("p", [9, 8, 7, 6], sampling=sp))
    while len(h.tokens) < 4:
        eng.step()
    seen = list(h.tokens)
    assert eng.preempt_youngest() == "p"
    drain(eng)
    assert eng.stats["preemptions"] == 1
    assert eng.stats["restores"] == 0  # discard flavor re-prefills
    assert h.tokens[:len(seen)] == seen  # no re-emission, no gap
    assert h.tokens == oracle.tokens


@pytest.mark.parametrize("sampling", ["greedy", "seeded"])
def test_snapshot_preemption_resumes_byte_identical(mamba2, sampling):
    """snapshot=True parks the slot's state and the sequence resumes
    decoding WITHOUT re-prefilling — same stream, zero extra prefill
    chunks after the eviction."""
    cfg, params = mamba2
    sp = (SamplingParams(max_new_tokens=10) if sampling == "greedy"
          else SAMPLED)
    eng = SSMEngine(cfg, params, max_len=64, max_slots=2)
    oracle = eng.generate([Request("o", [9, 8, 7, 6], sampling=sp)])[0]
    h = eng.submit(Request("p", [9, 8, 7, 6], sampling=sp))
    while len(h.tokens) < 4:
        eng.step()
    chunks_before = eng.stats["prefill_chunks"]
    assert eng.preempt_youngest(snapshot=True) == "p"
    assert "p" in eng._snapshots
    drain(eng)
    assert eng.stats["restores"] == 1
    assert eng.stats["prefill_chunks"] == chunks_before, "snapshot re-prefilled"
    assert not eng._snapshots, "parked snapshot leaked"
    assert h.tokens == oracle.tokens


def test_snapshot_preemption_rejected_on_hybrid(zamba2):
    cfg, params = zamba2
    eng = SSMEngine(cfg, params, max_len=64, max_slots=2, page_size=8)
    eng.submit(Request("h", [1, 2, 3], max_new_tokens=8))
    while not eng._has_decodable():
        eng.step()
    with pytest.raises(ValueError, match="pure-SSM"):
        eng.preempt_youngest(snapshot=True)
    eng.abort_all()
    drain(eng)


def test_preempt_youngest_picks_newest_decoder(mamba2):
    cfg, params = mamba2
    eng = SSMEngine(cfg, params, max_len=64, max_slots=3)
    old = eng.submit(Request("old", [1, 2, 3], max_new_tokens=30))
    while not old.tokens:
        eng.step()
    young = eng.submit(Request("young", [4, 5, 6], max_new_tokens=30))
    while not young.tokens:
        eng.step()
    assert eng.preempt_youngest() == "young"
    eng.abort_all()
    drain(eng)


# ---------------------------------------------------------------------------
# hybrid: paged attention + state bank in one step
# ---------------------------------------------------------------------------


def test_hybrid_serves_and_reclaims_pages(zamba2):
    cfg, params = zamba2
    eng = SSMEngine(cfg, params, max_len=64, max_slots=3, page_size=8)
    hs = [eng.submit(Request(f"r{i}", [1 + i, 2, 3], max_new_tokens=5))
          for i in range(4)]
    drain(eng)
    assert all(h.finish_reason == FinishReason.LENGTH for h in hs)
    assert eng.cache.pool.available == eng.cache.num_pages - 1
    assert eng.cache.free_slot_count == 3


def test_hybrid_page_pressure_preempts_and_recovers(zamba2):
    """A pool too small for the full batch forces organic youngest-first
    preemption during decode; every stream still finishes byte-identical
    to an unpressured run."""
    cfg, params = zamba2
    kw = dict(max_len=64, max_slots=3, page_size=8, prefill_chunk=8)
    roomy = SSMEngine(cfg, params, **kw)
    reqs = [Request(f"r{i}", [10 + i] + list(range(2, 12)), max_new_tokens=8)
            for i in range(3)]
    oracle = {r.uid: roomy.generate([Request(r.uid, list(r.prompt),
                                             sampling=r.sampling)])[0]
              for r in reqs}
    tight = SSMEngine(cfg, params, num_pages=7, **kw)
    hs = [tight.submit(Request(r.uid, list(r.prompt), sampling=r.sampling))
          for r in reqs]
    drain(tight)
    assert tight.stats["preemptions"] > 0, "pool pressure never preempted"
    for h in hs:
        assert h.finish_reason == FinishReason.LENGTH
        assert h.tokens == oracle[h.uid].tokens, h.uid


def test_hybrid_rejects_unschedulable_request(zamba2):
    cfg, params = zamba2
    eng = SSMEngine(cfg, params, max_len=256, max_slots=2, page_size=8,
                    num_pages=4)
    h = eng.submit(Request("big", list(range(1, 100)), max_new_tokens=50))
    assert h.finish_reason == FinishReason.REJECTED
    assert "pages" in h.error


# ---------------------------------------------------------------------------
# ops.ssd_decode_step == ops.ssd_scan on a single token
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("impl", ["xla_chunked", "pallas_interpret"])
def test_ssd_decode_step_matches_scan_single_token(seed, impl):
    """The fused decode recurrence must agree with a length-1 ssd_scan
    continued from the same carried state — the exact contract the engine
    relies on when a sequence crosses from chunked prefill into decode."""
    rng = np.random.default_rng(seed)
    b, h, p, n = 2, 3, 8, 4
    state = jnp.asarray(rng.normal(size=(b, h, p, n)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(b, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 1.0, size=(b, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.1, 1.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)

    y_step, s_step = ops.ssd_decode_step(state, x, dt, A, B, C, impl=impl)
    # the scan path takes (B, S, H, P) tokens and per-position dt
    y_scan, s_scan = ops.ssd_scan(
        x[:, None], dt[:, None], A, B[:, None], C[:, None],
        chunk=4, impl="naive", init_state=state,
    )
    np.testing.assert_allclose(np.asarray(y_step),
                               np.asarray(y_scan[:, 0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_step), np.asarray(s_scan),
                               rtol=1e-5, atol=1e-5)


def test_engine_decode_matches_whole_prompt_prefill(mamba2):
    """End-to-end cross-check of the same contract inside the engine: a
    one-chunk prefill of prompt+k tokens must reach the same stream as
    decoding those k tokens one step at a time (greedy)."""
    cfg, params = mamba2
    base = SSMEngine(cfg, params, max_len=64, max_slots=2)
    long = base.generate([Request("l", [3, 1, 4, 1, 5], max_new_tokens=8)])[0]
    # feed prompt + the first 4 generated tokens as a prompt: the remaining
    # stream must continue exactly (pure function of the token history)
    cont = base.generate([Request("c", [3, 1, 4, 1, 5] + long.tokens[:4],
                                  max_new_tokens=4)])[0]
    assert cont.tokens == long.tokens[4:]


# ---------------------------------------------------------------------------
# cross-engine: SSM continuous batching vs the lockstep baseline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["mamba2", "zamba2"])
def test_ssm_engine_matches_lockstep_greedy(arch, mamba2, zamba2):
    """Greedy streams are engine-invariant: the recurrent-state engine and
    the lockstep baseline must produce identical tokens for the same
    prompts (same math, different batching)."""
    cfg, params = mamba2 if arch == "mamba2" else zamba2
    reqs = [Request(f"r{i}", [1 + i, 2, 3 + i], max_new_tokens=6)
            for i in range(3)]
    ssm = SSMEngine(cfg, params, max_len=64, max_slots=3)
    lock = GenerationEngine(cfg, params, max_len=64, max_batch=3)
    a = ssm.generate([Request(r.uid, list(r.prompt), sampling=r.sampling)
                      for r in reqs])
    b = lock.generate([Request(r.uid, list(r.prompt), sampling=r.sampling)
                       for r in reqs])
    for ra, rb in zip(a, b):
        assert ra.tokens == rb.tokens, ra.uid
