"""Compressed gradient all-reduce: unbiasedness via error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh
from repro.parallel.collectives import (
    compressed_psum,
    dequantize_int8,
    flatten_grads,
    make_compressed_grad_allreduce,
    quantize_int8,
    unflatten_grads,
)


def test_quantize_roundtrip_error_bounded(rng):
    x = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    assert err.max() <= float(scale) * 0.5 + 1e-7  # half-ULP rounding


def test_flatten_unflatten_roundtrip(rng):
    tree = {"a": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32),
            "b": {"c": jnp.asarray(rng.standard_normal(7), jnp.float32)}}
    flat, meta = flatten_grads(tree)
    back = unflatten_grads(flat, meta)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_error_feedback_unbiased_over_steps(rng):
    """Repeatedly reducing the SAME gradients with error feedback must
    converge: the cumulative mean of compressed reductions approaches the
    exact mean (the EF carry re-injects quantization residuals)."""
    mesh = make_host_mesh()
    n = mesh.shape["data"]
    ef = make_compressed_grad_allreduce(mesh, "data")
    g = jnp.asarray(rng.standard_normal((n, 512)), jnp.float32)
    exact = np.asarray(jnp.mean(g, axis=0))
    carry = jnp.zeros_like(g)
    acc = np.zeros_like(exact)
    steps = 20
    for _ in range(steps):
        mean, carry = ef(g, carry)
        acc += np.asarray(mean)
    avg = acc / steps
    # single-shot error can be ~1e-2; EF-averaged error is ~n x smaller
    one_shot, _ = ef(g, jnp.zeros_like(g))
    assert np.abs(avg - exact).max() <= np.abs(np.asarray(one_shot) - exact).max() + 1e-6
    assert np.abs(avg - exact).max() < 5e-3


def test_compressed_psum_close_to_exact(rng):
    mesh = make_host_mesh()
    n = mesh.shape["data"]
    if n < 2:
        # single-device mesh: compressed psum must be a near-identity
        from functools import partial
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        @partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P())
        def f(x):
            return compressed_psum(x[0], "data")

        x = jnp.asarray(rng.standard_normal((1, 256)), jnp.float32)
        out = np.asarray(f(x))
        half_step = float(np.abs(x).max()) / 127.0 / 2.0
        assert np.abs(out - np.asarray(x[0])).max() <= half_step + 1e-6
