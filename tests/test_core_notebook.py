"""C1: AST dataflow extraction + piped-section splitting."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Notebook, split_pipeline
from repro.core.dag import build_cell_dag
from repro.core.notebook import Cell, extract_usage


# ---------------------------------------------------------------------------
# AST extraction
# ---------------------------------------------------------------------------

CASES = [
    ("x = 1", set(), {"x"}),
    ("y = x + 1", {"x"}, {"y"}),
    ("x += 1", {"x"}, {"x"}),
    ("import numpy as np\nz = np.zeros(3)", set(), {"np", "z"}),
    ("def f(a):\n    return a + b\nc = f(1)", {"b"}, {"f", "c"}),
    ("out = [i * scale for i in data]", {"scale", "data"}, {"out"}),
    ("d = {k: v for k, v in pairs}", {"pairs"}, {"d"}),
    ("g = lambda t: t + offset\nh = g(2)", {"offset"}, {"g", "h"}),
    ("for row in rows:\n    total = total + row", {"rows", "total"}, {"row", "total"}),
    ("class A:\n    pass\na = A()", set(), {"A", "a"}),
    ("with open(p) as fh:\n    text = fh.read()", {"p"}, {"fh", "text"}),
]


@pytest.mark.parametrize("src,reads,writes", CASES)
def test_extract_usage(src, reads, writes):
    r, w = extract_usage(src)
    assert r == reads, (src, r)
    assert w == writes, (src, w)


def test_comprehension_variable_not_leaked():
    r, w = extract_usage("clean = [x for x in raw if x % 7 != 0]")
    assert "x" not in r and "x" not in w
    assert r == {"raw"} and w == {"clean"}


# ---------------------------------------------------------------------------
# splitting algorithm
# ---------------------------------------------------------------------------


def test_linear_chain_fuses_to_one_step():
    nb = Notebook.from_sources(["a = 1", "b = a + 1", "c = b * 2"])
    g = split_pipeline(nb)
    assert len(g.steps) == 1, g.steps.keys()


def test_pipe_tag_forces_boundary():
    nb = Notebook.from_sources(["a = 1", "# %%pipe\nb = a + 1"])
    g = split_pipeline(nb)
    assert len(g.steps) == 2
    assert g.edges[("cell0", "cell1")] == {"a"}


def test_fanout_creates_parallel_steps():
    nb = Notebook.from_sources(
        ["base = list(range(10))",
         "evens = [v for v in base if v % 2 == 0]",
         "odds = [v for v in base if v % 2 == 1]",
         "summary = (len(evens), len(odds))"]
    )
    g = split_pipeline(nb)
    assert len(g.steps) >= 3  # fan-out forces separate pods
    order = g.topological()
    assert order.index("cell0") < order.index("cell1")
    assert order.index("cell0") < order.index("cell2")


def test_split_equivalence_to_linear_run():
    srcs = [
        "raw = list(range(50))",
        "clean = [v for v in raw if v % 3]",
        "# %%pipe\ns = sum(clean)",
        "n = len(clean)",
        "mean = s / n",
    ]
    nb = Notebook.from_sources(srcs)
    env = nb.run_linear()
    g = split_pipeline(nb)
    # execute the step graph sequentially in topo order
    artifacts = {}
    for name in g.topological():
        step = g.steps[name]
        out = step.run({k: artifacts[k] for k in step.reads})
        artifacts.update(out)
    assert artifacts["mean"] == env["mean"]


def test_cycle_detection():
    from repro.core.dag import Step, StepGraph
    steps = {
        "a": Step("a", fn=lambda i: {}, writes={"x"}),
        "b": Step("b", fn=lambda i: {}, reads={"x"}, writes={"y"}),
    }
    g = StepGraph(steps=steps, edges={("a", "b"): {"x"}, ("b", "a"): {"y"}})
    with pytest.raises(ValueError, match="cycle"):
        g.topological()


# hypothesis: random linear programs — split always preserves semantics
@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from([
    "v{i} = {j} + 1",
    "v{i} = v{j} * 2",
    "# %%pipe\nv{i} = v{j} - 1",
    "v{i} = v{j} + v{k}",
]), min_size=2, max_size=8), st.integers(0, 1000))
def test_split_equivalence_property(templates, seed):
    srcs = ["v0 = 7"]
    for i, t in enumerate(templates, start=1):
        srcs.append(t.format(i=i, j=(seed + i) % i, k=(seed * 3 + i) % i))
    nb = Notebook.from_sources(srcs)
    env = nb.run_linear()
    g = split_pipeline(nb)
    artifacts = {}
    for name in g.topological():
        step = g.steps[name]
        artifacts.update(step.run({k: artifacts[k] for k in step.reads}))
    finals = {k: v for k, v in env.items() if k.startswith("v")}
    for k, v in finals.items():
        assert artifacts.get(k, v) == v, (k, srcs)


def test_dag_edges_last_writer_wins():
    cells = [Cell(source="x = 1", name="c0"),
             Cell(source="x = 2", name="c1"),
             Cell(source="y = x", name="c2")]
    edges = build_cell_dag(cells)
    assert (1, 2, {"x"}) in edges and all(e[0] != 0 for e in edges)
