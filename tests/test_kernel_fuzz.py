"""Differential kernel fuzzing: every Pallas kernel vs its ``kernels/ref.py``
oracle, in interpret mode, over seeded randomized parameter sweeps.

The hand-picked shapes in ``test_kernels.py`` / ``test_serving_paged.py``
pin known-tricky cases; this harness systematically sweeps the shape space
the serving engine actually visits — chunk sizes 1/odd/page-straddling,
history lengths 0..multi-page, partial last pages, COW-forked block tables,
GQA/MQA groupings — and asserts kernel-vs-oracle parity ≤ 1e-3 (the repo
contract from ``ops.py``), reporting the exact failing parameter tuple on
mismatch so a regression reproduces with one ``pytest -k`` invocation.

Sweeps are a deterministic seeded grid (always run) plus a hypothesis
property pass (skipped when hypothesis is not installed — see
``conftest.py``). CI runs this file in the dedicated interpret-mode kernel
job next to ``test_kernels.py``.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.serving.kv_cache import NULL_PAGE, cdiv

TOL = 1e-3  # max abs error bound, kernel vs oracle (f32; observed ~1e-6)


def _assert_close(got, want, params, kind):
    err = float(jnp.abs(jnp.asarray(got, jnp.float32)
                        - jnp.asarray(want, jnp.float32)).max())
    assert err <= TOL, f"{kind}: err={err:.3e} > {TOL} at shape tuple {params}"


# ---------------------------------------------------------------------------
# chunked-prefill paged attention
# ---------------------------------------------------------------------------
# params: (c, start, valid, h, kvh, d, page, extra_mp)
#   c      chunk width (static padded size)
#   start  history positions already cached (0 = fresh prompt)
#   valid  real tokens in the chunk (< c = padded chunk)
#   extra_mp  trailing null-page block-table entries past the live pages

_PREFILL_EDGES = [
    (1, 0, 1, 4, 2, 16, 8, 1),    # single query, no history (first token)
    (1, 17, 1, 4, 1, 8, 8, 0),    # C=1 deep in history: decode degenerate, MQA
    (3, 5, 3, 4, 4, 16, 8, 2),    # odd chunk, history mid-page, MHA
    (8, 0, 8, 4, 2, 16, 8, 0),    # chunk == page, aligned
    (8, 3, 8, 4, 2, 16, 8, 1),    # chunk straddles a page boundary
    (8, 29, 5, 8, 2, 16, 8, 1),   # multi-page history ending mid-page + pad
    (16, 8, 16, 8, 4, 32, 8, 0),  # chunk spans two whole pages
    (16, 15, 1, 4, 2, 16, 16, 1), # one live token landing last-in-page
    (5, 0, 0, 4, 2, 16, 8, 1),    # fully padded chunk -> exact zeros
    (32, 40, 32, 4, 2, 16, 16, 0),# big chunk over 2.5 pages of history
]


def _prefill_sweep():
    cases = list(_PREFILL_EDGES)
    rng = np.random.default_rng(0xC0FFEE)
    for _ in range(24):
        page = int(rng.choice([4, 8, 16]))
        c = int(rng.integers(1, 33))
        start = int(rng.integers(0, 4 * page))
        valid = int(rng.integers(1, c + 1))
        group = int(rng.choice([1, 2, 4]))
        kvh = int(rng.choice([1, 2, 4]))
        d = int(rng.choice([8, 16, 32]))
        cases.append((c, start, valid, kvh * group, kvh, d, page,
                      int(rng.integers(0, 3))))
    return cases


def _prefill_case(params, seed, forked=False):
    """Pool + block table for one chunked-prefill call.

    ``forked``: the table's live pages alias a twin sequence's pages (the
    COW/fork layout — sharing is invisible to the kernel, but the aliased
    ids exercise non-contiguous, non-monotonic physical page order).
    """
    c, start, valid, h, kvh, d, page, extra = params
    rng = np.random.default_rng(seed)
    total = start + valid
    need = cdiv(max(total, 1), page)
    num_pages = need * (2 if forked else 1) + 3
    q = jnp.asarray(rng.standard_normal((c, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    perm = rng.permutation(np.arange(1, num_pages))
    bt = np.full((need + extra,), NULL_PAGE, np.int32)
    bt[:need] = perm[:need]
    if forked and need > 1:
        # fork: shared prefix pages + a privately COW-copied tail page
        bt[need - 1] = perm[need]
    return q, kp, vp, jnp.asarray(bt), jnp.int32(start), jnp.int32(valid)


@pytest.mark.parametrize("params", _prefill_sweep(),
                         ids=lambda p: "c{}s{}v{}h{}k{}d{}p{}x{}".format(*p))
def test_paged_prefill_kernel_vs_oracle(params):
    for seed, forked in ((0, False), (1, True)):
        q, kp, vp, bt, start, valid = _prefill_case(params, seed, forked)
        want = ref.paged_prefill_attention_ref(q, kp, vp, bt, start, valid)
        got = ops.paged_prefill_attention(
            q, kp, vp, bt, start, valid, impl="pallas_interpret"
        )
        _assert_close(got, want, params + (("forked",) if forked else ()),
                      "paged_prefill")


def test_paged_prefill_ref_vs_dense():
    """Semantic anchor: the oracle itself equals dense causal attention over
    the gathered sequence (queries are its last ``valid`` positions)."""
    for params in _PREFILL_EDGES:
        c, start, valid, h, kvh, d, page, _ = params
        if valid == 0:
            continue
        q, kp, vp, bt, s_, v_ = _prefill_case(params, seed=2)
        total = start + valid
        kd = np.stack([np.asarray(kp)[bt[j // page], j % page]
                       for j in range(total)])
        vd = np.stack([np.asarray(vp)[bt[j // page], j % page]
                       for j in range(total)])
        want = ref.flash_attention_ref(
            q[None, :valid], jnp.asarray(kd)[None], jnp.asarray(vd)[None],
            causal=True,
        )[0]
        got = ref.paged_prefill_attention_ref(q, kp, vp, bt, s_, v_)[:valid]
        _assert_close(got, want, params, "paged_prefill_ref_vs_dense")


def test_paged_prefill_chunk_walk_matches_dense():
    """Walk a whole prompt through the kernel chunk by chunk — scatter each
    chunk's K/V into the pages then attend — and require the concatenated
    outputs to equal ONE dense causal attention over the full prompt. This
    is the end-to-end contract ``DecoderLM.prefill_chunk`` relies on."""
    for plen, chunk, page, h, kvh, d in [
        (37, 8, 8, 4, 2, 16),   # partial last page AND partial last chunk
        (24, 5, 8, 4, 4, 8),    # odd chunk size straddling pages
        (16, 16, 4, 2, 1, 16),  # one chunk spanning 4 pages, MQA
    ]:
        rng = np.random.default_rng(plen)
        kd = rng.standard_normal((plen, kvh, d)).astype(np.float32)
        vd = rng.standard_normal((plen, kvh, d)).astype(np.float32)
        qd = rng.standard_normal((plen, h, d)).astype(np.float32)
        need = cdiv(plen, page)
        num_pages = need + 2
        kp = np.zeros((num_pages, page, kvh, d), np.float32)
        vp = np.zeros((num_pages, page, kvh, d), np.float32)
        bt = np.asarray(rng.permutation(np.arange(1, num_pages))[:need],
                        np.int32)
        outs = []
        for start in range(0, plen, chunk):
            valid = min(chunk, plen - start)
            for i in range(start, start + valid):  # the model's page scatter
                kp[bt[i // page], i % page] = kd[i]
                vp[bt[i // page], i % page] = vd[i]
            qc = np.zeros((chunk, h, d), np.float32)
            qc[:valid] = qd[start:start + valid]
            out = ops.paged_prefill_attention(
                jnp.asarray(qc), jnp.asarray(kp), jnp.asarray(vp),
                jnp.asarray(bt), jnp.int32(start), jnp.int32(valid),
                impl="pallas_interpret",
            )
            outs.append(np.asarray(out)[:valid])
        want = ref.flash_attention_ref(
            jnp.asarray(qd)[None], jnp.asarray(kd)[None], jnp.asarray(vd)[None],
            causal=True,
        )[0]
        _assert_close(np.concatenate(outs), want,
                      (plen, chunk, page, h, kvh, d), "chunk_walk")


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(1, 24),
    start=st.integers(0, 40),
    pad=st.integers(0, 8),
    group=st.sampled_from([1, 2, 4]),
    kvh=st.sampled_from([1, 2]),
    page=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**16),
)
def test_paged_prefill_property(c, start, pad, group, kvh, page, seed):
    valid = max(1, c - pad)
    params = (c, start, valid, kvh * group, kvh, 8, page, 1)
    q, kp, vp, bt, s_, v_ = _prefill_case(params, seed)
    want = ref.paged_prefill_attention_ref(q, kp, vp, bt, s_, v_)
    got = ops.paged_prefill_attention(q, kp, vp, bt, s_, v_,
                                      impl="pallas_interpret")
    _assert_close(got, want, params + (seed,), "paged_prefill_property")
    # convexity: live rows are convex combinations of V rows
    out = np.asarray(got)[:valid]
    assert np.isfinite(out).all()
    assert out.max() <= float(jnp.max(vp)) + 1e-4
    assert out.min() >= float(jnp.min(vp)) - 1e-4


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------
# params: (b, h, kvh, d, page, mp, alias)
#   alias: rows share physical pages (post-fork COW table layout)

def _decode_sweep():
    cases = [
        (1, 4, 2, 16, 8, 1, False),    # one seq, one page
        (3, 4, 2, 16, 8, 4, False),    # the classic mixed batch (idle row 0)
        (4, 8, 1, 8, 16, 2, False),    # MQA
        (4, 4, 4, 32, 4, 6, True),     # MHA, forked tables
        (6, 4, 2, 16, 8, 3, True),
    ]
    rng = np.random.default_rng(0xDEC0DE)
    for _ in range(16):
        kvh = int(rng.choice([1, 2, 4]))
        cases.append((
            int(rng.integers(1, 7)), kvh * int(rng.choice([1, 2, 4])), kvh,
            int(rng.choice([8, 16, 32])), int(rng.choice([4, 8, 16])),
            int(rng.integers(1, 5)), bool(rng.integers(0, 2)),
        ))
    return cases


def _decode_case(params, seed):
    b, h, kvh, d, page, mp, alias = params
    rng = np.random.default_rng(seed)
    num_pages = b * mp + 2
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    # lengths: always include an idle slot (0), a partial page and a full table
    lens = rng.integers(1, mp * page + 1, b).astype(np.int32)
    if b > 1:
        lens[0] = 0
    if b > 2:
        lens[1] = mp * page  # every page full
    bt = np.full((b, mp), NULL_PAGE, np.int32)
    nxt = 1
    for i in range(b):
        for p in range(cdiv(int(lens[i]), page)):
            if alias and i > 1 and p < cdiv(int(lens[1]), page) - 1:
                bt[i, p] = bt[1, p]  # shared prefix pages with row 1
            else:
                bt[i, p] = nxt
                nxt += 1
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(lens)


@pytest.mark.parametrize("params", _decode_sweep(),
                         ids=lambda p: "b{}h{}k{}d{}p{}m{}{}".format(
                             *p[:6], "a" if p[6] else ""))
def test_paged_decode_kernel_vs_oracle(params):
    for seed in (0, 1):
        q, kp, vp, bt, lens = _decode_case(params, seed)
        want = ops.paged_attention(q, kp, vp, bt, lens, impl="xla_chunked")
        got = ops.paged_attention(q, kp, vp, bt, lens,
                                  impl="pallas_interpret")
        _assert_close(got, want, params + (seed,), "paged_decode")
        if int(lens[0]) == 0:
            assert (np.asarray(got)[0] == 0).all(), (
                f"idle slot must be exact zeros at {params}")


def test_paged_decode_equals_prefill_c1():
    """Cross-kernel consistency: decode is the C=1 chunk case."""
    params = (3, 4, 2, 16, 8, 3, False)
    q, kp, vp, bt, lens = _decode_case(params, seed=5)
    dec = ops.paged_attention(q, kp, vp, bt, lens, impl="pallas_interpret")
    for i in range(q.shape[0]):
        n = int(lens[i])
        if n == 0:
            continue
        chunk = ops.paged_prefill_attention(
            q[i][None], kp, vp, bt[i], jnp.int32(n - 1), jnp.int32(1),
            impl="pallas_interpret",
        )[0]
        _assert_close(chunk, dec[i], params + (i,), "decode_vs_prefill_c1")


# ---------------------------------------------------------------------------
# mixed prefill+decode paged attention (the fused engine step's kernel)
# ---------------------------------------------------------------------------
# params: (r, h, kvh, d, page, mp, n_dead, chunk_rows)
#   every row is ONE query position with its own (block_table, last_pos);
#   n_dead rows get last_pos=-1 (padding/idle — exact-zero output), the
#   last chunk_rows rows share one block table with consecutive last_pos
#   (a prefill chunk laid out as independent rows)


def _mixed_sweep():
    cases = [
        (1, 4, 2, 16, 8, 1, 0, 0),     # lone decode row
        (3, 4, 2, 16, 8, 4, 1, 0),     # decode batch with a dead row
        (4, 8, 1, 8, 16, 2, 0, 4),     # pure chunk, MQA
        (6, 4, 4, 32, 4, 2, 1, 3),     # the fused mix: decode+dead+chunk
        (5, 4, 2, 16, 8, 3, 4, 0),     # almost everything dead
    ]
    rng = np.random.default_rng(0x313DED)
    for _ in range(16):
        kvh = int(rng.choice([1, 2, 4]))
        r = int(rng.integers(1, 9))
        page = int(rng.choice([4, 8, 16]))
        mp = int(rng.integers(1, 5))
        ck = min(int(rng.integers(0, r + 1)), mp * page)
        cases.append((
            r, kvh * int(rng.choice([1, 2, 4])), kvh,
            int(rng.choice([8, 16, 32])), page, mp,
            int(rng.integers(0, r - ck + 1)), ck,
        ))
    return cases


def _mixed_case(params, seed):
    r, h, kvh, d, page, mp, n_dead, ck = params
    rng = np.random.default_rng(seed)
    num_pages = r * mp + 2
    q = jnp.asarray(rng.standard_normal((r, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    last = rng.integers(0, mp * page, r).astype(np.int32)
    bt = np.full((r, mp), NULL_PAGE, np.int32)
    nxt = 1
    for i in range(r - ck):
        for p in range(cdiv(int(last[i]) + 1, page)):
            bt[i, p] = nxt
            nxt += 1
    if ck:
        # chunk rows: one shared table, consecutive positions ending mid-page
        # (start clamped so the run fits the mp-page table)
        start = int(rng.integers(0, max(mp * page - ck, 1)))
        last[r - ck:] = start + np.arange(ck)
        pages = cdiv(start + ck, page)
        bt[r - ck:, :pages] = np.arange(nxt, nxt + pages)
    order = rng.permutation(r - ck)  # dead rows anywhere among the decodes
    last[order[:n_dead]] = -1
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(last)


@pytest.mark.parametrize("params", _mixed_sweep(),
                         ids=lambda p: "r{}h{}k{}d{}p{}m{}x{}c{}".format(*p))
def test_paged_mixed_kernel_vs_oracle(params):
    for seed in (0, 1):
        q, kp, vp, bt, last = _mixed_case(params, seed)
        want = ops.paged_mixed_attention(q, kp, vp, bt, last,
                                         impl="xla_chunked")
        got = ops.paged_mixed_attention(q, kp, vp, bt, last,
                                        impl="pallas_interpret")
        _assert_close(got, want, params + (seed,), "paged_mixed")
        dead = np.asarray(last) < 0
        assert (np.asarray(got)[dead] == 0).all(), (
            f"dead rows must be exact zeros at {params}")


def test_paged_mixed_subsumes_decode_and_chunk():
    """Cross-kernel consistency: with last_pos = lengths - 1 the mixed
    kernel IS paged decode, and a run of consecutive last_pos over a shared
    table IS the chunk-prefill kernel — the two dispatches the fused engine
    step replaces."""
    params = (4, 4, 2, 16, 8, 3, 1, 0)
    q, kp, vp, bt, last = _mixed_case(params, seed=3)
    lens = jnp.asarray(np.maximum(np.asarray(last) + 1, 0))
    dec = ops.paged_attention(q, kp, vp, bt, lens, impl="pallas_interpret")
    mix = ops.paged_mixed_attention(q, kp, vp, bt, last,
                                    impl="pallas_interpret")
    _assert_close(mix, dec, params, "mixed_vs_decode")

    c, start, h, kvh, d, page = 8, 5, 4, 2, 16, 8
    cp = (c, start, c, h, kvh, d, page, 1)
    qc, kpc, vpc, btc, s_, v_ = _prefill_case(cp, seed=7)
    chunk = ops.paged_prefill_attention(qc, kpc, vpc, btc, s_, v_,
                                        impl="pallas_interpret")
    mixc = ops.paged_mixed_attention(
        qc, kpc, vpc, jnp.broadcast_to(btc, (c,) + btc.shape),
        jnp.int32(start) + jnp.arange(c, dtype=jnp.int32),
        impl="pallas_interpret",
    )
    _assert_close(mixc, chunk, cp, "mixed_vs_chunk")


def test_paged_mixed_structured_xla_matches_oracle():
    """The ``num_decode`` structure hint must not change results: the split
    XLA fallback (decode rows through the decode ref, chunk rows through
    ONE shared-table prefill gather) equals the generic per-row oracle,
    with dead rows — idle decode slots AND chunk padding suffixes — still
    exact zeros."""
    for params, dead_tail in (((7, 4, 2, 16, 8, 3, 1, 4), 2),
                              ((6, 8, 1, 8, 16, 2, 0, 3), 0),
                              ((5, 4, 4, 32, 4, 2, 1, 2), 2)):
        q, kp, vp, bt, last = _mixed_case(params, seed=11)
        r, ck = params[0], params[7]
        last = np.asarray(last).copy()
        if dead_tail:
            last[r - dead_tail:] = -1  # chunk padding: a dead suffix
        last = jnp.asarray(last)
        want = ops.paged_mixed_attention(q, kp, vp, bt, last,
                                         impl="xla_chunked")
        got = ops.paged_mixed_attention(q, kp, vp, bt, last,
                                        impl="xla_chunked",
                                        num_decode=r - ck)
        _assert_close(got, want, params + (dead_tail,), "mixed_structured")
        dead = np.asarray(last) < 0
        assert (np.asarray(got)[dead] == 0).all(), (
            f"dead rows must be exact zeros at {params}")


# ---------------------------------------------------------------------------
# speculative verify (k drafted tokens scored through the chunk path)
# ---------------------------------------------------------------------------
# params reuse the prefill tuple: (c, start, valid, h, kvh, d, page, extra)
#   c = bundle width W (spec_k+1, padded), valid = 1 + k live rows,
#   start = the sequence's cached length L when the bundle dispatched


def _verify_sweep():
    cases = []
    rng = np.random.default_rng(0x5BEC)
    for k in range(1, 9):  # the engine's full draft-depth range
        start = int(rng.integers(1, 25))
        cases.append((k + 1, start, k + 1, 4, 2, 16, 8, 1))
        if k > 1:  # padded bundle: fewer drafts than the compiled width
            cases.append((k + 2, start, k + 1, 4, 2, 16, 8, 0))
    return cases


@pytest.mark.parametrize("params", _verify_sweep(),
                         ids=lambda p: "c{}s{}v{}h{}k{}d{}p{}x{}".format(*p))
def test_paged_verify_equals_decode_loop(params):
    """The verify contract, end to end: scoring a k-draft bundle through
    the chunk path (``models/lm.py::verify_step_paged`` lowers through
    ``ops.paged_prefill_attention``) must equal BOTH the dedicated
    ``paged_verify_attention_ref`` oracle and a k+1-iteration single-token
    decode loop over the same pages — the unrolled sequential decode the
    bundle replaces. COW-forked tables included: speculation runs on
    post-fork sequences too. Rows past ``valid`` are exact zeros (the
    executor pads every bundle to the compiled width)."""
    for seed, forked in ((0, False), (1, True)):
        q, kp, vp, bt, start, valid = _prefill_case(params, seed, forked)
        want = ref.paged_verify_attention_ref(q, kp, vp, bt, start, valid)
        chunk = ops.paged_prefill_attention(q, kp, vp, bt, start, valid,
                                            impl="pallas_interpret")
        _assert_close(chunk, want, params + (seed,), "verify_vs_chunk")
        mixed = ops.paged_mixed_attention(
            q, kp, vp, jnp.broadcast_to(bt, (q.shape[0],) + bt.shape),
            jnp.where(jnp.arange(q.shape[0]) < valid,
                      start + jnp.arange(q.shape[0]), -1).astype(jnp.int32),
            impl="pallas_interpret",
        )
        _assert_close(mixed, want, params + (seed,), "verify_vs_mixed")
        for j in range(int(valid)):  # the decode loop the bundle replaces
            dec = ops.paged_attention(
                q[j][None], kp, vp, bt[None],
                jnp.asarray([int(start) + j + 1], jnp.int32),
                impl="xla_chunked",
            )[0]
            _assert_close(dec, want[j], params + (seed, j),
                          "verify_vs_decode_loop")
        assert (np.asarray(want)[int(valid):] == 0).all(), (
            f"padded verify rows must be exact zeros at {params}")


def test_paged_verify_invariant_to_page_relocation():
    """Preempt-and-resume re-admits a sequence into different physical
    pages; verify must depend only on table-addressed content, so moving
    a page and repointing the block table cannot change a single logit."""
    params = (5, 11, 5, 4, 2, 16, 8, 1)
    q, kp, vp, bt, start, valid = _prefill_case(params, seed=9)
    base = ref.paged_verify_attention_ref(q, kp, vp, bt, start, valid)
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    bt2 = np.asarray(bt).copy()
    live = [p for p in bt2.tolist() if p != NULL_PAGE]
    spare = next(p for p in range(1, kp2.shape[0]) if p not in live)
    kp2[spare], vp2[spare] = kp2[live[0]], vp2[live[0]]
    bt2[np.asarray(bt).tolist().index(live[0])] = spare
    moved = ops.paged_prefill_attention(
        q, jnp.asarray(kp2), jnp.asarray(vp2), jnp.asarray(bt2),
        start, valid, impl="pallas_interpret",
    )
    _assert_close(moved, base, params, "verify_page_relocation")


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def _flash_sweep():
    cases = []
    rng = np.random.default_rng(0xF1A54)
    for _ in range(10):
        bq = int(rng.choice([16, 32, 64]))
        nq = int(rng.integers(1, 4))
        nk = nq + int(rng.integers(0, 3))  # Skv >= Sq (prefill continuation)
        kvh = int(rng.choice([1, 2, 4]))
        cases.append((
            int(rng.integers(1, 3)), bq * nq, bq * nk,
            kvh * int(rng.choice([1, 2])), kvh,
            int(rng.choice([16, 32, 64])), bool(rng.integers(0, 2)), bq,
        ))
    return cases


@pytest.mark.parametrize("params", _flash_sweep(),
                         ids=lambda p: "b{}q{}k{}h{}g{}d{}{}blk{}".format(
                             *p[:6], "c" if p[6] else "f", p[7]))
def test_flash_kernel_vs_oracle(params):
    b, sq, skv, h, kvh, d, causal, blk = params
    rng = np.random.default_rng(sum(params))
    q = jnp.asarray(rng.standard_normal((b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, kvh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, kvh, d)), jnp.float32)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    got = ops.flash_attention(q, k, v, causal=causal, impl="pallas_interpret",
                              block_q=blk, block_kv=blk)
    _assert_close(got, want, params, "flash")


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


def _ssd_sweep():
    cases = []
    rng = np.random.default_rng(0x55D)
    for _ in range(8):
        chunk = int(rng.choice([8, 16, 32]))
        # s NOT necessarily divisible by chunk: exercises the pad-and-mask
        # path (dt=0 tail positions are identities on the recurrence)
        cases.append((
            int(rng.integers(1, 3)), chunk * int(rng.integers(1, 4))
            + int(rng.choice([0, 3])), int(rng.choice([1, 2, 4])),
            int(rng.choice([8, 16])), int(rng.choice([16, 32])), chunk,
        ))
    return cases


@pytest.mark.parametrize("params", _ssd_sweep(),
                         ids=lambda p: "b{}s{}h{}p{}n{}c{}".format(*p))
def test_ssd_kernel_vs_oracle(params):
    b, s, h, p, n, chunk = params
    rng = np.random.default_rng(sum(params))
    x = rng.standard_normal((b, s, h, p)).astype(np.float32)
    dt = (0.1 + 0.9 * rng.random((b, s, h))).astype(np.float32)
    A = (-1.0 * rng.random((h,)) - 0.1).astype(np.float32)
    Bm = (rng.standard_normal((b, s, n)) / np.sqrt(n)).astype(np.float32)
    Cm = (rng.standard_normal((b, s, n)) / np.sqrt(n)).astype(np.float32)
    y_want, st_want = ref.ssd_sequential(x, dt, A, Bm, Cm)
    y_got, st_got = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                                 impl="pallas_interpret")
    _assert_close(y_got, y_want, params, "ssd_y")
    _assert_close(st_got, st_want, params, "ssd_state")


@pytest.mark.parametrize("params", _ssd_sweep(),
                         ids=lambda p: "b{}s{}h{}p{}n{}c{}".format(*p))
def test_ssd_kernel_vs_oracle_with_init_state(params):
    """Carried-state continuation (chunked serving prefill): the kernel path
    must thread ``init_state`` exactly like the literal recurrence, on the
    same non-chunk-multiple lengths as the fresh-state sweep."""
    b, s, h, p, n, chunk = params
    rng = np.random.default_rng(sum(params) ^ 0x1517)
    x = rng.standard_normal((b, s, h, p)).astype(np.float32)
    dt = (0.1 + 0.9 * rng.random((b, s, h))).astype(np.float32)
    A = (-1.0 * rng.random((h,)) - 0.1).astype(np.float32)
    Bm = (rng.standard_normal((b, s, n)) / np.sqrt(n)).astype(np.float32)
    Cm = (rng.standard_normal((b, s, n)) / np.sqrt(n)).astype(np.float32)
    h0 = rng.standard_normal((b, h, p, n)).astype(np.float32)
    y_want, st_want = ref.ssd_sequential(x, dt, A, Bm, Cm, init_state=h0)
    y_got, st_got = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                                 impl="pallas_interpret", init_state=h0)
    _assert_close(y_got, y_want, params, "ssd_y_h0")
    _assert_close(st_got, st_want, params, "ssd_state_h0")


def _ssd_decode_sweep():
    cases = []
    rng = np.random.default_rng(0xDECD)
    for _ in range(6):
        cases.append((int(rng.integers(1, 5)), int(rng.choice([1, 2, 4, 8])),
                      int(rng.choice([8, 16, 64])), int(rng.choice([16, 32]))))
    return cases


def _ssd_decode_case(params, seed):
    b, h, p, n = params
    rng = np.random.default_rng(seed + sum(params))
    state = rng.standard_normal((b, h, p, n)).astype(np.float32)
    x_t = rng.standard_normal((b, h, p)).astype(np.float32)
    dt_t = (0.1 + 0.9 * rng.random((b, h))).astype(np.float32)
    A = (-1.0 * rng.random((h,)) - 0.1).astype(np.float32)
    B_t = (rng.standard_normal((b, n)) / np.sqrt(n)).astype(np.float32)
    C_t = (rng.standard_normal((b, n)) / np.sqrt(n)).astype(np.float32)
    return state, x_t, dt_t, A, B_t, C_t


@pytest.mark.parametrize("params", _ssd_decode_sweep(),
                         ids=lambda p: "b{}h{}p{}n{}".format(*p))
def test_ssd_decode_step_kernel_vs_oracle(params):
    args = _ssd_decode_case(params, 0)
    y_want, st_want = ref.ssd_decode_step(*args)
    y_got, st_got = ops.ssd_decode_step(*args, impl="pallas_interpret")
    _assert_close(y_got, y_want, params, "ssd_dec_y")
    _assert_close(st_got, st_want, params, "ssd_dec_state")


# ---------------------------------------------------------------------------
# int8 quantized KV pages (fused-dequant kernel variants)
# ---------------------------------------------------------------------------
# Two distinct bounds, asserted separately:
#  - kernel parity: the fused-dequant Pallas kernel vs dequantize_pages +
#    the unchanged fp32 oracle over the SAME int8 pool must agree to TOL —
#    quantization itself contributes zero error to this comparison.
#  - quantization error: int8 attention vs the original fp32 pool. Each KV
#    element carries at most scale/2 ≈ absmax/254 absolute error; through
#    the softmax-weighted sum the V error passes via a convex combination
#    (bounded by max per-row V error) and the K error perturbs logits by
#    O(|q|·d·scale/2), so for unit-normal inputs the observed output error
#    is ~1e-2. QTOL below holds 4x margin over the sweep's observed max.

QTOL = 8e-2  # int8-vs-fp32 attention output bound (observed ~2e-2)


def _quantize_pool(kp, vp):
    kq, ks = ref.quantize_kv(kp)
    vq, vs = ref.quantize_kv(vp)
    return kq, ks, vq, vs


@pytest.mark.parametrize("params", _PREFILL_EDGES,
                         ids=lambda p: "c{}s{}v{}h{}k{}d{}p{}x{}".format(*p))
def test_paged_prefill_quantized_kernel_vs_oracle(params):
    q, kp, vp, bt, start, valid = _prefill_case(params, seed=0)
    kq, ks, vq, vs = _quantize_pool(kp, vp)
    want = ref.paged_prefill_attention_ref(
        q, ref.dequantize_pages(kq, ks), ref.dequantize_pages(vq, vs),
        bt, start, valid,
    )
    got = ops.paged_prefill_attention(
        q, kq, vq, bt, start, valid, k_scale=ks, v_scale=vs,
        impl="pallas_interpret",
    )
    _assert_close(got, want, params, "paged_prefill_q")
    # quantization error vs the original fp32 pool: the documented bound
    fp32 = ref.paged_prefill_attention_ref(q, kp, vp, bt, start, valid)
    err = float(jnp.abs(got - fp32).max())
    assert err <= QTOL, f"paged_prefill int8-vs-fp32 err={err:.3e} > {QTOL}"


@pytest.mark.parametrize("params", _decode_sweep()[:8],
                         ids=lambda p: "b{}h{}k{}d{}p{}m{}{}".format(
                             *p[:6], "a" if p[6] else ""))
def test_paged_decode_quantized_kernel_vs_oracle(params):
    q, kp, vp, bt, lens = _decode_case(params, seed=0)
    kq, ks, vq, vs = _quantize_pool(kp, vp)
    want = ops.paged_attention(
        q, ref.dequantize_pages(kq, ks), ref.dequantize_pages(vq, vs),
        bt, lens, impl="xla_chunked",
    )
    got = ops.paged_attention(q, kq, vq, bt, lens, k_scale=ks, v_scale=vs,
                              impl="pallas_interpret")
    _assert_close(got, want, params, "paged_decode_q")
    fp32 = ops.paged_attention(q, kp, vp, bt, lens, impl="xla_chunked")
    err = float(jnp.abs(got - fp32).max())
    assert err <= QTOL, f"paged_decode int8-vs-fp32 err={err:.3e} > {QTOL}"
    if int(lens[0]) == 0:
        assert (np.asarray(got)[0] == 0).all(), "idle slot must stay zero"


@pytest.mark.parametrize("params", _mixed_sweep()[:8],
                         ids=lambda p: "r{}h{}k{}d{}p{}m{}x{}c{}".format(*p))
def test_paged_mixed_quantized_kernel_vs_oracle(params):
    q, kp, vp, bt, last = _mixed_case(params, seed=0)
    kq, ks, vq, vs = _quantize_pool(kp, vp)
    want = ops.paged_mixed_attention(
        q, ref.dequantize_pages(kq, ks), ref.dequantize_pages(vq, vs),
        bt, last, impl="xla_chunked",
    )
    got = ops.paged_mixed_attention(q, kq, vq, bt, last,
                                    k_scale=ks, v_scale=vs,
                                    impl="pallas_interpret")
    _assert_close(got, want, params, "paged_mixed_q")
    fp32 = ops.paged_mixed_attention(q, kp, vp, bt, last, impl="xla_chunked")
    err = float(jnp.abs(got - fp32).max())
    assert err <= QTOL, f"paged_mixed int8-vs-fp32 err={err:.3e} > {QTOL}"
    dead = np.asarray(last) < 0
    assert (np.asarray(got)[dead] == 0).all(), "dead rows must stay zero"


def test_quantize_dequant_roundtrip_grid():
    """Deterministic always-run slice of the round-trip property below."""
    for rows, kvh, d, scale_exp, seed in [
        (1, 1, 4, 0, 0), (16, 2, 8, -8, 1), (40, 4, 32, 8, 2),
        (7, 1, 16, -3, 3), (24, 2, 4, 5, 4),
    ]:
        _roundtrip_check(rows, kvh, d, scale_exp, seed)


def _roundtrip_check(rows, kvh, d, scale_exp, seed):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((rows, kvh, d)) * 2.0 ** scale_exp).astype(
        np.float32)
    x[0] = 0.0
    q, scale = ref.quantize_kv(jnp.asarray(x))
    back = np.asarray(ref.dequantize_pages(q, scale))
    bound = np.asarray(scale)[..., None] / 2 + 1e-9
    assert (np.abs(back - x) <= bound).all()
    assert (back[0] == 0).all()


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 40),
    kvh=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([4, 8, 16, 32]),
    scale_exp=st.integers(-8, 8),
    seed=st.integers(0, 2**16),
)
def test_quantize_dequant_roundtrip_bound(rows, kvh, d, scale_exp, seed):
    """quantize_kv -> dequantize_pages recovers every element to within
    scale/2 (the round-to-nearest half step), across magnitudes 2^-8..2^8,
    and all-zero rows survive the scale clamp exactly."""
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal((rows, kvh, d)) * 2.0 ** scale_exp).astype(
        np.float32)
    x[0] = 0.0  # always include an all-zero row
    q, scale = ref.quantize_kv(jnp.asarray(x))
    assert q.dtype == jnp.int8 and scale.shape == x.shape[:-1]
    back = np.asarray(ref.dequantize_pages(q, scale))
    bound = np.asarray(scale)[..., None] / 2 + 1e-9
    assert (np.abs(back - x) <= bound).all(), (
        f"round-trip exceeded scale/2 at rows={rows} d={d} 2^{scale_exp}")
    assert (back[0] == 0).all()


# ---------------------------------------------------------------------------
# non-TPU fallback policy (ops.paged_* with impl="pallas")
# ---------------------------------------------------------------------------


def test_pallas_fallback_warns_once_and_matches_ref():
    """On a non-TPU backend ``impl='pallas'`` must serve through the ref
    path — numerically identical — after ONE RuntimeWarning per op."""
    if jax.default_backend() == "tpu":
        pytest.skip("fallback only exists off-TPU")
    q, kp, vp, bt, start, valid = _prefill_case((4, 4, 4, 4, 2, 16, 8, 1), 0)
    ops._PALLAS_FALLBACK_WARNED.discard("paged_prefill_attention")
    with pytest.warns(RuntimeWarning, match="falling back"):
        got = ops.paged_prefill_attention(q, kp, vp, bt, start, valid,
                                          impl="pallas")
    want = ref.paged_prefill_attention_ref(q, kp, vp, bt, start, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # second call: silent
        ops.paged_prefill_attention(q, kp, vp, bt, start, valid, impl="pallas")

    qd, kpd, vpd, btd, lens = _decode_case((2, 4, 2, 16, 8, 2, False), 0)
    ops._PALLAS_FALLBACK_WARNED.discard("paged_attention")
    with pytest.warns(RuntimeWarning, match="falling back"):
        got = ops.paged_attention(qd, kpd, vpd, btd, lens, impl="pallas")
    want = ref.paged_attention_ref(qd, kpd, vpd, btd, lens)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ops.paged_attention(qd, kpd, vpd, btd, lens, impl="pallas")

    # the serving decode hot-path op must obey the same policy: off-TPU
    # impl='pallas' pins to ref.ssd_decode_step bit-for-bit after one warning
    dargs = _ssd_decode_case((2, 4, 16, 32), 0)
    ops._PALLAS_FALLBACK_WARNED.discard("ssd_decode_step")
    with pytest.warns(RuntimeWarning, match="falling back"):
        y_got, st_got = ops.ssd_decode_step(*dargs, impl="pallas")
    y_want, st_want = ref.ssd_decode_step(*dargs)
    np.testing.assert_array_equal(np.asarray(y_got), np.asarray(y_want))
    np.testing.assert_array_equal(np.asarray(st_got), np.asarray(st_want))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ops.ssd_decode_step(*dargs, impl="pallas")
