"""Dedicated ``core/storage.py`` unit tests.

The ArtifactStore grew a second life as the serving KV tiers' persistence
backend (``serving/kv_tiers.py``): spilled prefix pages are ``put`` as
ndarrays into the node tier and looked up by content-keyed refs after a
process restart. These tests pin the exact properties that path relies on
— round-trips by kind, tier directory layout, restart visibility, ref
idempotence — plus the VolumeClaim capacity accounting (claim /
``used_bytes`` / release) that ``test_bus_storage.py`` only touches.
"""

import json

import numpy as np
import pytest

from repro.core.storage import TIERS, ArtifactStore


# ---------------------------------------------------------------------------
# put/get round-trips
# ---------------------------------------------------------------------------


def test_put_get_roundtrip_ndarray_dtypes(tmp_path):
    """The KV spill path stores int8 pages, f32 scales and i64-derived
    metadata — every dtype must round-trip bit-exact, shape included."""
    store = ArtifactStore(tmp_path)
    for arr in (
        np.arange(24, dtype=np.int8).reshape(2, 3, 4),
        np.linspace(-1, 1, 12, dtype=np.float32).reshape(3, 4),
        np.array([], dtype=np.float64),
        np.zeros((1, 2, 8, 2, 4), np.float16),
    ):
        got = store.get(store.put(arr, name="kv"))
        assert got.dtype == arr.dtype and got.shape == arr.shape
        np.testing.assert_array_equal(got, arr)


def test_put_is_idempotent_and_ref_stable(tmp_path):
    """Same content -> same ref, and re-putting never rewrites the object
    (content addressing is what makes write-through spill cheap on reruns)."""
    store = ArtifactStore(tmp_path)
    arr = np.arange(10, dtype=np.float32)
    r1 = store.put(arr, name="kv.k")
    data = tmp_path / "shared" / "objects" / r1.split("://")[1].split("/")[0] / "data"
    mtime = data.stat().st_mtime_ns
    r2 = store.put(arr, name="kv.k")
    assert r1 == r2
    assert data.stat().st_mtime_ns == mtime  # not rewritten
    assert store.exists(r1)


def test_put_tree_reconstructs_nested_pytree(tmp_path):
    store = ArtifactStore(tmp_path)
    tree = {"k": np.arange(6).reshape(2, 3), "nested": [np.ones(3), np.zeros(2)]}
    meta = store.get(store.put_tree(tree, name="params"))
    assert set(meta) == {"treedef", "leaves"} and len(meta["leaves"]) == 3
    got = [store.get(r) for r in meta["leaves"]]
    np.testing.assert_array_equal(got[0], tree["k"])
    np.testing.assert_array_equal(got[1], tree["nested"][0])
    np.testing.assert_array_equal(got[2], tree["nested"][1])


# ---------------------------------------------------------------------------
# tier directories
# ---------------------------------------------------------------------------


def test_tier_directories_created_and_disjoint(tmp_path):
    store = ArtifactStore(tmp_path, node_id="n1")
    assert (tmp_path / "shared" / "objects").is_dir()
    assert (tmp_path / "node" / "n1" / "objects").is_dir()
    rn = store.put(b"same-bytes", tier="node")
    rs = store.put(b"same-bytes", tier="shared")
    # same digest, but each tier holds its own copy under its own root
    assert rn.split("://")[1] == rs.split("://")[1]
    digest = rn.split("://")[1].split("/")[0]
    assert (tmp_path / "node" / "n1" / "objects" / digest / "data").exists()
    assert (tmp_path / "shared" / "objects" / digest / "data").exists()


def test_node_tier_is_node_affine(tmp_path):
    """A node:// ref written by one node is invisible to another node's
    store over the same root — the PV nodeAffinity analogue."""
    a = ArtifactStore(tmp_path, node_id="a")
    b = ArtifactStore(tmp_path, node_id="b")
    ref = a.put(b"node-local", tier="node")
    assert a.exists(ref) and not b.exists(ref)
    shared = a.put(b"cluster-wide", tier="shared")
    assert b.get(shared) == b"cluster-wide"


def test_unknown_tier_rejected(tmp_path):
    store = ArtifactStore(tmp_path)
    with pytest.raises(ValueError, match="unknown tier"):
        store.put(b"x", tier="ebs")
    assert set(TIERS) == {"node", "shared"}


def test_restart_sees_persisted_objects(tmp_path):
    """A fresh store over the same root resolves yesterday's refs — the
    property the KV prefix persistence index depends on across restarts."""
    ref = ArtifactStore(tmp_path, node_id="n0").put(
        np.full((4, 4), 7, np.int8), tier="node", name="kv.k"
    )
    # side files next to the objects survive too (kv_prefix_index.json)
    (tmp_path / "kv_prefix_index.json").write_text(json.dumps({"ck": {"k": ref}}))

    store2 = ArtifactStore(tmp_path, node_id="n0")
    idx = json.loads((store2.root / "kv_prefix_index.json").read_text())
    got = store2.get(idx["ck"]["k"])
    np.testing.assert_array_equal(got, np.full((4, 4), 7, np.int8))


# ---------------------------------------------------------------------------
# VolumeClaim capacity accounting
# ---------------------------------------------------------------------------


def test_claim_used_bytes_tracks_nested_files(tmp_path):
    store = ArtifactStore(tmp_path)
    claim = store.claim("ckpt", tier="shared", capacity_bytes=1 << 16)
    assert claim.used_bytes() == 0
    (claim.path / "a.bin").write_bytes(b"x" * 100)
    sub = claim.path / "sub"
    sub.mkdir()
    (sub / "b.bin").write_bytes(b"y" * 50)
    assert claim.used_bytes() == 150  # recursive, files only
    (claim.path / "a.bin").unlink()
    assert claim.used_bytes() == 50
    assert claim.capacity_bytes == 1 << 16


def test_claim_same_name_is_stable_and_release_removes(tmp_path):
    """Re-claiming a name re-attaches to the same directory (restart
    resumes its volume); release removes it and is idempotent."""
    store = ArtifactStore(tmp_path, node_id="w0")
    c1 = store.claim("vol", tier="node", capacity_bytes=1024)
    (c1.path / "f").write_bytes(b"z" * 10)
    c2 = store.claim("vol", tier="node", capacity_bytes=1024)
    assert c2.path == c1.path and c2.used_bytes() == 10
    assert c1.tier == "node" and "w0" in str(c1.path)
    store.release(c1)
    assert not c1.path.exists()
    store.release(c1)  # already gone: no error


def test_claims_isolated_per_name(tmp_path):
    store = ArtifactStore(tmp_path)
    a = store.claim("a")
    b = store.claim("b")
    (a.path / "f").write_bytes(b"q" * 30)
    assert b.used_bytes() == 0
    store.release(a)
    assert b.path.exists()
