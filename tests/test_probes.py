"""Units for the probe-state machine (``core/probes.py``), serving edition.

Deterministic without sleeps: heartbeats are published at real wall time,
and the monitor's injected ``clock`` is then moved forward relative to
``time.time()`` to place "now" exactly where each assertion needs it —
inside the liveness window, past the livelock window, past the liveness
window — so no test waits for a real gap to elapse.
"""

import time

import pytest

from repro.core.bus import TopicBus
from repro.core.probes import HealthMonitor, HeartbeatWriter


@pytest.fixture
def bus(tmp_path):
    return TopicBus(tmp_path / "bus")


def _monitor(bus, clock_holder, liveness=10.0, livelock=None):
    return HealthMonitor(bus, liveness_window_s=liveness,
                         livelock_window_s=livelock,
                         clock=lambda: clock_holder["t"])


def test_not_ready_to_live_to_dead(bus):
    now = {"t": time.time()}
    mon = _monitor(bus, now)
    hb = HeartbeatWriter(bus, "p0")

    assert mon.status("p0") == "unknown"
    hb.beat(progress=0)  # beats before ready: still initializing
    assert mon.status("p0") == "not_ready"
    hb.ready()
    now["t"] = time.time()
    assert mon.status("p0") == "live"
    now["t"] = time.time() + 5
    assert mon.status("p0") == "live"  # inside the window
    now["t"] = time.time() + 11
    assert mon.status("p0") == "dead"
    assert mon.dead_pods() == ["p0"]
    # a fresh beat revives it
    hb.beat(progress=1)
    now["t"] = time.time()
    assert mon.status("p0") == "live"


def test_livelock_detection(bus):
    """Heartbeats arriving, pod busy, progress flat -> livelocked; progress
    advancing or pod idle -> live; detection off without a window."""
    now = {"t": time.time()}
    mon = _monitor(bus, now, liveness=100.0, livelock=2.0)
    hb = HeartbeatWriter(bus, "p0")
    hb.ready()
    hb.beat(progress=3, busy=True)

    now["t"] = time.time() + 1
    assert mon.status("p0") == "live"  # flat for 1s < livelock window
    now["t"] = time.time() + 5
    assert mon.status("p0") == "livelocked"  # busy, flat past the window
    assert ("p0", "livelocked") in mon.unhealthy_pods()
    assert mon.dead_pods() == []  # livelock is NOT dead (scheduler compat)

    # forward progress resets the livelock clock
    hb.beat(progress=4, busy=True)
    now["t"] = time.time() + 1
    assert mon.status("p0") == "live"

    # an idle pod owes no progress: flat counter but busy=False stays live
    hb.beat(progress=4, busy=False)
    now["t"] = time.time() + 5
    assert mon.status("p0") == "live"

    # same history, no livelock window configured: never livelocked
    mon2 = _monitor(bus, now, liveness=100.0, livelock=None)
    hb2 = HeartbeatWriter(bus, "p1")
    hb2.ready()
    hb2.beat(progress=1, busy=True)
    now["t"] = time.time() + 50
    assert mon2.status("p1") == "live"


def test_unhealthy_pods_and_forget(bus):
    now = {"t": time.time()}
    mon = _monitor(bus, now, liveness=10.0, livelock=2.0)
    for name in ("dead0", "lock0", "ok0"):
        hb = HeartbeatWriter(bus, name)
        hb.ready()
        hb.beat(progress=1, busy=True)
    # ok0 keeps making progress right up to "now"
    HeartbeatWriter(bus, "ok0").beat(progress=2, busy=True)

    mon.refresh()
    # dead0's beats are ancient relative to a far-future clock; fake that by
    # aging only its last_ts (the bus stamps real time, so we edit the view)
    mon._state["dead0"].last_ts -= 20
    mon._state["lock0"].progress_ts -= 5
    mon._state["ok0"].progress_ts = now["t"]

    states = dict(mon.unhealthy_pods())
    assert states["dead0"] == "dead"
    assert states["lock0"] == "livelocked"
    assert "ok0" not in states
    assert mon.dead_pods() == ["dead0"]

    mon.forget("dead0")
    assert "dead0" not in dict(mon.unhealthy_pods())
    assert mon.progress("lock0") == 1
    assert set(mon.heartbeat_times()) >= {"lock0", "ok0"}


def test_progress_ts_tracks_advancement_only(bus):
    """The livelock clock restarts on progress CHANGE, not on every beat —
    a wedged-but-beating worker cannot reset it."""
    now = {"t": time.time()}
    mon = _monitor(bus, now, liveness=100.0, livelock=2.0)
    hb = HeartbeatWriter(bus, "p0")
    hb.ready()
    hb.beat(progress=7, busy=True)
    for _ in range(5):
        hb.beat(progress=7, busy=True)  # beats keep coming, progress flat
    now["t"] = time.time() + 3
    assert mon.status("p0") == "livelocked"
