"""Checkpointing: atomicity, integrity, async, elastic reshard."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def state_like():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "opt": {"m": {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))}},
        "step": jnp.asarray(7, jnp.int32),
    }


def abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def test_roundtrip(tmp_path):
    ck = CheckpointManager(tmp_path)
    s = state_like()
    ck.save(7, s, meta={"note": "hi"})
    got, meta = ck.restore(abstract(s))
    assert meta["note"] == "hi"
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save(tmp_path):
    ck = CheckpointManager(tmp_path)
    s = state_like()
    ck.save(1, s, sync=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_atomic_commit_crash_safety(tmp_path):
    """A .tmp dir from a crashed save must be invisible to restore."""
    ck = CheckpointManager(tmp_path)
    s = state_like()
    ck.save(1, s)
    # simulate a crash mid-save of step 2: stray tmp dir, no manifest
    tmp = tmp_path / "step_00000002.tmp"
    tmp.mkdir()
    (tmp / "leaf_00000.npy").write_bytes(b"garbage")
    assert ck.latest_step() == 1
    got, _ = ck.restore(abstract(s))
    assert int(jax.tree.leaves(got)[-1]) in (7,)  # step leaf intact


def test_integrity_detection(tmp_path):
    ck = CheckpointManager(tmp_path)
    s = state_like()
    ck.save(3, s)
    d = ck._step_dir(3)
    # corrupt one leaf
    leaf = sorted(d.glob("leaf_*.npy"))[0]
    raw = bytearray(leaf.read_bytes())
    raw[-1] ^= 0xFF
    leaf.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="integrity"):
        ck.restore(abstract(s))


def test_gc_keeps_latest(tmp_path):
    ck = CheckpointManager(tmp_path, keep=2)
    s = state_like()
    for step in (1, 2, 3, 4):
        ck.save(step, s)
    assert ck.steps() == [3, 4]


def test_shape_mismatch_rejected(tmp_path):
    ck = CheckpointManager(tmp_path)
    s = state_like()
    ck.save(1, s)
    bad = abstract(s)
    bad["params"]["w"] = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    with pytest.raises(ValueError, match="ckpt"):
        ck.restore(bad)


def test_elastic_reshard_mesh_to_mesh(tmp_path):
    """Save under mesh A, restore under mesh B (different sharding) — the
    elastic-rescale primitive. On 1 CPU device both meshes are trivial, but
    the sharding plumbing (device_put per leaf with a NamedSharding) is the
    same code path the 512-device dry-run uses."""
    from repro.core.elastic import RescalePlan, rolling_phases
    from repro.launch.mesh import make_host_mesh

    ck = CheckpointManager(tmp_path)
    s = state_like()
    ck.save(5, s)
    mesh = make_host_mesh()
    axes = {
        "params": {"w": ("embed", "ff"), "b": (None,)},
        "opt": {"m": {"w": ("embed", "ff"), "b": (None,)}},
        "step": None,
    }
    plan = RescalePlan(axes, mesh)
    shardings = plan.new_shardings(abstract(s))
    got, _ = ck.restore(abstract(s), shardings=shardings)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.asarray(s["params"]["w"]))
    # rolling phases follow maxUnavailable
    phases = list(rolling_phases(4, 2, max_unavailable=1))
    assert [p["phase"] for p in phases] == [
        "checkpoint_barrier", "drain", "drain", "reshard", "resume"]
