"""Engine-invariant stress test: a randomized submit/cancel/shared-prefix
trace driven through ``EngineCore.step()`` on BOTH engines, with the paged
engine's page pool sized to force preemption mid-trace.

After EVERY step the paged engine must satisfy the scheduler/page-pool
invariants (refcounts equal live block-table references, the free list and
the referenced set exactly partition the pool with no double-frees, the
prefix index only maps full frozen pages bijectively, slot occupancy equals
the live sequence set), and at drain every handle must have finished with a
typed :class:`FinishReason` and every surviving stream must be byte-identical
to an unperturbed replay of the same requests (no cancels, ample pages) —
the determinism contract that makes preemption and sharing invisible.

CI also runs this file under the forced 4-device mesh job, so the same
trace stresses the sharded executor (head-sharded page pool, replicated
tables) without any test changes.
"""

import numpy as np
import pytest

import jax

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serving import (
    ContinuousBatchingEngine,
    FinishReason,
    GenerationEngine,
    Request,
    SamplingParams,
    SSMEngine,
)
from repro.serving.kv_cache import NULL_PAGE

PAGE = 8
MAX_LEN = 64


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg)
    return cfg, model.init(jax.random.key(0))


# ---------------------------------------------------------------------------
# trace construction
# ---------------------------------------------------------------------------


def _make_trace(seed: int, n: int = 14):
    """Requests with explicit sampling seeds (stream identity must not depend
    on submission order), a shared 2-page prefix on half of them, mixed
    greedy/sampled rows, and a submit/cancel schedule keyed by step index."""
    rng = np.random.default_rng(seed)
    prefix = list(rng.integers(1, 250, 2 * PAGE))
    reqs = []
    for i in range(n):
        shared = i % 2 == 0
        body = list(rng.integers(1, 250, int(rng.integers(3, 15))))
        reqs.append(Request(
            f"s{i}",
            (prefix if shared else []) + body,
            sampling=SamplingParams(
                temperature=0.7 if i % 5 == 4 else 0.0,
                top_k=8 if i % 5 == 4 else 0,
                max_new_tokens=int(rng.integers(3, 7)),
                seed=1000 + i,
            ),
        ))
    # submissions staggered in bursts; two cancels land mid-flight
    actions: dict[int, list[tuple[str, str]]] = {}
    for i, r in enumerate(reqs):
        actions.setdefault(i // 3, []).append(("submit", r.uid))

    actions.setdefault(4, []).append(("cancel", reqs[2].uid))   # likely decoding
    actions.setdefault(2, []).append(("cancel", reqs[5].uid))   # likely queued
    cancelled = {reqs[2].uid, reqs[5].uid}
    return reqs, actions, cancelled


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------


def _check_paged_invariants(engine: ContinuousBatchingEngine) -> None:
    cache, sched, pool = engine.cache, engine.scheduler, engine.cache.pool

    # refcounts match live block-table references, slot by slot
    refs: dict[int, int] = {}
    for slot in range(cache.max_slots):
        for p in cache._slot_pages[slot]:
            assert p != NULL_PAGE
            refs[p] = refs.get(p, 0) + 1
    for page in range(1, cache.num_pages):
        assert int(pool.refcounts[page]) == refs.get(page, 0), (
            f"page {page}: refcount {int(pool.refcounts[page])} != "
            f"{refs.get(page, 0)} live references"
        )

    # free list + referenced + parked pages exactly partition the pool; a
    # page on the free list twice would be a double-free, an unreachable
    # allocated page a leak, an overlap a tier state-machine violation
    free = pool._free
    assert len(set(free)) == len(free), "double-freed page on the free list"
    assert NULL_PAGE not in free
    used = set(refs)
    tiers = cache.tiers
    parked = set(tiers.parked) if tiers is not None else set()
    assert not set(free) & used, "page simultaneously free and referenced"
    assert not parked & used, "parked page still referenced by a slot"
    assert not set(free) & parked, "parked page on the free list"
    assert set(free) | used | parked == set(range(1, cache.num_pages)), \
        "leaked page"
    if tiers is not None:
        for p in parked:
            assert int(pool.refcounts[p]) == 0, f"parked page {p} refcounted"
            # parked pages stay matchable: index entry + content key intact
            assert p in cache._page_key and p in cache._page_ck, p
        assert tiers.pending <= parked, "pending prefetch outside parked set"
        assert len(tiers.host) <= max(tiers.host_pages, 0), "host tier overflow"

    # the prefix index only maps full frozen pages, bijectively
    assert len(cache._page_key) == len(cache._prefix_index)
    for key, page in cache._prefix_index.items():
        parent, chunk = key
        assert len(chunk) == cache.page_size, "partial page in prefix index"
        assert page in used or page in parked, "prefix index maps a freed page"
        assert cache._page_key.get(page) == key
    for slot, seq in sched.slots.items():
        # positions provably written for this slot: the prefill cursor while
        # prefilling (admit pre-sets ``lengths``), the live length after
        written = (seq.prefill_pos if seq.phase == "prefill"
                   else int(cache.lengths[slot]))
        for i, p in enumerate(cache._slot_pages[slot]):
            if p in cache._page_key:
                assert (i + 1) * cache.page_size <= written, (
                    f"slot {slot}: registered page {p} at index {i} is not "
                    f"frozen (written={written}, phase={seq.phase})"
                )

    # slot occupancy == live sequences
    live = set(sched.slots)
    assert live == {s for s in range(cache.max_slots)
                    if cache._slot_pages[s]}, "slot/page-map mismatch"
    assert set(cache._free_slots) == set(range(cache.max_slots)) - live
    assert len(set(cache._free_slots)) == len(cache._free_slots)
    for s in cache._free_slots:
        assert int(cache.lengths[s]) == 0
        assert (cache.block_tables[s] == NULL_PAGE).all()


def _check_drained(cache) -> None:
    """Post-drain tier partition: no refcounts, every page free or parked,
    and the prefix index covers exactly the parked set."""
    assert cache.pool.available + cache.parked_count == cache.num_pages - 1
    assert (cache.pool.refcounts[1:] == 0).all()
    parked = set(cache.tiers.parked) if cache.tiers is not None else set()
    assert set(cache._page_key) == parked


def _check_lockstep_invariants(engine: GenerationEngine) -> None:
    if engine._batch is None:
        assert engine._bstate is None
        return
    assert not all(r.done for r in engine._batch), "retired batch kept alive"
    for row in engine._batch:
        sp = row.request.sampling
        assert len(row.handle.tokens) <= sp.max_new_tokens


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------


def _drive(engine, reqs, actions, check):
    """Run the schedule through ``step()``, checking invariants and event
    well-formedness after every step. Returns (handles, events_by_uid)."""
    by_uid = {r.uid: r for r in reqs}
    handles, events = {}, {}
    finished = set()
    cancelled = set()  # cancels that actually landed (not already finished)
    last_idx: dict[str, int] = {}
    step = 0
    while True:
        for kind, uid in actions.get(step, []):
            if kind == "submit":
                handles[uid] = engine.submit(by_uid[uid])
            elif engine.cancel(uid):
                cancelled.add(uid)
        for ev in engine.step():
            assert ev.uid not in finished, f"{ev.uid}: event after finish"
            if ev.kind == "finish":
                assert isinstance(ev.finish_reason, FinishReason)
                finished.add(ev.uid)
            elif ev.kind == "token":
                last = last_idx.get(ev.uid, -1)
                assert ev.index > last, f"{ev.uid}: non-monotonic delta index"
                last_idx[ev.uid] = ev.index
                assert handles[ev.uid].tokens[ev.index] == ev.token
            events.setdefault(ev.uid, []).append(ev)
        check(engine)
        step += 1
        done_sched = all(s <= step for s in actions)
        if done_sched and engine.idle:
            break
        assert step < 600, "trace failed to drain"
    return handles, events, cancelled


def _replay(cfg, params, engine_cls, reqs, **kw):
    """Unperturbed oracle run: same requests, no cancels, no pressure."""
    eng = engine_cls(cfg, params, max_len=MAX_LEN, **kw)
    handles = [eng.submit(Request(r.uid, list(r.prompt), sampling=r.sampling))
               for r in reqs]
    while not eng.idle:
        eng.step()
    return {h.uid: h.result() for h in handles}


@pytest.mark.parametrize("seed", [0, 1])
def test_paged_engine_invariants_under_stress(smollm, seed):
    cfg, params = smollm
    reqs, actions, attempted = _make_trace(seed)
    # 7 usable pages: admission gates on availability, so two admitted
    # sequences fill the pool and decode-time page growth runs it dry —
    # the youngest-first preemption path WILL fire mid-trace
    engine = ContinuousBatchingEngine(
        cfg, params, max_len=MAX_LEN, max_slots=4, page_size=PAGE,
        num_pages=8, prefill_chunk=PAGE, prefix_sharing=True, seed=seed,
    )
    handles, _, cancelled = _drive(engine, reqs, actions,
                                   _check_paged_invariants)
    assert cancelled, "no cancel landed: schedule the cancels earlier"
    assert engine.stats["preemptions"] > 0, (
        "trace too gentle: preemption path never exercised")
    assert engine.cache.stats["prefix_hits"] > 0, (
        "trace too gentle: prefix sharing never exercised")

    # drain state: every page free or parked, slots free, and exactly the
    # parked pages keep prefix-index entries (tiers keep prefixes warm)
    _check_drained(engine.cache)
    assert len(engine.cache._free_slots) == engine.cache.max_slots

    # every handle finished with a typed reason
    for uid, h in handles.items():
        assert isinstance(h.finish_reason, FinishReason), uid
        if uid in cancelled:
            assert h.finish_reason is FinishReason.CANCELLED
        else:
            assert h.finish_reason in (FinishReason.LENGTH, FinishReason.STOP)

    # streams replay-identical to an unperturbed run (cancelled: prefix)
    oracle = _replay(cfg, params, ContinuousBatchingEngine, reqs,
                     max_slots=4, page_size=PAGE, prefill_chunk=PAGE,
                     prefix_sharing=True, seed=seed)
    for uid, h in handles.items():
        want = oracle[uid].tokens
        if uid in cancelled:
            assert h.tokens == want[:len(h.tokens)], uid
        else:
            assert h.tokens == want, uid


@pytest.mark.parametrize("seed", [0, 1])
def test_paged_engine_restart_mid_trace(smollm, seed):
    """Worker-restart perturbation arm: the engine is torn down mid-trace
    (the fleet's crash model — state lost, handles stranded) and a fresh
    engine is rebuilt with every unfinished in-flight request resubmitted
    under its original sampling seed. The same invariant sweep must hold
    on the rebuilt engine after every step, and the combined streams —
    tokens delivered before the crash + the resubmitted run — must be
    byte-identical to the unperturbed oracle, with the pre-crash delivery
    an exact prefix of the regenerated stream (no token re-emitted or
    skipped across the restart)."""
    cfg, params = smollm
    reqs, actions, _attempted = _make_trace(seed)
    kw = dict(max_slots=4, page_size=PAGE, num_pages=8, prefill_chunk=PAGE,
              prefix_sharing=True, seed=seed)
    engine = ContinuousBatchingEngine(cfg, params, max_len=MAX_LEN, **kw)
    by_uid = {r.uid: r for r in reqs}
    handles: dict[str, object] = {}
    cancelled = set()
    crash_step = 6  # past every submit burst and both cancels
    for step in range(crash_step):
        for kind, uid in actions.get(step, []):
            if kind == "submit":
                handles[uid] = engine.submit(by_uid[uid])
            elif engine.cancel(uid):
                cancelled.add(uid)
        engine.step()
        _check_paged_invariants(engine)

    # the crash: engine state is gone; only the delivered tokens survive
    delivered = {uid: list(h.tokens) for uid, h in handles.items()}
    pre_crash = {uid: h for uid, h in handles.items() if h.done}
    inflight = [uid for uid, h in handles.items() if not h.done]
    assert inflight, "crash step too late: nothing was in flight"
    assert any(delivered[u] for u in inflight), (
        "crash step too early: no mid-stream request to resume")
    del engine

    engine2 = ContinuousBatchingEngine(cfg, params, max_len=MAX_LEN, **kw)
    handles2 = {
        uid: engine2.submit(Request(uid, list(by_uid[uid].prompt),
                                    sampling=by_uid[uid].sampling))
        for uid in inflight
    }
    steps = 0
    while not engine2.idle:
        engine2.step()
        _check_paged_invariants(engine2)
        steps += 1
        assert steps < 600, "restarted trace failed to drain"

    # rebuilt-engine drain state: pool reclaimed up to parked prefixes
    _check_drained(engine2.cache)

    oracle = _replay(cfg, params, ContinuousBatchingEngine, reqs, **kw)
    for uid, h in pre_crash.items():
        assert isinstance(h.finish_reason, FinishReason), uid
        want = oracle[uid].tokens
        if uid in cancelled:
            assert h.tokens == want[:len(h.tokens)], uid
        else:
            assert h.tokens == want, uid
    for uid, h in handles2.items():
        assert h.finish_reason in (FinishReason.LENGTH, FinishReason.STOP), uid
        # seeded replay: the regenerated stream IS the original stream, so
        # the pre-crash delivery is an exact prefix — a client that dedupes
        # by index (the fleet supervisor) sees every token exactly once
        assert h.tokens == oracle[uid].tokens, uid
        pre = delivered[uid]
        assert h.tokens[:len(pre)] == pre, (
            f"{uid}: pre-crash delivery is not a prefix of the replay")


@pytest.mark.parametrize("seed", [0, 1])
def test_tiered_engine_streams_match_untiered(smollm, seed, tmp_path):
    """Park/spill/reload/reclaim must never change a stream: the stress
    trace with every tier engaged — a pool small enough that parked pages
    get reclaimed, a host-RAM tier, a persisted ArtifactStore tier — must
    produce byte-identical streams to a tiers-OFF run of the same trace,
    while the tier partition invariant holds after every step."""
    cfg, params = smollm
    reqs, actions, _attempted = _make_trace(seed)
    kw = dict(max_slots=4, page_size=PAGE, num_pages=8, prefill_chunk=PAGE,
              prefix_sharing=True, seed=seed)
    engine = ContinuousBatchingEngine(
        cfg, params, max_len=MAX_LEN, host_pages=16,
        persist_dir=str(tmp_path / "kv"), **kw)
    handles, _, cancelled = _drive(engine, reqs, actions,
                                   _check_paged_invariants)
    t = engine.cache.tiers
    assert t.counters["reclaimed_pages"] > 0, (
        "trace too gentle: parked pages were never reclaimed under pressure")
    assert t.counters["spilled_pages"] > 0, "spill path never exercised"
    _check_drained(engine.cache)

    oracle = _replay(cfg, params, ContinuousBatchingEngine, reqs,
                     kv_tiers=False, **kw)
    for uid, h in handles.items():
        want = oracle[uid].tokens
        if uid in cancelled:
            assert h.tokens == want[:len(h.tokens)], uid
        else:
            assert h.tokens == want, uid


@pytest.mark.parametrize("seed", [0])
def test_quantized_engine_invariants_and_determinism(smollm, seed):
    """``kv_quant="int8"`` arm: the full invariant sweep holds under the
    same perturbed trace, and streams replay byte-identical to an
    unperturbed int8 oracle — quantized numerics may differ from fp32, but
    determinism (the preemption/sharing-invisibility contract) must not."""
    cfg, params = smollm
    reqs, actions, _attempted = _make_trace(seed)
    kw = dict(max_slots=4, page_size=PAGE, num_pages=8, prefill_chunk=PAGE,
              prefix_sharing=True, seed=seed, kv_quant="int8")
    engine = ContinuousBatchingEngine(cfg, params, max_len=MAX_LEN, **kw)
    handles, _, cancelled = _drive(engine, reqs, actions,
                                   _check_paged_invariants)
    _check_drained(engine.cache)
    oracle = _replay(cfg, params, ContinuousBatchingEngine, reqs, **kw)
    for uid, h in handles.items():
        want = oracle[uid].tokens
        if uid in cancelled:
            assert h.tokens == want[:len(h.tokens)], uid
        else:
            assert h.tokens == want, uid


def _check_spec_invariants(engine: ContinuousBatchingEngine) -> None:
    """Full paged sweep + the speculation-specific page-publication rule:
    pages grown during decode — which is where speculative bundles write
    their (possibly later-rejected) KV — must NEVER appear in the prefix
    index, and therefore can never park in the tiers either (parking only
    ever takes registered pages). Only full pages inside the PROMPT are
    legal index entries; everything past the prompt is decode-written and
    rollback means its content is unreliable beyond the committed length."""
    _check_paged_invariants(engine)
    cache, sched = engine.cache, engine.scheduler
    for slot, seq in sched.slots.items():
        prompt_pages = len(seq.request.prompt) // cache.page_size
        for i, p in enumerate(cache._slot_pages[slot]):
            if i >= prompt_pages:
                assert p not in cache._page_key, (
                    f"slot {slot}: decode-phase page {p} (index {i}) was "
                    f"published to the prefix index"
                )


class _AdversarialProposer:
    """Proposes k uniformly random drafts for every slot, every step:
    bundles always dispatch and essentially every draft is rejected —
    maximum rollback pressure, interleaved with preemption and tiers."""

    def __init__(self, seed: int):
        self.rng = np.random.default_rng(seed)

    def propose(self, uid, history, k):
        return [int(t) for t in self.rng.integers(1, 250, k)]

    def retire(self, uid):
        return None


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("mode", ["ngram", "draft", "adversarial"])
def test_speculative_engine_streams_and_invariants(smollm, seed, mode,
                                                   tmp_path):
    """Speculative arm of the stress trace.

    The same perturbed submit/cancel schedule runs with speculation on and
    the page pool sized to force preemption; after every step the full
    paged sweep must hold PLUS the publication rule (no decode-written —
    hence no partially-accepted — page in the prefix index or the tiers),
    and at drain every surviving stream must be byte-identical to a
    spec-OFF unperturbed replay: acceptance is exact under the
    ``(seed, token_index)``-keyed sampler, for the mixed greedy/sampled
    trace alike. Three proposer arms: ``ngram`` (the production
    self-speculation path, run with host+persist tiers engaged so
    speculation interleaves with park/spill/reclaim), ``draft`` (drafting
    with the TARGET's own weights — oracle draft, so acceptance is high
    and the multi-token commit path is exercised), and ``adversarial``
    (an injected proposer drafting random tokens every step — every
    bundle rolls back, so a single leaked or double-freed rollback page
    would trip the partition sweep within a step or two)."""
    cfg, params = smollm
    reqs, actions, _attempted = _make_trace(seed)
    kw = dict(max_slots=4, page_size=PAGE, num_pages=8, prefill_chunk=PAGE,
              prefix_sharing=True, seed=seed)
    spec_kw = dict(speculative="ngram", spec_k=3)
    if mode == "draft":
        spec_kw = dict(speculative="draft", spec_k=3,
                       draft_config=cfg, draft_params=params)
    else:
        kw.update(host_pages=16, persist_dir=str(tmp_path / "kv"))
    engine = ContinuousBatchingEngine(cfg, params, max_len=MAX_LEN,
                                      **kw, **spec_kw)
    if mode == "adversarial":
        engine.spec = _AdversarialProposer(seed)
    handles, _, cancelled = _drive(engine, reqs, actions,
                                   _check_spec_invariants)
    u = engine.utilization
    if mode == "adversarial":
        assert engine.stats["spec_bundles"] > 0, "no bundle ever dispatched"
        assert u.spec_rollbacks > 0, "rollback path unexercised"
    elif mode == "draft":
        assert engine.stats["spec_bundles"] > 0, "no bundle ever dispatched"
        assert u.spec_accepted > 0, (
            "oracle draft should land drafts: commit path unexercised")
    _check_drained(engine.cache)

    oracle = _replay(cfg, params, ContinuousBatchingEngine, reqs,
                     max_slots=4, page_size=PAGE, prefill_chunk=PAGE,
                     prefix_sharing=True, seed=seed)
    for uid, h in handles.items():
        want = oracle[uid].tokens
        if uid in cancelled:
            assert h.tokens == want[:len(h.tokens)], uid
        else:
            assert h.tokens == want, uid


@pytest.mark.parametrize("seed", [0])
def test_lockstep_engine_invariants_under_stress(smollm, seed):
    cfg, params = smollm
    reqs, actions, _attempted = _make_trace(seed, n=10)
    engine = GenerationEngine(cfg, params, max_len=MAX_LEN, max_batch=4,
                              seed=seed)
    handles, _, cancelled = _drive(engine, reqs, actions,
                                   _check_lockstep_invariants)
    for uid, h in handles.items():
        assert isinstance(h.finish_reason, FinishReason), uid
        if uid in cancelled:
            assert h.finish_reason is FinishReason.CANCELLED

    oracle = _replay(cfg, params, GenerationEngine, reqs, max_batch=4,
                     seed=seed)
    for uid, h in handles.items():
        want = oracle[uid].tokens
        if uid in cancelled:
            assert h.tokens == want[:len(h.tokens)], uid
        else:
            assert h.tokens == want, uid

# ---------------------------------------------------------------------------
# SSM / hybrid recurrent-state engine arms
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mamba2():
    cfg = reduced(ARCHS["mamba2-1.3b"])
    model = build_model(cfg)
    return cfg, model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def zamba2():
    cfg = reduced(ARCHS["zamba2-2.7b"])
    model = build_model(cfg)
    return cfg, model.init(jax.random.key(0))


def _check_ssm_invariants(engine: SSMEngine) -> None:
    """Slot-bank bookkeeping: live sequences and the free list exactly
    partition the slot range (pure SSM) or match the cache's occupancy
    (hybrid), and parked state snapshots belong only to evicted-but-live
    requests — never to an occupant or a finished handle."""
    live = set(engine.slots)
    if engine.hybrid:
        cache = engine.cache
        assert live == {s for s in range(cache.max_slots)
                        if cache._slot_pages[s]}, "slot/page-map mismatch"
    else:
        free = engine._free
        assert len(set(free)) == len(free), "double-freed slot"
        assert not set(free) & live, "slot simultaneously free and live"
        assert set(free) | live == set(range(engine.max_slots)), "leaked slot"
    for slot, seq in engine.slots.items():
        assert len(seq.tokens) <= seq.request.sampling.max_new_tokens
        assert seq.request.uid not in engine._snapshots, (
            f"slot {slot}: occupant still has a parked snapshot")
    for uid in engine._snapshots:
        h = engine._handles.get(uid)
        assert h is not None and not h.done, (
            f"snapshot parked for finished/unknown request {uid}")


@pytest.mark.parametrize("seed", [0, 1])
def test_ssm_engine_invariants_under_stress(mamba2, seed):
    """The randomized submit/cancel trace on the recurrent-state engine,
    with forced youngest-first preemptions injected mid-trace — alternating
    discard (re-prefill) and snapshot (state restored verbatim) eviction —
    and only 2 slots so the queue stays under pressure. Every surviving
    stream must be byte-identical to an unperturbed replay: preemption of
    either flavor is invisible under the (seed, token_index)-keyed
    sampler."""
    cfg, params = mamba2
    reqs, actions, _attempted = _make_trace(seed, n=10)
    by_uid = {r.uid: r for r in reqs}
    engine = SSMEngine(cfg, params, max_len=MAX_LEN, max_slots=2,
                       prefill_chunk=PAGE, seed=seed)
    handles, cancelled = {}, set()
    preempt_at = {4: False, 7: True, 10: False, 13: True}  # step -> snapshot
    step = 0
    while True:
        for kind, uid in actions.get(step, []):
            if kind == "submit":
                handles[uid] = engine.submit(by_uid[uid])
            elif engine.cancel(uid):
                cancelled.add(uid)
        if step in preempt_at:
            engine.preempt_youngest(snapshot=preempt_at[step])
        engine.step()
        _check_ssm_invariants(engine)
        step += 1
        if all(s <= step for s in actions) and engine.idle:
            break
        assert step < 600, "trace failed to drain"
    assert engine.stats["preemptions"] > 0
    assert engine.stats["restores"] > 0, (
        "no snapshot preemption ever restored: move the snapshot steps")
    assert not engine._snapshots, "parked snapshot leaked past drain"
    assert len(engine._free) == engine.max_slots

    oracle = _replay(cfg, params, SSMEngine, reqs, max_slots=2,
                     prefill_chunk=PAGE, seed=seed)
    for uid, h in handles.items():
        assert isinstance(h.finish_reason, FinishReason), uid
        want = oracle[uid].tokens
        if uid in cancelled:
            assert h.tokens == want[:len(h.tokens)], uid
        else:
            assert h.tokens == want, uid


@pytest.mark.parametrize("seed", [0, 1])
def test_ssm_engine_restart_mid_trace(mamba2, seed):
    """Crash-replay arm for the SSM engine (the PR-7 fleet recovery model):
    the engine dies mid-trace — recurrent state gone, handles stranded —
    and a fresh engine re-serves every in-flight request under its original
    sampling seed. The resumed sequences re-prefill from scratch, yet the
    combined streams must be byte-identical to the unperturbed oracle with
    the pre-crash delivery an exact prefix of the regenerated stream."""
    cfg, params = mamba2
    reqs, actions, _attempted = _make_trace(seed, n=10)
    by_uid = {r.uid: r for r in reqs}
    kw = dict(max_slots=3, prefill_chunk=PAGE, seed=seed)
    engine = SSMEngine(cfg, params, max_len=MAX_LEN, **kw)
    handles, cancelled = {}, set()
    # crash once the trace is genuinely mid-flight: past the submit bursts
    # and cancels, with at least one request mid-stream (the chunked SSM
    # prefill makes the fixed-step-6 crash of the paged arm too early on
    # some seeds)
    step = 0
    while True:
        for kind, uid in actions.get(step, []):
            if kind == "submit":
                handles[uid] = engine.submit(by_uid[uid])
            elif engine.cancel(uid):
                cancelled.add(uid)
        engine.step()
        _check_ssm_invariants(engine)
        step += 1
        mid_stream = any(h.tokens for h in handles.values() if not h.done)
        if step >= 6 and all(s < step for s in actions) and mid_stream:
            break
        assert step < 600, "trace never reached a crashable state"

    delivered = {uid: list(h.tokens) for uid, h in handles.items()}
    pre_crash = {uid: h for uid, h in handles.items() if h.done}
    inflight = [uid for uid, h in handles.items() if not h.done]
    assert inflight, "crash step too late: nothing was in flight"
    assert any(delivered[u] for u in inflight), (
        "crash step too early: no mid-stream request to resume")
    del engine

    engine2 = SSMEngine(cfg, params, max_len=MAX_LEN, **kw)
    handles2 = {
        uid: engine2.submit(Request(uid, list(by_uid[uid].prompt),
                                    sampling=by_uid[uid].sampling))
        for uid in inflight
    }
    steps = 0
    while not engine2.idle:
        engine2.step()
        _check_ssm_invariants(engine2)
        steps += 1
        assert steps < 600, "restarted trace failed to drain"

    oracle = _replay(cfg, params, SSMEngine, reqs, **kw)
    for uid, h in pre_crash.items():
        want = oracle[uid].tokens
        if uid in cancelled:
            assert h.tokens == want[:len(h.tokens)], uid
        else:
            assert h.tokens == want, uid
    for uid, h in handles2.items():
        assert h.finish_reason in (FinishReason.LENGTH, FinishReason.STOP), uid
        assert h.tokens == oracle[uid].tokens, uid
        pre = delivered[uid]
        assert h.tokens[:len(pre)] == pre, (
            f"{uid}: pre-crash delivery is not a prefix of the replay")


@pytest.mark.parametrize("seed", [0])
def test_hybrid_engine_invariants_under_stress(zamba2, seed):
    """Hybrid (Zamba2) arm: attention pages and recurrent state advance in
    the same step, with the page pool sized so decode-time growth runs it
    dry and ORGANIC youngest-first preemption fires. Streams must still be
    byte-identical to an unpressured replay."""
    cfg, params = zamba2
    reqs, actions, _attempted = _make_trace(seed, n=10)
    engine = SSMEngine(cfg, params, max_len=MAX_LEN, max_slots=4,
                       page_size=PAGE, num_pages=8, prefill_chunk=PAGE,
                       seed=seed)
    handles, _events, cancelled = _drive(engine, reqs, actions,
                                         _check_ssm_invariants)
    assert engine.stats["preemptions"] > 0, (
        "trace too gentle: hybrid page-pressure preemption never fired")
    assert engine.cache.pool.available == engine.cache.num_pages - 1

    oracle = _replay(cfg, params, SSMEngine, reqs, max_slots=4,
                     page_size=PAGE, prefill_chunk=PAGE, seed=seed)
    for uid, h in handles.items():
        assert isinstance(h.finish_reason, FinishReason), uid
        want = oracle[uid].tokens
        if uid in cancelled:
            assert h.tokens == want[:len(h.tokens)], uid
        else:
            assert h.tokens == want, uid
