"""Per-architecture smoke tests (reduced configs) + decode-path consistency.

Every assigned architecture: one forward/train step on CPU asserting output
shapes and finiteness, plus the strongest cache test there is — prefill(T)
then decode k tokens must reproduce prefill(T+k)'s last logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, describe, reduced
from repro.configs.base import ShapeConfig
from repro.compat import tree_leaves_with_path
from repro.models import build_model
from repro.models.api import make_batch
from repro.models.lm import chunked_cross_entropy, padded_vocab

SMOKE = ShapeConfig("smoke", seq_len=64, global_batch=2, kind="train")
ARCH_NAMES = sorted(ARCHS)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_train_step(name):
    cfg = reduced(ARCHS[name])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, SMOKE, seed=1)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (name, loss)
    assert np.isfinite(float(metrics["ce"]))
    # gradients exist and are finite for every leaf
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    for path, g in tree_leaves_with_path(grads):
        assert np.isfinite(np.asarray(g)).all(), (name, path)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_prefill_decode_consistency(name):
    """decode with cache == full forward: prefill(T) + k decode steps must
    match the last-position logits of prefill(T+k)."""
    import dataclasses

    cfg = reduced(ARCHS[name])
    if cfg.family == "moe":
        # capacity dropping (cf=1.25) perturbs prefill outputs vs the exact
        # decode path; raise capacity so the test isolates CACHE correctness
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    t, k = 32, 4
    full = make_batch(cfg, ShapeConfig("c", seq_len=t + k, global_batch=2, kind="train"), seed=2)
    toks = full["tokens"]

    def sub_batch(upto):
        b = {"tokens": toks[:, :upto]}
        if "vision_embeds" in full:
            b["vision_embeds"] = full["vision_embeds"]
        if "frames" in full:
            b["frames"] = full["frames"]  # encoder input fixed across steps
        return b

    max_len = t + k + 8 + cfg.num_frontend_tokens
    cache, logits = jax.jit(lambda p, b: model.prefill(p, b, max_len))(params, sub_batch(t))
    decode = jax.jit(model.decode_step)
    for i in range(k):
        cache, logits = decode(params, cache, toks[:, t + i: t + i + 1])
    _, want = jax.jit(lambda p, b: model.prefill(p, b, max_len))(params, sub_batch(t + k))
    got = np.asarray(logits, np.float32)[:, : cfg.vocab_size]
    wantv = np.asarray(want, np.float32)[:, : cfg.vocab_size]
    np.testing.assert_allclose(got, wantv, atol=2e-3, rtol=2e-3)


def test_vocab_padding_exact():
    """Padded vocab columns must not change the CE loss."""
    rng = np.random.default_rng(0)
    b, s, d, v = 2, 16, 8, 100  # padded to 256
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, 256)), jnp.float32)
    t = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    loss_pad = chunked_cross_entropy(x, w, t, real_vocab=v, chunk=8)
    logits = np.asarray(x @ w[:, :v], np.float32)
    lse = np.log(np.exp(logits - logits.max(-1, keepdims=True)).sum(-1)) + logits.max(-1)
    nll = lse - np.take_along_axis(logits, np.asarray(t)[..., None], -1)[..., 0]
    np.testing.assert_allclose(float(loss_pad), nll.mean(), atol=1e-4, rtol=1e-4)


def test_chunked_ce_matches_full():
    rng = np.random.default_rng(1)
    b, s, d, v = 2, 32, 16, 256
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
    t = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    l1 = chunked_cross_entropy(x, w, t, real_vocab=v, chunk=8)
    l2 = chunked_cross_entropy(x, w, t, real_vocab=v, chunk=32)
    np.testing.assert_allclose(float(l1), float(l2), atol=1e-5, rtol=1e-5)
    # gradient flows through the checkpointed chunks
    g = jax.grad(lambda xx: chunked_cross_entropy(xx, w, t, real_vocab=v, chunk=8))(x)
    assert np.isfinite(np.asarray(g)).all()


def test_param_counts_match_published():
    """Analytic param counts must land near the published sizes."""
    expect = {
        "grok-1-314b": 314e9, "dbrx-132b": 132e9, "qwen3-32b": 32.8e9,
        "phi3-medium-14b": 14e9, "smollm-360m": 360e6, "llama3-8b": 8e9,
        "zamba2-2.7b": 2.7e9, "mamba2-1.3b": 1.3e9,
    }
    for name, want in expect.items():
        got = ARCHS[name].param_count()
        assert abs(got - want) / want < 0.15, (name, got, want)


def test_padded_vocab_multiple():
    for cfg in ARCHS.values():
        pv = padded_vocab(cfg)
        assert pv % 256 == 0 and pv >= cfg.vocab_size


@pytest.mark.parametrize("name", ["grok-1-314b", "dbrx-132b"])
def test_moe_capacity_drop_monotone(name):
    """With capacity_factor -> large no tokens drop; outputs stay finite and
    the decode (s=1) path works on the same params."""
    import dataclasses
    cfg = dataclasses.replace(reduced(ARCHS[name]), capacity_factor=8.0)
    from repro.models.moe import moe_block, moe_param_specs
    from repro.models.common import init_params
    p = init_params(moe_param_specs(cfg), jax.random.key(0), "float32")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.1, jnp.float32)
    y, aux = moe_block(p, x, cfg)
    assert y.shape == x.shape and np.isfinite(np.asarray(y)).all()
    # decode path (s=1) consistent with the capacity path at full capacity
    y1, _ = moe_block(p, x[:, :1], cfg)
    assert np.isfinite(np.asarray(y1)).all()


def test_group_remat_matches_plain():
    """Nested group checkpointing is a pure memory knob — loss/grads equal."""
    import dataclasses

    base = dataclasses.replace(reduced(ARCHS["llama3-8b"]), num_layers=4)
    batch = make_batch(base, SMOKE, seed=3)
    vals = {}
    for policy in ("nothing", "group2", "group2names"):
        cfg = dataclasses.replace(base, remat_policy=policy)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        loss, _ = jax.jit(model.loss_fn)(params, batch)
        g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
        vals[policy] = (float(loss), g)
    l0, g0 = vals["nothing"]
    for policy in ("group2", "group2names"):
        l1, g1 = vals[policy]
        assert abs(l1 - l0) < 1e-5, (policy, l0, l1)
        # recompute reorders float accumulation; compare by relative norm
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
            num = np.linalg.norm(a - b)
            den = max(np.linalg.norm(a), 1e-9)
            assert num / den < 0.02, (policy, num / den)


def test_padded_heads_zero_init_is_identity():
    """Padded o-proj rows are zero-init: the padded heads contribute nothing
    to the block output at init (so padding is a pure sharding trick)."""
    import dataclasses

    cfg = dataclasses.replace(
        reduced(ARCHS["phi3-medium-14b"]), num_heads_padded=8, num_kv_heads_padded=4
    )
    assert cfg.eff_heads == 8 and cfg.eff_kv_heads == 4
    from repro.models.attention import attn_param_specs, self_attention
    from repro.models.common import init_params

    p = init_params(attn_param_specs(cfg), jax.random.key(1), "float32")
    # wo rows for the padded heads are zero
    np.testing.assert_array_equal(np.asarray(p["wo"]), 0.0)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.3, jnp.float32)
    out = self_attention(p, x, cfg)
    assert out.shape == x.shape
    np.testing.assert_array_equal(np.asarray(out), 0.0)  # zero o-proj at init
    # and it trains: gradient reaches wq through wo being updated first step
    g = jax.grad(lambda pp: jnp.sum(self_attention(pp, x, cfg) ** 2))(p)
    assert np.isfinite(np.asarray(g["wo"])).all()
