"""C2: capsule capture — id stability, drift detection."""

import dataclasses

from repro.core.capsule import Capsule, capture, seal_step
from repro.core.dag import Step


def make_step():
    def fn(inputs):
        return {"y": inputs.get("x", 0) + 1}

    return Step("s", fn=fn, reads={"x"}, writes={"y"})


def test_capsule_id_stable():
    s = make_step()
    c1 = capture(s, config={"lr": 0.1})
    c2 = capture(s, config={"lr": 0.1})
    assert c1.capsule_id == c2.capsule_id


def test_capsule_id_sensitive_to_config():
    s = make_step()
    assert capture(s, {"lr": 0.1}).capsule_id != capture(s, {"lr": 0.2}).capsule_id


def test_capsule_roundtrip_json():
    c = capture(make_step(), {"a": 1}, seeds={"train": 7})
    c2 = Capsule.from_json(c.to_json())
    assert c2.capsule_id == c.capsule_id
    assert c2.seeds == {"train": 7}


def test_drift_detection():
    img = seal_step(make_step(), config={})
    current = capture(make_step(), config={})
    assert img.verify_against(current) == []  # same env -> no drift
    drifted = dataclasses.replace(current, packages={**current.packages, "jax": "9.9.9"})
    report = img.verify_against(drifted)
    assert any("jax" in line for line in report)


def test_capsule_captures_packages_and_platform():
    c = capture(make_step())
    assert "jax" in c.packages and "numpy" in c.packages
    assert c.platform["jax_backend"] in ("cpu", "tpu", "gpu")
    assert make_step().name in c.code
