"""C6: scheduler fault tolerance — retries, speculative replicas, liveness,
chaos recovery — plus C3 deployer rendering."""

import time
from pathlib import Path

import pytest

from repro.core import ArtifactStore, TopicBus, WorkflowScheduler
from repro.core.dag import Step, StepGraph
from repro.core.deployer import DynamicPodDeployer, PodManager
from repro.core.faults import FaultInjector, KillRule
from repro.core.scheduler import RetryPolicy


def make_graph(steps, edges):
    return StepGraph(steps=steps, edges=edges).validate()


def run(graph, tmp_path, faults=None, retry=None, **kw):
    bus = TopicBus(tmp_path / "bus")
    store = ArtifactStore(tmp_path / "store")
    sched = WorkflowScheduler(
        graph, bus, store,
        retry=retry or RetryPolicy(max_attempts=4, backoff_s=0.01),
        fault_injector=faults, **kw,
    )
    return sched, sched.run(timeout_s=60)


def test_diamond_workflow_runs(tmp_path):
    steps = {
        "src": Step("src", fn=lambda i: {"x": 10}, writes={"x"}, replicas=1),
        "l": Step("l", fn=lambda i: {"a": i["x"] + 1}, reads={"x"}, writes={"a"}, replicas=1),
        "r": Step("r", fn=lambda i: {"b": i["x"] * 2}, reads={"x"}, writes={"b"}, replicas=1),
        "join": Step("join", fn=lambda i: {"y": i["a"] + i["b"]},
                     reads={"a", "b"}, writes={"y"}, replicas=1),
    }
    edges = {("src", "l"): {"x"}, ("src", "r"): {"x"},
             ("l", "join"): {"a"}, ("r", "join"): {"b"}}
    _, arts = run(make_graph(steps, edges), tmp_path)
    assert arts["y"] == 31


def test_retry_after_crash(tmp_path):
    attempts = []

    def flaky(inputs):
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return {"v": 42}

    steps = {"s": Step("s", fn=flaky, writes={"v"}, replicas=1, max_attempts=4)}
    sched, arts = run(make_graph(steps, {}), tmp_path)
    assert arts["v"] == 42 and len(attempts) == 3
    kinds = [e["kind"] for e in sched.events.history()]
    assert kinds.count("step_retry_scheduled") == 2
    assert kinds.count("step_error") == 2


def test_permanent_failure_raises(tmp_path):
    def broken(inputs):
        raise ValueError("always")

    steps = {"s": Step("s", fn=broken, writes={"v"}, replicas=1)}
    with pytest.raises(RuntimeError, match="failed after"):
        run(make_graph(steps, {}), tmp_path,
            retry=RetryPolicy(max_attempts=2, backoff_s=0.01))


def test_speculative_replicas_first_wins(tmp_path):
    """ReplicaSet analogue: slow replica is superseded by the fast one."""
    def racy(inputs, ctx):
        if ctx.attempt % 2 == 0:  # even attempts are fast
            return {"v": ctx.attempt}
        for _ in range(200):
            time.sleep(0.02)
            ctx.check()  # cancelled when a sibling wins
        return {"v": -1}

    steps = {"s": Step("s", fn=racy, writes={"v"}, replicas=3)}
    sched, arts = run(make_graph(steps, {}), tmp_path)
    assert arts["v"] % 2 == 0
    done = sched.events.history("step_done")
    assert len(done) == 1  # idempotent completion despite 3 replicas


def test_chaos_kill_then_recover(tmp_path):
    calls = []

    def work(inputs, ctx):
        calls.append(ctx.attempt)
        for _ in range(30):
            time.sleep(0.01)
            ctx.beat(progress=len(calls))
        return {"v": "done"}

    faults = FaultInjector([KillRule(step="s", after_s=0.05, times=1)])
    steps = {"s": Step("s", fn=work, writes={"v"}, replicas=1, max_attempts=4)}
    sched, arts = run(make_graph(steps, {}), tmp_path, faults=faults)
    assert arts["v"] == "done"
    assert len(calls) >= 2  # first attempt killed, retry succeeded


def test_long_running_forces_single_replica(tmp_path):
    ran = []

    def trainer(inputs, ctx):
        ran.append(ctx.pod_name)
        return {"v": 1}

    steps = {"s": Step("s", fn=trainer, writes={"v"}, replicas=3, long_running=True)}
    _, arts = run(make_graph(steps, {}), tmp_path)
    assert len(ran) == 1  # DESIGN.md changed-assumption #2


def test_artifacts_stored_with_refs(tmp_path):
    steps = {"s": Step("s", fn=lambda i: {"v": [1, 2, 3]}, writes={"v"}, replicas=1)}
    sched, arts = run(make_graph(steps, {}), tmp_path)
    done = sched.events.history("step_done")[0]
    ref = done["refs"]["v"]
    assert sched.store.get(ref) == [1, 2, 3]


# ---------------------------------------------------------------------------
# deployer (C3)
# ---------------------------------------------------------------------------


def test_pod_manager_roles_and_topics():
    steps = {
        "a": Step("a", fn=lambda i: {"x": 1}, writes={"x"}),
        "b": Step("b", fn=lambda i: {"y": 1}, reads={"x"}, writes={"y"}),
        "c": Step("c", fn=lambda i: {}, reads={"y"}),
    }
    g = make_graph(steps, {("a", "b"): {"x"}, ("b", "c"): {"y"}})
    pm = PodManager(g)
    assert pm.role_of("a") == "producer"
    assert pm.role_of("b") == "both"
    assert pm.role_of("c") == "consumer"
    in_t, out_t = pm.topics_of("b")
    assert in_t == ["pipe.a.b"] and out_t == ["pipe.b.c"]


def test_deployer_renders_paper_listing1(tmp_path):
    steps = {"train": Step("train", fn=lambda i: {"m": 1}, writes={"m"})}
    g = make_graph(steps, {})
    dep = DynamicPodDeployer(PodManager(g), out_dir=tmp_path / "k8s")
    specs = dep.deploy_all()
    y = (tmp_path / "k8s" / "train-deployment.yaml").read_text()
    # the paper's Listing 1 structure, faithfully
    for needle in ["apiVersion: apps/v1", "kind: Deployment", "replicas: 3",
                   "RollingUpdate", "maxUnavailable: 1", "maxSurge: 1",
                   "KAFKA_BROKER", "livenessProbe", "readinessProbe",
                   "/healthz", "/readiness", "persistentVolumeClaim",
                   "mountPath: /mnt/efs"]:
        assert needle in y, needle
    pv = (tmp_path / "k8s" / "train-storage.yaml").read_text()
    assert "PersistentVolume" in pv and "PersistentVolumeClaim" in pv
    assert specs[0].replicas == 3  # paper default


def test_straggler_hedging(tmp_path):
    """A slow-but-alive attempt triggers ONE hedged speculative attempt;
    the fast hedge wins and the straggler is cancelled."""
    import threading

    state = {"n": 0}
    lock = threading.Lock()

    def work(inputs, ctx):
        with lock:
            state["n"] += 1
            first = state["n"] == 1
        if first:  # the straggler: alive (heartbeating) but slow
            for _ in range(500):
                time.sleep(0.02)
                ctx.beat(progress=1)
            return {"v": "slow"}
        return {"v": "fast"}

    steps = {"s": Step("s", fn=work, writes={"v"}, replicas=1, max_attempts=4)}
    bus = TopicBus(tmp_path / "bus")
    store = ArtifactStore(tmp_path / "store")
    sched = WorkflowScheduler(
        make_graph(steps, {}), bus, store,
        retry=RetryPolicy(max_attempts=4, backoff_s=0.01),
        hedge_after_s=0.2,
    )
    arts = sched.run(timeout_s=60)
    assert arts["v"] == "fast"
    kinds = [e["kind"] for e in sched.events.history()]
    assert kinds.count("pod_hedged") == 1
    assert kinds.count("step_done") == 1


# ---------------------------------------------------------------------------
# serving StepPlan: the fused engine step's host-side plan
# ---------------------------------------------------------------------------


import jax.numpy as jnp
import numpy as np

from repro.serving import PagedKVCache, Request, RequestHandle
from repro.serving.kv_cache import NULL_PAGE
from repro.serving.scheduler import Scheduler, StepPlan


def _cache(**kw):
    args = dict(num_layers=1, num_kv_heads=1, head_dim=4, dtype=jnp.float32,
                max_slots=3, max_context=64, page_size=8)
    args.update(kw)
    return PagedKVCache(**args)


def _sched(cache, **kw):
    args = dict(prefill_chunk=8, chunked=True, prefix_sharing=True)
    args.update(kw)
    return Scheduler(cache, **args)


def _req(uid, prompt, **kw):
    r = Request(uid, prompt, **kw)
    return r, RequestHandle(r)


def _start_decode(sched, uid, prompt, first_tok=7):
    """Place + fully prefill one request so its slot is decodable."""
    slot, seq, _ = sched.place(*_req(uid, prompt))
    while True:
        work = sched.next_prefill()
        assert work.slot == slot
        if sched.complete_chunk(work):
            break
    seq.tokens.append(first_tok)
    sched.begin_decode(slot)
    return slot, seq


def test_step_plan_degenerate_shapes():
    """Empty scheduler -> empty plan; prefill-only -> chunk-only plan;
    decode-only -> no chunk; step_tokens accounts both parts."""
    sched = _sched(_cache())
    plan = sched.build_step_plan()
    assert isinstance(plan, StepPlan)
    assert plan.decode_slots == [] and plan.decode is None
    assert plan.chunk is None and plan.step_tokens == 0

    # prefill-only: the plan carries the chunk, no decode batch
    sched.place(*_req("p", list(range(1, 13))))
    plan = sched.build_step_plan()
    assert plan.decode_slots == [] and plan.decode is None
    assert plan.chunk is not None and plan.chunk.valid == 8
    assert plan.step_tokens == 8

    # decode-only: complete the prefill; no chunk remains
    sched.complete_chunk(sched.next_prefill())
    sched.complete_chunk(sched.next_prefill())
    slot = plan.chunk.slot
    sched.slots[slot].tokens.append(3)
    sched.begin_decode(slot)
    plan = sched.build_step_plan()
    assert plan.decode_slots == [slot] and plan.chunk is None
    assert plan.step_tokens == 1
    assert plan.decode is not None  # composition changed -> batch rebuilt


def test_step_plan_token_budget_accounting():
    """The chunk's live tokens fill budget - decode_rows; a budget already
    spent by decode rows defers the chunk; no decode rows waives the cap."""
    cache = _cache(max_slots=3, num_pages=32)
    sched = _sched(cache, token_budget=6)
    s0, _ = _start_decode(sched, "d0", list(range(1, 7)))
    s1, _ = _start_decode(sched, "d1", list(range(20, 26)))
    sched.place(*_req("p", list(range(40, 52))))  # 12 tokens to prefill

    plan = sched.build_step_plan()
    assert plan.decode_slots == sorted([s0, s1])
    assert plan.chunk is not None
    assert plan.chunk.valid == 4           # 6 budget - 2 decode rows
    assert plan.step_tokens == 6
    # under a budget the STATIC buffer shrinks to budget - decode_rows:
    # the live tokens can never exceed that, so a wider buffer would only
    # add masked-dead compute to every fused dispatch
    assert plan.chunk.tokens.shape == (4,)
    assert plan.chunk.valid == plan.chunk.tokens.shape[0]
    sched.complete_chunk(plan.chunk)

    # budget <= decode rows: the chunk is deferred, decode still runs
    sched.token_budget = 2
    plan = sched.build_step_plan()
    assert plan.chunk is None
    assert plan.step_tokens == 2

    # no decode rows in flight: the budget is waived (progress guarantee)
    for s in list(plan.decode_slots):
        sched.release(s)
    plan = sched.build_step_plan()
    assert plan.decode_slots == []
    assert plan.chunk is not None and plan.chunk.valid == 8
    assert plan.step_tokens == 8


def test_step_plan_preemption_mid_chunk():
    """A sequence preempted mid-prefill vanishes from the next plan: its
    chunk is not dispatched and its slot is not harvested."""
    cache = _cache(num_pages=5, max_slots=3)  # 4 usable pages
    sched = _sched(cache, prefix_sharing=False)
    s0, seq0 = _start_decode(sched, "old", list(range(1, 16)))  # 2 pages
    # the youngest sequence is mid-prefill when the pool runs dry
    sched.place(*_req("young", [90 + i for i in range(15)]))
    assert sched.next_prefill() is not None
    cache.lengths[s0] = 16  # next decode write needs a 3rd page: none free
    preempted = sched.ensure_decode_capacity()
    assert [s.request.uid for s in preempted] == ["young"]

    plan = sched.build_step_plan()
    assert plan.chunk is None              # the mid-chunk prefill is gone
    assert plan.decode_slots == [s0]
    assert plan.decode is not None         # eviction dirtied the batch
    assert plan.decode.active[s0] == 1


def test_step_plan_static_shapes_and_mirror_reuse():
    """Decode batches keep (max_slots,)-static shapes across steps, clean
    steady-state plans skip the rebuild (decode=None), and append_decoded
    keeps the mirrors current without dirtying."""
    cache = _cache(max_slots=3, num_pages=32)
    sched = _sched(cache)
    s0, seq0 = _start_decode(sched, "a", list(range(1, 7)))
    plan1 = sched.build_step_plan()
    d = plan1.decode
    assert d.tokens.shape == (3, 1) and d.active.shape == (3,)
    assert d.block_tables.shape == cache.block_tables.shape
    assert d.active[s0] == 1 and d.lengths[s0] == cache.lengths[s0]
    idle = [s for s in range(3) if s != s0]
    assert (d.block_tables[idle] == NULL_PAGE).all()
    assert sched.dirty is False

    # harvest: mirrors advance in lockstep with the device, still clean
    sched.append_decoded(s0, 42)
    assert sched.dirty is False
    plan2 = sched.build_step_plan()
    assert plan2.decode is None            # zero-transfer steady state
    assert plan2.decode_slots == [s0] and plan2.step_tokens == 1
    assert sched._mir_tokens[s0, 0] == 42
    assert sched._mir_idx[s0] == len(seq0.tokens)
    assert sched._mir_lens[s0] == cache.lengths[s0]

    # a composition change re-dirties and the rebuilt batch matches a
    # from-scratch refresh of every slot
    s1, _ = _start_decode(sched, "b", list(range(30, 37)), first_tok=9)
    plan3 = sched.build_step_plan()
    assert plan3.decode is not None
    fresh = Scheduler(cache, prefill_chunk=8, chunked=True,
                      prefix_sharing=True)
    fresh.slots = sched.slots
    rebuilt = fresh.build_decode_inputs()
    for a, b in zip(
        (plan3.decode.tokens, plan3.decode.active, plan3.decode.lengths,
         plan3.decode.block_tables, plan3.decode.idx),
        (rebuilt.tokens, rebuilt.active, rebuilt.lengths,
         rebuilt.block_tables, rebuilt.idx),
    ):
        np.testing.assert_array_equal(a, b)
