"""C6: scheduler fault tolerance — retries, speculative replicas, liveness,
chaos recovery — plus C3 deployer rendering."""

import time
from pathlib import Path

import pytest

from repro.core import ArtifactStore, TopicBus, WorkflowScheduler
from repro.core.dag import Step, StepGraph
from repro.core.deployer import DynamicPodDeployer, PodManager
from repro.core.faults import FaultInjector, KillRule
from repro.core.scheduler import RetryPolicy


def make_graph(steps, edges):
    return StepGraph(steps=steps, edges=edges).validate()


def run(graph, tmp_path, faults=None, retry=None, **kw):
    bus = TopicBus(tmp_path / "bus")
    store = ArtifactStore(tmp_path / "store")
    sched = WorkflowScheduler(
        graph, bus, store,
        retry=retry or RetryPolicy(max_attempts=4, backoff_s=0.01),
        fault_injector=faults, **kw,
    )
    return sched, sched.run(timeout_s=60)


def test_diamond_workflow_runs(tmp_path):
    steps = {
        "src": Step("src", fn=lambda i: {"x": 10}, writes={"x"}, replicas=1),
        "l": Step("l", fn=lambda i: {"a": i["x"] + 1}, reads={"x"}, writes={"a"}, replicas=1),
        "r": Step("r", fn=lambda i: {"b": i["x"] * 2}, reads={"x"}, writes={"b"}, replicas=1),
        "join": Step("join", fn=lambda i: {"y": i["a"] + i["b"]},
                     reads={"a", "b"}, writes={"y"}, replicas=1),
    }
    edges = {("src", "l"): {"x"}, ("src", "r"): {"x"},
             ("l", "join"): {"a"}, ("r", "join"): {"b"}}
    _, arts = run(make_graph(steps, edges), tmp_path)
    assert arts["y"] == 31


def test_retry_after_crash(tmp_path):
    attempts = []

    def flaky(inputs):
        attempts.append(1)
        if len(attempts) < 3:
            raise RuntimeError("transient")
        return {"v": 42}

    steps = {"s": Step("s", fn=flaky, writes={"v"}, replicas=1, max_attempts=4)}
    sched, arts = run(make_graph(steps, {}), tmp_path)
    assert arts["v"] == 42 and len(attempts) == 3
    kinds = [e["kind"] for e in sched.events.history()]
    assert kinds.count("step_retry_scheduled") == 2
    assert kinds.count("step_error") == 2


def test_permanent_failure_raises(tmp_path):
    def broken(inputs):
        raise ValueError("always")

    steps = {"s": Step("s", fn=broken, writes={"v"}, replicas=1)}
    with pytest.raises(RuntimeError, match="failed after"):
        run(make_graph(steps, {}), tmp_path,
            retry=RetryPolicy(max_attempts=2, backoff_s=0.01))


def test_speculative_replicas_first_wins(tmp_path):
    """ReplicaSet analogue: slow replica is superseded by the fast one."""
    def racy(inputs, ctx):
        if ctx.attempt % 2 == 0:  # even attempts are fast
            return {"v": ctx.attempt}
        for _ in range(200):
            time.sleep(0.02)
            ctx.check()  # cancelled when a sibling wins
        return {"v": -1}

    steps = {"s": Step("s", fn=racy, writes={"v"}, replicas=3)}
    sched, arts = run(make_graph(steps, {}), tmp_path)
    assert arts["v"] % 2 == 0
    done = sched.events.history("step_done")
    assert len(done) == 1  # idempotent completion despite 3 replicas


def test_chaos_kill_then_recover(tmp_path):
    calls = []

    def work(inputs, ctx):
        calls.append(ctx.attempt)
        for _ in range(30):
            time.sleep(0.01)
            ctx.beat(progress=len(calls))
        return {"v": "done"}

    faults = FaultInjector([KillRule(step="s", after_s=0.05, times=1)])
    steps = {"s": Step("s", fn=work, writes={"v"}, replicas=1, max_attempts=4)}
    sched, arts = run(make_graph(steps, {}), tmp_path, faults=faults)
    assert arts["v"] == "done"
    assert len(calls) >= 2  # first attempt killed, retry succeeded


def test_long_running_forces_single_replica(tmp_path):
    ran = []

    def trainer(inputs, ctx):
        ran.append(ctx.pod_name)
        return {"v": 1}

    steps = {"s": Step("s", fn=trainer, writes={"v"}, replicas=3, long_running=True)}
    _, arts = run(make_graph(steps, {}), tmp_path)
    assert len(ran) == 1  # DESIGN.md changed-assumption #2


def test_artifacts_stored_with_refs(tmp_path):
    steps = {"s": Step("s", fn=lambda i: {"v": [1, 2, 3]}, writes={"v"}, replicas=1)}
    sched, arts = run(make_graph(steps, {}), tmp_path)
    done = sched.events.history("step_done")[0]
    ref = done["refs"]["v"]
    assert sched.store.get(ref) == [1, 2, 3]


# ---------------------------------------------------------------------------
# deployer (C3)
# ---------------------------------------------------------------------------


def test_pod_manager_roles_and_topics():
    steps = {
        "a": Step("a", fn=lambda i: {"x": 1}, writes={"x"}),
        "b": Step("b", fn=lambda i: {"y": 1}, reads={"x"}, writes={"y"}),
        "c": Step("c", fn=lambda i: {}, reads={"y"}),
    }
    g = make_graph(steps, {("a", "b"): {"x"}, ("b", "c"): {"y"}})
    pm = PodManager(g)
    assert pm.role_of("a") == "producer"
    assert pm.role_of("b") == "both"
    assert pm.role_of("c") == "consumer"
    in_t, out_t = pm.topics_of("b")
    assert in_t == ["pipe.a.b"] and out_t == ["pipe.b.c"]


def test_deployer_renders_paper_listing1(tmp_path):
    steps = {"train": Step("train", fn=lambda i: {"m": 1}, writes={"m"})}
    g = make_graph(steps, {})
    dep = DynamicPodDeployer(PodManager(g), out_dir=tmp_path / "k8s")
    specs = dep.deploy_all()
    y = (tmp_path / "k8s" / "train-deployment.yaml").read_text()
    # the paper's Listing 1 structure, faithfully
    for needle in ["apiVersion: apps/v1", "kind: Deployment", "replicas: 3",
                   "RollingUpdate", "maxUnavailable: 1", "maxSurge: 1",
                   "KAFKA_BROKER", "livenessProbe", "readinessProbe",
                   "/healthz", "/readiness", "persistentVolumeClaim",
                   "mountPath: /mnt/efs"]:
        assert needle in y, needle
    pv = (tmp_path / "k8s" / "train-storage.yaml").read_text()
    assert "PersistentVolume" in pv and "PersistentVolumeClaim" in pv
    assert specs[0].replicas == 3  # paper default


def test_straggler_hedging(tmp_path):
    """A slow-but-alive attempt triggers ONE hedged speculative attempt;
    the fast hedge wins and the straggler is cancelled."""
    import threading

    state = {"n": 0}
    lock = threading.Lock()

    def work(inputs, ctx):
        with lock:
            state["n"] += 1
            first = state["n"] == 1
        if first:  # the straggler: alive (heartbeating) but slow
            for _ in range(500):
                time.sleep(0.02)
                ctx.beat(progress=1)
            return {"v": "slow"}
        return {"v": "fast"}

    steps = {"s": Step("s", fn=work, writes={"v"}, replicas=1, max_attempts=4)}
    bus = TopicBus(tmp_path / "bus")
    store = ArtifactStore(tmp_path / "store")
    sched = WorkflowScheduler(
        make_graph(steps, {}), bus, store,
        retry=RetryPolicy(max_attempts=4, backoff_s=0.01),
        hedge_after_s=0.2,
    )
    arts = sched.run(timeout_s=60)
    assert arts["v"] == "fast"
    kinds = [e["kind"] for e in sched.events.history()]
    assert kinds.count("pod_hedged") == 1
    assert kinds.count("step_done") == 1
