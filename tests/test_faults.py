"""Units for chaos-injection accounting (``core/faults.py``).

The headline regression: ``_killed`` bookkeeping is hit concurrently from
the scheduler thread, every serving worker, and timer threads — before the
module-wide lock, two pods starting at once could both pass a ``times=1``
rule's check and arm two kills. The threaded tests here race real threads
through a barrier and pin exactly-once accounting.
"""

import threading
from types import SimpleNamespace

from repro.core.executor import KillSwitch
from repro.core.faults import FaultInjector, KillRule, WorkerKillRule


def _pod(step="s", attempt=0):
    return SimpleNamespace(
        image=SimpleNamespace(step=SimpleNamespace(name=step)),
        attempt=attempt,
        kill_switch=KillSwitch(),
    )


def test_on_pod_start_times_respected_under_races():
    inj = FaultInjector(rules=[KillRule(step="s", after_s=60.0, times=1)])
    n = 16
    barrier = threading.Barrier(n)
    results = [False] * n

    def runner(i):
        pod = _pod()
        barrier.wait()
        results[i] = inj.on_pod_start(pod)

    threads = [threading.Thread(target=runner, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    inj.cancel_all()
    assert sum(results) == 1, f"times=1 rule armed {sum(results)} kills"
    assert inj.kills_armed() == 1


def test_check_worker_fires_once_per_rule_budget():
    inj = FaultInjector(worker_rules=[WorkerKillRule(after_steps=3, times=2)])
    # below the threshold: never fires
    assert inj.check_worker("w0", 0, steps=0, tokens=0) is None
    assert inj.check_worker("w0", 0, steps=2, tokens=9) is None
    # at the threshold: fires, with a progress-stamped reason
    reason = inj.check_worker("w0", 0, steps=3, tokens=11)
    assert reason == "chaos:w0:a0:steps=3:tokens=11"
    # same attempt past the kill point: NOT re-killed every step
    assert inj.check_worker("w0", 0, steps=4, tokens=12) is None
    # the restarted attempt consumes the second (and last) budget unit
    assert inj.check_worker("w0", 1, steps=3, tokens=0) is not None
    assert inj.check_worker("w1", 0, steps=5, tokens=0) is None  # exhausted
    assert inj.kills_armed() == 2


def test_check_worker_filters_and_conjunction():
    rules = [
        WorkerKillRule(worker="w1", attempt=1, after_steps=1),
        WorkerKillRule(after_steps=2, after_tokens=5, times=3),
    ]
    # worker/attempt filters
    inj2 = FaultInjector(worker_rules=[rules[0]])
    assert inj2.check_worker("w0", 1, steps=9, tokens=9) is None
    assert inj2.check_worker("w1", 0, steps=9, tokens=9) is None
    assert inj2.check_worker("w1", 1, steps=0, tokens=0) is None
    assert inj2.check_worker("w1", 1, steps=1, tokens=0) is not None
    # both-set rule: BOTH thresholds must be reached
    inj3 = FaultInjector(worker_rules=[rules[1]])
    assert inj3.check_worker("a", 0, steps=2, tokens=4) is None
    assert inj3.check_worker("a", 0, steps=1, tokens=7) is None
    assert inj3.check_worker("a", 0, steps=2, tokens=5) is not None


def test_check_worker_threaded_exactly_once():
    """N workers cross a times=1 rule's threshold simultaneously: exactly
    one dies (the check-then-increment is atomic under the lock)."""
    inj = FaultInjector(worker_rules=[WorkerKillRule(after_steps=1, times=1)])
    n = 12
    barrier = threading.Barrier(n)
    out = [None] * n

    def runner(i):
        barrier.wait()
        out[i] = inj.check_worker(f"w{i}", 0, steps=1, tokens=0)

    threads = [threading.Thread(target=runner, args=(i,)) for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fired = [r for r in out if r is not None]
    assert len(fired) == 1, f"times=1 worker rule fired {len(fired)} kills"
    assert inj.kills_armed() == 1


def test_rules_without_thresholds_are_inert():
    inj = FaultInjector(worker_rules=[WorkerKillRule(worker="w0")])
    assert inj.check_worker("w0", 0, steps=100, tokens=100) is None
    assert inj.kills_armed() == 0
