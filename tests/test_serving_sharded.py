"""Scheduler/executor split: host-policy units + sharded-executor parity.

The scheduler tests drive pure host-side decisions (placement, chunk
ordering, prefix deferral, preemption, decode-batch masking) against a
:class:`PagedKVCache` without dispatching a single model step — the point
of the split. The executor tests assert the tensor-parallel mesh contract:
pages sharded along the kv-head dim, embedding replicated, and the sharded
engine producing byte-identical token streams to a forced 1-device mesh.

Sharding-specific tests need >= 2 local devices and skip otherwise; CI runs
this file (with the rest of the serving tests) under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the sharded path
is exercised on every PR without TPU hardware.
"""

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serving import (
    ContinuousBatchingEngine,
    PagedKVCache,
    Request,
    RequestHandle,
    SamplingParams,
)
from repro.serving.executor import pick_tp, serving_mesh_scope
from repro.serving.kv_cache import NULL_PAGE
from repro.serving.scheduler import Scheduler
from repro.launch.mesh import make_serving_mesh

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# scheduler: pure host-side policy
# ---------------------------------------------------------------------------


def _cache(**kw):
    args = dict(num_layers=1, num_kv_heads=1, head_dim=4, dtype=jnp.float32,
                max_slots=3, max_context=64, page_size=8)
    args.update(kw)
    return PagedKVCache(**args)


def _sched(cache, **kw):
    args = dict(prefill_chunk=8, chunked=True, prefix_sharing=True)
    args.update(kw)
    return Scheduler(cache, **args)


def _req(uid, prompt, **kw):
    r = Request(uid, prompt, **kw)
    return r, RequestHandle(r)


def test_scheduler_place_and_chunk_ordering():
    """Chunked placement starts in the prefill phase; next_prefill always
    advances the OLDEST prefill; completion flips the slot decodable."""
    sched = _sched(_cache())
    r0, h0 = _req("r0", list(range(1, 13)))   # 12 tokens: 2 chunks
    r1, h1 = _req("r1", list(range(20, 25)))  # 5 tokens: 1 chunk
    s0, q0, cached = sched.place(r0, h0)
    assert q0.phase == "prefill" and cached == 0
    s1, q1, _ = sched.place(r1, h1)
    assert not sched.has_decodable()

    work = sched.next_prefill()
    assert work.slot == s0 and work.start == 0 and work.valid == 8
    assert list(work.tokens[:8]) == r0.prompt[:8]
    assert sched.complete_chunk(work) is False   # 4 tokens left
    work = sched.next_prefill()
    assert work.slot == s0 and work.start == 8 and work.valid == 4
    assert (work.tokens[4:] == 0).all()          # padded fixed-size chunk
    assert sched.complete_chunk(work) is True
    sched.begin_decode(s0)
    q0.tokens.append(7)
    assert sched.has_decodable()
    # r1 becomes the oldest remaining prefill
    assert sched.next_prefill().slot == s1


def test_scheduler_decode_batch_masks_prefilling_slots():
    """build_decode_inputs: decoding slots carry their sampling state;
    prefilling/idle slots are masked to the null page / length 0 so the
    executor's scatter lands in the sink."""
    cache = _cache()
    sched = _sched(cache)
    r0, h0 = _req("d", [1, 2, 3, 4], sampling=SamplingParams(
        temperature=0.9, top_k=5, top_p=0.8, max_new_tokens=4, seed=11))
    h0.seed = 11
    s0, q0, _ = sched.place(r0, h0)
    sched.complete_chunk(sched.next_prefill())
    sched.begin_decode(s0)
    q0.tokens.append(42)
    r1, h1 = _req("p", list(range(1, 12)))
    s1, _, _ = sched.place(r1, h1)           # still prefilling

    inputs = sched.build_decode_inputs()
    assert sched.dirty is False
    assert inputs.greedy_only is False       # sampled request in flight
    assert inputs.active[s0] == 1 and inputs.tokens[s0, 0] == 42
    assert inputs.temps[s0] == np.float32(0.9)
    assert inputs.seeds[s0] == 11 and inputs.idx[s0] == 1
    assert inputs.active[s1] == 0
    assert (inputs.block_tables[s1] == NULL_PAGE).all()
    assert inputs.lengths[s1] == 0
    # the cache's own table for the prefilling slot is NOT nulled
    assert cache.block_tables[s1, 0] != NULL_PAGE


def test_scheduler_prefix_deferral_until_inflight_publishes():
    """Admission defers while an in-flight prefill is about to publish a
    longer prefix than the index currently holds — then admits with the
    shared pages mapped."""
    cache = _cache()
    sched = _sched(cache)
    prompt = list(range(1, 25))              # 3 full pages, 2 shareable
    r0, h0 = _req("a", prompt)
    sched.place(r0, h0)
    r1, h1 = _req("b", list(prompt))
    assert sched.can_place(r1) is False      # 16 shareable tokens pending
    sched.complete_chunk(sched.next_prefill())   # publishes page 0
    assert sched.can_place(r1) is False      # still one more page coming
    sched.complete_chunk(sched.next_prefill())   # publishes page 1
    assert sched.can_place(r1) is True
    _, _, cached = sched.place(r1, h1)
    assert cached == 16
    assert cache.stats["prefix_hits"] == 1


def test_scheduler_preempts_youngest_for_capacity():
    """ensure_decode_capacity evicts the youngest sequence (releasing its
    pages) until every decoding slot can take its next write."""
    cache = _cache(num_pages=5, max_slots=3)  # 4 usable pages
    sched = _sched(cache, prefix_sharing=False)
    seqs = []
    for i in range(2):
        r, h = _req(f"r{i}", [10 * i + j for j in range(15)])  # 2 pages each
        slot, seq, _ = sched.place(r, h)
        seq.prefill_pos = 15
        sched.begin_decode(slot)
        seq.tokens.append(1)
        seqs.append(seq)
    # both slots at 15/16 within page 2; appending past 16 needs new pages:
    # only 0 free -> the youngest must go
    cache.lengths[:] = [16, 16, 0]
    preempted = sched.ensure_decode_capacity()
    assert [s.request.uid for s in preempted] == ["r1"]
    assert sched.has_decodable()             # r0 kept and can now grow
    assert cache.pool.available >= 0 and sched.dirty


def test_scheduler_gauges():
    sched = _sched(_cache())
    r, h = _req("g", [1, 2, 3])
    slot, seq, _ = sched.place(r, h)
    assert sched.occupancy() == (0, 3)
    sched.begin_decode(slot)
    assert sched.occupancy() == (1, 3)
    used, total = sched.page_utilization()
    assert used == 1 and total == sched.cache.num_pages - 1


# ---------------------------------------------------------------------------
# executor: mesh selection + sharding contract
# ---------------------------------------------------------------------------


def test_pick_tp_respects_divisibility():
    cfg = reduced(ARCHS["smollm-360m"])      # kv=2, heads=4, ff=128, tied
    assert pick_tp(cfg, 1) == 1
    assert pick_tp(cfg, 2) == 2
    assert pick_tp(cfg, 4) == 2              # kv_heads=2 caps the degree
    assert pick_tp(cfg, 3) == 2
    untied = reduced(ARCHS["llama3-8b"])     # untied: padded vocab counts
    assert pick_tp(untied, 2) == 2


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, params


def _trace(cfg, n=5):
    rng = np.random.default_rng(3)
    reqs = [
        Request(f"t{i}",
                list(rng.integers(1, cfg.vocab_size, rng.integers(3, 40))),
                max_new_tokens=int(rng.integers(2, 9)))
        for i in range(n)
    ]
    reqs.append(Request("hot", [5, 6, 7], sampling=SamplingParams(
        temperature=1.0, top_k=20, top_p=0.9, seed=13, max_new_tokens=6)))
    return reqs


def test_executor_single_device_mesh_runs_everything(smollm):
    """The 1-device mesh is the same shard_map code path with the
    collectives compiled away — exactness vs lockstep is asserted by the
    conformance suite; here we pin the wiring."""
    cfg, params = smollm
    with serving_mesh_scope(make_serving_mesh(1)):
        eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=3,
                                       page_size=8)
    assert eng.executor.tp == 1
    assert eng.executor.mesh.axis_names == ("model",)
    out = eng.generate(_trace(cfg, n=3))
    assert all(len(o.tokens) == r.max_new_tokens
               for r, o in zip(_trace(cfg, n=3), out))
    # at drain every page is free or parked-for-reuse (tiers are on by
    # default with prefix sharing); null page 0 stays reserved
    assert (eng.cache.pool.available + eng.cache.parked_count
            == eng.cache.num_pages - 1)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device host (CI forces 4 CPU "
                           "devices via XLA_FLAGS)")
def test_pages_and_params_sharded_over_model_axis(smollm):
    """The page pool shards along the kv-head dim (same pages on every
    shard), attention weights shard along their head dims, and the token
    embedding stays replicated."""
    cfg, params = smollm
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=3,
                                   page_size=8)
    ex = eng.executor
    assert ex.tp >= 2
    kvh_local = cfg.eff_kv_heads // ex.tp
    for shard in eng.cache.k_pages.addressable_shards:
        assert shard.data.shape[3] == kvh_local       # head dim sharded
        assert shard.data.shape[1] == eng.cache.num_pages  # pages NOT
    wq = ex.params["layers"]["attn"]["wq"]
    h_local = cfg.eff_heads // ex.tp
    assert {s.data.shape[2] for s in wq.addressable_shards} == {h_local}
    emb = ex.params["embed"]
    assert all(s.data.shape == emb.shape for s in emb.addressable_shards)


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device host")
def test_sharded_engine_matches_single_device_tokens(smollm):
    """Token streams (greedy AND seeded-sampled) are byte-identical between
    the auto-sharded mesh and a forced 1-device mesh."""
    cfg, params = smollm
    sharded = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=3,
                                       page_size=8)
    assert sharded.executor.tp >= 2
    out_s = sharded.generate(_trace(cfg))
    with serving_mesh_scope(make_serving_mesh(1)):
        single = ContinuousBatchingEngine(cfg, params, max_len=64,
                                          max_slots=3, page_size=8)
    out_1 = single.generate(_trace(cfg))
    for a, b in zip(out_s, out_1):
        assert a.tokens == b.tokens, a.uid
    assert sharded.cache.pool.available == sharded.cache.num_pages - 1


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs a multi-device host")
def test_untied_vocab_sharded_logits_gather(smollm_unused=None):
    """Untied embeddings shard the unembed columns; the logits all-gather
    must reassemble the full distribution — sharded tokens equal the
    1-device mesh's, including the whole-prompt (legacy) prefill path."""
    cfg = reduced(ARCHS["llama3-8b"])
    assert not cfg.tie_embeddings
    params = build_model(cfg).init(jax.random.key(1))
    sharded = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                       page_size=8, prefill_chunk=None)
    assert sharded.executor.vocab_sharded
    reqs = [Request("u0", [1, 2, 3, 4], max_new_tokens=6),
            Request("u1", [9, 8, 7], max_new_tokens=4)]
    out_s = sharded.generate(reqs)
    with serving_mesh_scope(make_serving_mesh(1)):
        single = ContinuousBatchingEngine(cfg, params, max_len=64,
                                          max_slots=2, page_size=8,
                                          prefill_chunk=None)
    out_1 = single.generate([Request("u0", [1, 2, 3, 4], max_new_tokens=6),
                             Request("u1", [9, 8, 7], max_new_tokens=4)])
    for a, b in zip(out_s, out_1):
        assert a.tokens == b.tokens, a.uid


def test_mesh_size_that_does_not_divide_heads_is_rejected(smollm):
    cfg, params = smollm
    if jax.device_count() < 3:
        pytest.skip("needs >= 3 devices to build an indivisible mesh")
    with serving_mesh_scope(make_serving_mesh(3)):  # kv_heads=2 % 3 != 0
        with pytest.raises(ValueError, match="does not divide"):
            ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                     page_size=8)
