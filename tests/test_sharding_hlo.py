"""Logical-axis sharding rules + the HLO cost analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import analyze_hlo, parse_computations
from repro.parallel import DEFAULT_RULES, logical_to_spec, make_shardings
from repro.launch.mesh import make_host_mesh


class FakeMesh:
    """Shape-only stand-in so spec tests don't need 512 devices."""

    def __init__(self, shape: dict):
        self._shape = shape

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return dict(self._shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def spec(axes, mesh=MESH1, dims=None):
    return logical_to_spec(axes, rules=DEFAULT_RULES, mesh=mesh, dim_sizes=dims)


def test_batch_spans_pod_and_data():
    assert spec(("batch", "seq"), MESH2, (256, 4096)) == P(("pod", "data"), None)
    # single-pod mesh: the pod axis silently drops
    assert spec(("batch", "seq"), MESH1, (256, 4096)) == P("data", None)


def test_divisibility_drops_axis():
    # kv_heads=8 cannot shard over model=16 -> replicated
    assert spec(("embed", "kv_heads", "head_dim"), MESH1, (4096, 8, 128)) == \
        P("data", None, None)
    # 32 kv heads CAN shard (zamba2)
    assert spec(("embed", "kv_heads", "head_dim"), MESH1, (2560, 32, 80)) == \
        P("data", "model", None)


def test_batch_one_falls_back_to_replicated():
    assert spec(("cache_batch", "cache_seq"), MESH2, (1, 524288)) == P(None, "model")


def test_no_axis_reuse_within_spec():
    s = spec(("vocab", "ff"), MESH1, (131072, 32768))
    flat = [a for e in s if e for a in (e if isinstance(e, tuple) else (e,))]
    assert len(flat) == len(set(flat))


def test_partial_multiaxis_prefix():
    # batch=32 over (pod=2, data=16) shards fully; batch=16 keeps pod only
    assert spec(("batch",), MESH2, (32,)) == P(("pod", "data"))
    assert spec(("batch",), MESH2, (16,)) == P(("pod",)) or \
        spec(("batch",), MESH2, (16,)) == P("pod")


def test_make_shardings_tree():
    mesh = make_host_mesh()
    axes = {"w": ("embed", "ff"), "b": (None,), "s": None}
    shapes = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32),
              "b": jax.ShapeDtypeStruct((8,), jnp.float32),
              "s": jax.ShapeDtypeStruct((), jnp.int32)}
    sh = make_shardings(axes, mesh, shapes_tree=shapes)
    assert set(sh) == {"w", "b", "s"}


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------


def test_scan_flops_trip_corrected():
    L, M, K, N = 8, 64, 128, 128

    def scanned(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), ()
        h, _ = jax.lax.scan(body, x, w)
        return h

    w = jax.ShapeDtypeStruct((L, K, N), jnp.float32)
    x = jax.ShapeDtypeStruct((M, K), jnp.float32)
    comp = jax.jit(scanned).lower(w, x).compile()
    cost = analyze_hlo(comp.as_text())
    expect = L * 2 * M * K * N
    assert abs(cost.flops / expect - 1.0) < 0.05
    assert list(cost.while_trips.values()) == [L]
    # XLA's own cost_analysis counts the body once — ours corrects it
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # older jax returns one dict per device
        ca = ca[0]
    xla_flops = ca["flops"]
    assert cost.flops / xla_flops == pytest.approx(L, rel=0.05)


def test_unrolled_equals_scanned_flops():
    def unrolled(w, x):
        for i in range(4):
            x = x @ w[i]
        return x

    def scanned(w, x):
        return jax.lax.scan(lambda c, wl: (c @ wl, ()), x, w)[0]

    w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    cu = analyze_hlo(jax.jit(unrolled).lower(w, x).compile().as_text())
    cs = analyze_hlo(jax.jit(scanned).lower(w, x).compile().as_text())
    assert cu.flops == pytest.approx(cs.flops, rel=0.02)


def test_collective_bytes_parsed():
    mesh = make_host_mesh()
    n = mesh.shape["data"]
    if n < 2:
        pytest.skip("needs >1 device to emit collectives")


def test_parse_computations_finds_entry():
    def f(x):
        return jnp.sum(x * 2)

    txt = jax.jit(f).lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile().as_text()
    comps = parse_computations(txt)
    assert any(c.is_entry for c in comps.values())


def test_dus_counts_slice_not_buffer():
    """dynamic-update-slice into a big buffer must charge the slice."""
    def f(buf, upd):
        return jax.lax.dynamic_update_slice(buf, upd, (0, 0))

    buf = jax.ShapeDtypeStruct((4096, 4096), jnp.float32)  # 64MB
    upd = jax.ShapeDtypeStruct((4, 4096), jnp.float32)     # 64KB
    cost = analyze_hlo(jax.jit(f, donate_argnums=(0,)).lower(buf, upd).compile().as_text())
    assert cost.hbm_bytes < 10e6, cost.hbm_bytes  # not 128MB


def test_roofline_terms_math():
    from repro.analysis.hlo import HloCost
    from repro.analysis.roofline import HW, roofline_terms

    cost = HloCost(flops=197e12, hbm_bytes=819e9,
                   hbm_bytes_kernelized=819e9,
                   collective_bytes={"all-reduce": 25e9})
    t = roofline_terms(cost, HW(), model_flops_per_chip=98.5e12)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(1.0)
    assert t.collective_s == pytest.approx(1.0)  # 2x ring factor
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.dominant in ("compute", "memory", "collective")
