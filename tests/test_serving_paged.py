"""Paged KV cache + paged attention + continuous batching engine.

Covers the satellite checklist: page alloc/free/reuse, block-table
correctness vs. the dense cache, paged-attention-vs-reference numerical
parity (including the Pallas kernel in interpret mode), and end-to-end
engine equivalence with the lockstep baseline.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.kernels import ops, ref
from repro.models import build_model
from repro.serving import (
    ContinuousBatchingEngine,
    GenerationEngine,
    PagedKVCache,
    PagePool,
    Request,
)
from repro.serving.kv_cache import NULL_PAGE, cdiv, write_prefill_pages


def assert_drained(cache):
    """Every page is either free or parked (zero-refcount prefix pages kept
    for reuse by the tier manager) once all sequences have released."""
    assert cache.pool.available + cache.parked_count == cache.num_pages - 1
    assert (cache.pool.refcounts[1:] == 0).all()


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------


def test_page_pool_alloc_free_reuse():
    pool = PagePool(8)  # pages 1..7 usable, 0 reserved
    assert pool.available == 7
    a = pool.alloc(3)
    assert len(set(a)) == 3 and NULL_PAGE not in a
    b = pool.alloc(4)
    assert pool.available == 0
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc(1)
    pool.free(a)
    assert pool.available == 3
    c = pool.alloc(3)
    assert set(c) == set(a)  # freed pages are reused
    assert not set(c) & set(b)


def test_paged_cache_block_table_bookkeeping():
    cache = PagedKVCache(
        num_layers=1, num_kv_heads=1, head_dim=4, dtype=jnp.float32,
        max_slots=2, max_context=32, page_size=8,
    )
    slot, cached = cache.admit(context_len=10)  # needs 2 pages
    assert cached == 0  # no prompt tokens given -> nothing shared
    pages = cache._slot_pages[slot]
    assert len(pages) == 2
    assert list(cache.block_tables[slot, :2]) == pages
    assert (cache.block_tables[slot, 2:] == NULL_PAGE).all()

    # appending through position 15 stays inside page 2; 16 allocates page 3
    for _ in range(6):
        cache.ensure_append_capacity(slot)
        cache.append(slot)
    assert len(cache._slot_pages[slot]) == 2
    cache.ensure_append_capacity(slot)
    assert len(cache._slot_pages[slot]) == 3

    avail = cache.pool.available
    cache.release(slot)
    assert cache.pool.available == avail + 3
    assert (cache.block_tables[slot] == NULL_PAGE).all()
    assert cache.lengths[slot] == 0


# ---------------------------------------------------------------------------
# paged attention numerics
# ---------------------------------------------------------------------------


def _random_paged_case(rng, b=3, h=4, kvh=2, d=16, page=8, mp=4):
    num_pages = b * mp + 1
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    lens = np.array([0] + list(rng.integers(1, mp * page + 1, b - 1)), np.int32)
    bt = np.full((b, mp), NULL_PAGE, np.int32)
    nxt = 1
    for i in range(b):
        for p in range(cdiv(int(lens[i]), page)):
            bt[i, p] = nxt
            nxt += 1
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(lens)


def test_paged_attention_ref_matches_dense(rng):
    q, kp, vp, bt, lens = _random_paged_case(rng)
    out = ref.paged_attention_ref(q, kp, vp, bt, lens)
    assert (np.asarray(out[0]) == 0).all()  # idle slot -> zeros, not NaN
    page = kp.shape[1]
    for b in range(q.shape[0]):
        n = int(lens[b])
        if n == 0:
            continue
        kd = np.stack([np.asarray(kp)[bt[b, j // page], j % page] for j in range(n)])
        vd = np.stack([np.asarray(vp)[bt[b, j // page], j % page] for j in range(n)])
        dense = ref.flash_attention_ref(
            q[b][None, None], jnp.asarray(kd)[None], jnp.asarray(vd)[None],
            causal=False,
        )[0, 0]
        np.testing.assert_allclose(
            np.asarray(out[b]), np.asarray(dense), atol=1e-5, rtol=1e-5
        )


def test_paged_attention_pallas_matches_ref(rng):
    """Acceptance: kernel vs reference <= 1e-3 max abs error (interpret)."""
    for seed in range(3):
        r = np.random.default_rng(seed)
        q, kp, vp, bt, lens = _random_paged_case(r)
        o_ref = ops.paged_attention(q, kp, vp, bt, lens, impl="xla_chunked")
        o_pal = ops.paged_attention(
            q, kp, vp, bt, lens, impl="pallas", interpret=True
        )
        err = float(jnp.abs(o_ref - o_pal).max())
        assert err <= 1e-3, err


def test_paged_attention_gqa_and_mqa(rng):
    for kvh in (1, 4):
        q, kp, vp, bt, lens = _random_paged_case(rng, h=4, kvh=kvh)
        o_ref = ops.paged_attention(q, kp, vp, bt, lens, impl="xla_chunked")
        o_pal = ops.paged_attention(
            q, kp, vp, bt, lens, impl="pallas", interpret=True
        )
        assert float(jnp.abs(o_ref - o_pal).max()) <= 1e-3


def test_paged_prefill_pallas_matches_ref(rng):
    """Acceptance: chunk-prefill kernel vs oracle <= 1e-3 (interpret mode).
    The exhaustive shape sweep lives in ``test_kernel_fuzz.py``; this pins
    the canonical serving shape (chunk straddling a page, partial history)."""
    c, h, kvh, d, page = 8, 4, 2, 16, 8
    start, valid = 5, 8
    num_pages = 4
    q = jnp.asarray(rng.standard_normal((c, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((num_pages, page, kvh, d)), jnp.float32)
    bt = jnp.asarray([2, 1, 3], jnp.int32)
    args = (q, kp, vp, bt, jnp.int32(start), jnp.int32(valid))
    o_ref = ops.paged_prefill_attention(*args, impl="xla_chunked")
    o_pal = ops.paged_prefill_attention(*args, impl="pallas_interpret")
    assert float(jnp.abs(o_ref - o_pal).max()) <= 1e-3


# ---------------------------------------------------------------------------
# paged model path vs dense cache
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, model, params


def test_prefill_pages_match_dense_cache(smollm):
    """Block-table scatter reproduces the dense prefill KV exactly."""
    cfg, model, params = smollm
    plen, bucket = 11, 16
    toks = np.zeros((1, bucket), np.int32)
    toks[0, :plen] = np.arange(1, plen + 1)
    cache, _ = jax.jit(lambda p, b: model.prefill(p, b, bucket))(
        params, {"tokens": jnp.asarray(toks)}
    )

    paged = PagedKVCache(
        num_layers=cfg.num_layers, num_kv_heads=cfg.eff_kv_heads,
        head_dim=cfg.head_dim, dtype=jnp.dtype(cfg.dtype),
        max_slots=2, max_context=32, page_size=4,
    )
    slot, _ = paged.admit(context_len=plen)
    paged.swap_pages(write_prefill_pages(
        dict(paged.pages), cache["k"][:, 0], cache["v"][:, 0],
        paged.device_row(slot), jnp.asarray(plen, jnp.int32),
    ))
    got_k, got_v = paged.gather_dense(slot)
    np.testing.assert_array_equal(got_k, np.asarray(cache["k"][:, 0, :plen]))
    np.testing.assert_array_equal(got_v, np.asarray(cache["v"][:, 0, :plen]))


def test_decode_step_paged_matches_dense(smollm):
    """Paged decode logits == dense decode logits for the same sequence."""
    cfg, model, params = smollm
    plen, steps, max_len = 7, 5, 32
    prompt = np.arange(1, plen + 1, dtype=np.int32)

    # dense path; record the token fed at each step so the paged path sees
    # the IDENTICAL stream (an argmax near-tie must not fork the comparison)
    batch = {"tokens": jnp.asarray(prompt[None])}
    dcache, dlogits = jax.jit(lambda p, b: model.prefill(p, b, max_len))(
        params, batch
    )
    dense_logits = [np.asarray(dlogits[0])]
    fed_tokens = []
    tok = jnp.argmax(dlogits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    for _ in range(steps):
        fed_tokens.append(int(tok[0]))
        dcache, dlogits = model.decode_step(params, dcache, tok[:, None])
        dense_logits.append(np.asarray(dlogits[0]))
        tok = jnp.argmax(dlogits[:, : cfg.vocab_size], -1).astype(jnp.int32)

    # paged path (slot 1 of 3, other slots idle)
    paged = PagedKVCache(
        num_layers=cfg.num_layers, num_kv_heads=cfg.eff_kv_heads,
        head_dim=cfg.head_dim, dtype=jnp.dtype(cfg.dtype),
        max_slots=3, max_context=max_len, page_size=4,
    )
    slot, _ = paged.admit(context_len=plen)
    pcache, plogits = jax.jit(
        lambda p, b, i: model.prefill(p, b, plen, logits_index=i)
    )(params, batch, jnp.asarray(plen - 1, jnp.int32))
    paged.swap_pages(write_prefill_pages(
        dict(paged.pages), pcache["k"][:, 0], pcache["v"][:, 0],
        paged.device_row(slot), jnp.asarray(plen, jnp.int32),
    ))
    np.testing.assert_allclose(
        np.asarray(plogits[0]), dense_logits[0], atol=1e-4, rtol=1e-4
    )

    pages = {"k": paged.k_pages, "v": paged.v_pages}
    for i in range(steps):
        paged.ensure_append_capacity(slot)
        tokens = np.zeros((3, 1), np.int32)
        tokens[slot, 0] = fed_tokens[i]
        bt, lens = paged.device_tables()
        pages, logits = model.decode_step_paged(
            params, pages, bt, lens, jnp.asarray(tokens)
        )
        paged.append(slot)
        np.testing.assert_allclose(
            np.asarray(logits[slot]), dense_logits[i + 1], atol=1e-4, rtol=1e-4
        )
        assert np.isfinite(np.asarray(logits)).all()  # idle slots too


# ---------------------------------------------------------------------------
# continuous batching engine
# ---------------------------------------------------------------------------


def test_continuous_engine_matches_lockstep(smollm):
    """Greedy decode through the continuous batcher must equal the lockstep
    engine run one request at a time (the exact, no-padding baseline)."""
    cfg, model, params = smollm
    rng = np.random.default_rng(2)
    reqs = [
        Request(
            f"r{i}",
            list(rng.integers(1, cfg.vocab_size, rng.integers(3, 30))),
            max_new_tokens=int(rng.integers(1, 10)),
        )
        for i in range(7)
    ]
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=3,
                                   page_size=8)
    out = eng.generate(reqs)
    base = GenerationEngine(cfg, params, max_len=64)
    for r, o in zip(reqs, out):
        exact = base.generate([Request(r.uid, r.prompt, r.max_new_tokens)])[0]
        assert o.uid == r.uid
        assert o.tokens == exact.tokens, r.uid
        assert len(o.tokens) == r.max_new_tokens
    # all pages returned to the pool (or parked for prefix reuse)
    assert_drained(eng.cache)
    assert eng.cache.free_slot_count == eng.max_slots


def test_continuous_engine_per_request_temperature(smollm):
    cfg, model, params = smollm
    reqs = [
        Request("greedy", [1, 2, 3], max_new_tokens=6, temperature=0.0),
        Request("hot", [1, 2, 3], max_new_tokens=6, temperature=1.0),
    ]
    eng = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=2,
                                   page_size=8, seed=7)
    out = {r.uid: r for r in eng.generate(reqs)}
    base = GenerationEngine(cfg, params, max_len=32)
    exact = base.generate([Request("greedy", [1, 2, 3], 6)])[0]
    # greedy row unaffected by the hot row's sampling
    assert out["greedy"].tokens == exact.tokens
    assert len(out["hot"].tokens) == 6


def test_lockstep_per_request_temperature(smollm):
    """Satellite fix: greedy rows stay greedy when batched with hot rows."""
    cfg, model, params = smollm
    base = GenerationEngine(cfg, params, max_len=32)
    exact = base.generate([Request("g", [1, 2, 3], 6)])[0]
    mixed = base.generate([
        Request("g", [1, 2, 3], 6, temperature=0.0),
        Request("h", [1, 2, 3], 6, temperature=1.0),
    ])
    assert mixed[0].tokens == exact.tokens


def test_engine_preempts_under_pool_pressure(smollm):
    """A too-small page pool forces preemption, never a crash or a hang,
    and preempted (regenerated) greedy outputs stay exact."""
    cfg, model, params = smollm
    eng = ContinuousBatchingEngine(cfg, params, max_len=40, max_slots=2,
                                   page_size=8, num_pages=6)
    # distinct prompts: prefix sharing must not relieve the pool pressure
    reqs = [Request(f"p{i}", [100 + i] + list(range(2, 15)), max_new_tokens=10)
            for i in range(3)]
    out = eng.generate(reqs)
    assert eng.stats["preemptions"] > 0
    base = GenerationEngine(cfg, params, max_len=40)
    for r, o in zip(reqs, out):
        exact = base.generate([Request(r.uid, r.prompt, r.max_new_tokens)])[0]
        assert o.tokens == exact.tokens
    assert_drained(eng.cache)


def test_engine_rejects_unschedulable_request(smollm):
    cfg, model, params = smollm
    eng = ContinuousBatchingEngine(cfg, params, max_len=40, max_slots=2,
                                   page_size=8, num_pages=4)
    with pytest.raises(ValueError, match="never be scheduled"):
        eng.enqueue(Request("never", list(range(1, 31)), max_new_tokens=10))
    with pytest.raises(ValueError, match="max_len"):
        eng.enqueue(Request("long", list(range(1, 40)), max_new_tokens=10))


def test_bus_poison_message_is_rejected_and_committed(smollm, tmp_path):
    """An unservable bus message must be committed (not redelivered forever)
    and recorded as a rejection, while later messages still serve."""
    from repro.core import TopicBus

    cfg, model, params = smollm
    bus = TopicBus(tmp_path)
    bus.publish("requests", {"uid": "bad", "prompt": list(range(40)),
                             "max_new_tokens": 16})
    bus.publish("requests", {"uid": "good", "prompt": [1, 2, 3],
                             "max_new_tokens": 3})
    eng = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=2,
                                   page_size=8)
    n = eng.admit_from_bus(bus, "requests", "g0")
    assert n == 1
    assert bus.lag("requests", "g0") == 0  # poison message consumed
    assert eng.stats["rejected"] == 1
    (uid, err), = eng.drain_rejections()
    assert uid == "bad" and "max_len" in err
    served = []
    while not eng.idle:
        served.extend(ev.uid for ev in eng.step() if ev.kind == "finish")
    assert served == ["good"]


# ---------------------------------------------------------------------------
# prefix sharing / copy-on-write
# ---------------------------------------------------------------------------


def _small_cache(**kw):
    args = dict(num_layers=1, num_kv_heads=1, head_dim=4, dtype=jnp.float32,
                max_slots=3, max_context=32, page_size=8)
    args.update(kw)
    return PagedKVCache(**args)


def test_match_prefix_capped_below_last_token():
    """A prompt equal to its cached prefix must still recompute >= 1 token
    (the engine needs its logits), so matching stops strictly before the
    last token even on a page boundary."""
    cache = _small_cache()
    toks = list(range(100, 116))  # exactly 2 full pages
    slot, cached = cache.admit(len(toks), toks)
    assert cached == 0
    cache.register_prefix(slot, toks, len(toks))
    # identical prompt: only page 0 is eligible (page 1 holds the last token)
    _, cached2 = cache.match_prefix(toks)
    assert cached2 == 8
    # a longer prompt extending the prefix can use both full pages
    _, cached3 = cache.match_prefix(toks + [1, 2, 3])
    assert cached3 == 16


def test_shared_prefix_pages_not_double_freed():
    """Two slots sharing prefix pages release independently; the shared
    page survives the first release and every refcount returns to zero."""
    cache = _small_cache()
    toks = list(range(1, 21))  # 20 tokens: 2 full pages + 1 partial
    a, cached_a = cache.admit(len(toks), toks)
    assert cached_a == 0 and len(cache._slot_pages[a]) == 3
    cache.register_prefix(a, toks, len(toks))

    b, cached_b = cache.admit(len(toks), toks)
    assert cached_b == 16  # both full pages shared
    shared = cache._slot_pages[b][:2]
    assert shared == cache._slot_pages[a][:2]
    assert all(cache.pool.refcounts[p] == 2 for p in shared)

    avail = cache.pool.available
    cache.release(a)
    # a's private tail page freed; the two shared pages survive for b
    assert cache.pool.available == avail + 1
    assert all(cache.pool.refcounts[p] == 1 for p in shared)
    # b can still resolve its prefix through the index
    assert cache.match_prefix(toks + [99])[1] == 16
    cache.release(b)
    assert cache.pool.available == cache.num_pages - 1
    assert (cache.pool.refcounts[1:] == 0).all()
    assert not cache._prefix_index  # freed pages leave the index


def test_fork_cow_copies_exactly_one_page():
    """A write after fork copies exactly the written page; the other pages
    stay shared and the source slot's data is untouched."""
    cache = _small_cache()
    toks = list(range(1, 13))  # 12 tokens: 1 full page + 1 partial
    a, _ = cache.admit(len(toks), toks)
    # fill the pool pages with recognizable data
    k = cache.k_pages
    for i, p in enumerate(cache._slot_pages[a]):
        k = k.at[:, p].set(float(i + 1))
    cache.set_pages(k, cache.v_pages)

    b = cache.fork(a)
    assert cache._slot_pages[b] == cache._slot_pages[a]
    assert int(cache.lengths[b]) == 12
    assert all(cache.pool.refcounts[p] == 2 for p in cache._slot_pages[a])

    avail = cache.pool.available
    changed = cache.ensure_append_capacity(b)  # next write: pos 12, page 1
    assert changed and cache.stats["cow_copies"] == 1
    assert cache.pool.available == avail - 1  # exactly one page allocated
    pa, pb = cache._slot_pages[a], cache._slot_pages[b]
    assert pb[0] == pa[0] and pb[1] != pa[1]  # full page shared, tail copied
    assert cache.pool.refcounts[pa[0]] == 2
    assert cache.pool.refcounts[pa[1]] == 1 and cache.pool.refcounts[pb[1]] == 1
    # the copy carried the tail page's contents
    np.testing.assert_array_equal(
        np.asarray(cache.k_pages[:, pb[1]]), np.asarray(cache.k_pages[:, pa[1]])
    )
    # a's next append sees refcount 1 everywhere: no second copy
    assert not cache.ensure_append_capacity(a)
    assert cache.stats["cow_copies"] == 1

    cache.release(a)
    cache.release(b)
    assert cache.pool.available == cache.num_pages - 1
    assert (cache.pool.refcounts[1:] == 0).all()


def test_cow_append_fails_cleanly_when_pool_exhausted():
    """Pool exhaustion DURING a copy-on-write append: every free page is
    held by refcounted (unfreeable) sharers, so the COW copy has nowhere to
    land. ensure_append_capacity must raise (so the engine can preempt)
    WITHOUT corrupting state: no page leaked, the shared mapping and block
    table untouched, refcounts intact — and the append must succeed after
    pressure drops."""
    cache = _small_cache(num_pages=4)  # pages 1..3 usable
    toks = list(range(1, 13))  # 12 tokens: 1 full page + 1 partial
    a, _ = cache.admit(len(toks), toks)   # takes pages 1, 2
    b = cache.fork(a)                     # maps both COW (refcounts 2)
    (filler,) = cache.pool.alloc(1)       # page 3: pool now empty
    assert cache.pool.available == 0

    before_pages = list(cache._slot_pages[b])
    before_bt = cache.block_tables[b].copy()
    before_rc = cache.pool.refcounts.copy()
    # b's next write lands at position 12 inside shared page 2 -> COW needs
    # a fresh page, but every page is refcounted and unfreeable
    with pytest.raises(RuntimeError, match="exhausted"):
        cache.ensure_append_capacity(b)
    assert cache.stats["cow_copies"] == 0
    assert cache._slot_pages[b] == before_pages      # mapping unchanged
    np.testing.assert_array_equal(cache.block_tables[b], before_bt)
    np.testing.assert_array_equal(cache.pool.refcounts, before_rc)
    assert cache.pool.available == 0                 # nothing leaked

    # releasing unrelated pressure makes the SAME append succeed as a copy
    cache.pool.free([filler])
    assert cache.ensure_append_capacity(b) is True
    assert cache.stats["cow_copies"] == 1
    assert cache._slot_pages[b][1] != cache._slot_pages[a][1]
    cache.release(a)
    cache.release(b)
    assert cache.pool.available == cache.num_pages - 1


def test_cow_exhaustion_growth_page_also_raises():
    """The page-boundary growth branch hits the same exhaustion path: a
    slot at a page boundary with an empty pool raises instead of stealing a
    refcounted page, and the pool stays balanced."""
    cache = _small_cache(num_pages=3, page_size=8)  # pages 1..2 usable
    toks = list(range(1, 9))  # exactly one full page
    a, _ = cache.admit(len(toks), toks)
    b = cache.fork(a)          # page shared at refcount 2
    (filler,) = cache.pool.alloc(1)
    with pytest.raises(RuntimeError, match="exhausted"):
        cache.ensure_append_capacity(a)  # boundary: needs a NEW page
    assert cache.pool.refcounts[cache._slot_pages[a][0]] == 2
    cache.pool.free([filler])
    assert cache.ensure_append_capacity(a) is True   # growth succeeds now
    cache.release(a)
    cache.release(b)
    assert cache.pool.available == cache.num_pages - 1
    assert (cache.pool.refcounts[1:] == 0).all()


def test_engine_preempts_when_cow_append_cannot_allocate(smollm):
    """Engine-level: shared prefix pages make the pool LOOK full of
    unfreeable pages; when a decode append needs a page the scheduler must
    preempt the youngest sequence (whose release drops the shared
    refcounts) instead of crashing, and every request still finishes
    exactly."""
    cfg, model, params = smollm
    # 7 usable pages; two 17-token same-prefix prompts share 2 full pages:
    # 2 shared + 2 private tails + growth quickly exceeds the pool
    eng = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=3,
                                   page_size=8, num_pages=8,
                                   prefill_chunk=8)
    prefix = list(range(40, 56))  # 2 full pages
    reqs = [Request(f"c{i}", prefix + [60 + i], max_new_tokens=14)
            for i in range(3)]
    out = eng.generate(reqs)
    assert eng.cache.stats["prefix_hits"] >= 1  # sharing actually happened
    assert eng.stats["preemptions"] > 0         # pressure forced eviction
    base = GenerationEngine(cfg, params, max_len=48)
    for r, o in zip(reqs, out):
        exact = base.generate([Request(r.uid, r.prompt, r.max_new_tokens)])[0]
        assert o.tokens == exact.tokens, r.uid
    assert_drained(eng.cache)


def test_prefill_chunk_matches_whole_prefill(smollm):
    """Chunked prefill (2 chunks) reproduces the whole-prompt prefill's
    KV pages and final-position logits."""
    cfg, model, params = smollm
    plen, chunk = 11, 8
    prompt = np.arange(1, plen + 1, dtype=np.int32)
    batch = {"tokens": jnp.asarray(prompt[None])}
    dcache, dlogits = jax.jit(
        lambda p, b, i: model.prefill(p, b, plen, logits_index=i)
    )(params, batch, jnp.asarray(plen - 1, jnp.int32))

    paged = PagedKVCache(
        num_layers=cfg.num_layers, num_kv_heads=cfg.eff_kv_heads,
        head_dim=cfg.head_dim, dtype=jnp.dtype(cfg.dtype),
        max_slots=2, max_context=32, page_size=4,
    )
    slot, _ = paged.admit(context_len=plen)
    row = paged.device_row(slot)
    pages = {"k": paged.k_pages, "v": paged.v_pages}
    logits = None
    for start in range(0, plen, chunk):
        valid = min(chunk, plen - start)
        toks = np.zeros((chunk,), np.int32)
        toks[:valid] = prompt[start:start + valid]
        pages, logits = model.prefill_chunk(
            params, pages, row, jnp.asarray(toks),
            jnp.asarray(start, jnp.int32), jnp.asarray(valid, jnp.int32),
        )
    paged.set_pages(pages["k"], pages["v"])
    got_k, got_v = paged.gather_dense(slot)
    np.testing.assert_allclose(
        got_k, np.asarray(dcache["k"][:, 0, :plen]), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        got_v, np.asarray(dcache["v"][:, 0, :plen]), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(dlogits[0]), atol=1e-4, rtol=1e-4
    )


def test_engine_chunked_long_prompt_matches_lockstep(smollm):
    """A multi-chunk prompt through the chunked engine stays exact."""
    cfg, model, params = smollm
    rng = np.random.default_rng(5)
    reqs = [
        Request("long", list(rng.integers(1, cfg.vocab_size, 50)), 8),
        Request("short", list(rng.integers(1, cfg.vocab_size, 5)), 8),
    ]
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   page_size=8, prefill_chunk=16)
    out = eng.generate(reqs)
    assert eng.stats["prefill_chunks"] >= 4  # 50-token prompt = 4 chunks
    base = GenerationEngine(cfg, params, max_len=64)
    for r, o in zip(reqs, out):
        exact = base.generate([Request(r.uid, r.prompt, r.max_new_tokens)])[0]
        assert o.tokens == exact.tokens, r.uid
    assert_drained(eng.cache)


def test_engine_prefix_sharing_reuses_pages_and_stays_exact(smollm):
    """Identical prompts in flight share prefix pages (trie hits recorded)
    and greedy outputs match the no-sharing engine."""
    cfg, model, params = smollm
    rng = np.random.default_rng(9)
    prefix = list(rng.integers(1, cfg.vocab_size, 24))
    reqs = [Request(f"s{i}", prefix + [10 + i], max_new_tokens=6)
            for i in range(4)]
    shared = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=4,
                                      page_size=8, prefill_chunk=16)
    out_shared = shared.generate(reqs)
    assert shared.cache.stats["prefix_hits"] >= 1
    assert shared.cache.stats["prefix_tokens_reused"] >= 16

    plain = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=4,
                                     page_size=8, prefill_chunk=16,
                                     prefix_sharing=False)
    out_plain = plain.generate(
        [Request(r.uid, r.prompt, r.max_new_tokens) for r in reqs]
    )
    assert plain.cache.stats["prefix_hits"] == 0
    for a, b in zip(out_shared, out_plain):
        assert a.tokens == b.tokens, a.uid
    assert_drained(shared.cache)


def test_chunked_prefill_interleaves_with_decode(smollm):
    """While a long prompt prefills chunk-by-chunk, an in-flight decode
    keeps emitting: it must finish BEFORE the long prompt's first token."""
    cfg, model, params = smollm
    eng = ContinuousBatchingEngine(cfg, params, max_len=128, max_slots=2,
                                   page_size=8, prefill_chunk=8)
    short = eng.submit(Request("short", [1, 2, 3], max_new_tokens=6))
    eng.step()  # short: single-chunk prefill + first token
    long_prompt = list(range(1, 81))  # 10 chunks of 8
    long = eng.submit(Request("long", long_prompt, max_new_tokens=2))
    order = []
    while not eng.idle:
        order.extend(ev.uid for ev in eng.step() if ev.kind == "finish")
    assert order == ["short", "long"]
    assert len(short.tokens) == 6
    assert len(long.tokens) == 2
    # decode steps ran while the long prompt was still chunking
    assert eng.stats["prefill_chunks"] >= 10
    assert eng.stats["decode_steps"] >= 5


def test_engine_records_latency_metrics(smollm):
    cfg, model, params = smollm
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   page_size=8)
    (res,) = eng.generate([Request("t", [1, 2, 3, 4], max_new_tokens=5)])
    assert res.ttft is not None and res.ttft > 0
    assert len(res.itl) == 4  # gaps between the 5 emitted tokens
    assert all(g > 0 for g in res.itl)


def test_engine_admits_from_bus(smollm, tmp_path):
    from repro.core import TopicBus

    cfg, model, params = smollm
    bus = TopicBus(tmp_path)
    for i in range(5):
        bus.publish("requests", {
            "uid": f"b{i}", "prompt": [1 + i, 2, 3], "max_new_tokens": 4,
        })
    eng = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=2,
                                   page_size=8)
    served: dict[str, list[int]] = {}
    while bus.lag("requests", "g0") > 0 or not eng.idle:
        eng.admit_from_bus(bus, "requests", "g0",
                           max_msgs=eng.cache.free_slot_count)
        for ev in eng.step():
            if ev.kind == "token":  # streamed deltas rebuild the outputs
                served.setdefault(ev.uid, []).append(ev.token)
    assert sorted(served) == [f"b{i}" for i in range(5)]
    assert all(len(t) == 4 for t in served.values())


def test_kernel_path_engine_streams_match_ref_path(smollm):
    """The REAL Pallas kernels (interpret mode on CPU), run end-to-end inside
    the engine — chunked-prefill kernel per chunk, decode kernel per step —
    must produce byte-identical token streams to the XLA reference path.
    Under the forced 4-device CI job the same test exercises the kernels
    per shard inside the executor's ``shard_map``."""
    cfg, model, params = smollm
    rng = np.random.default_rng(11)
    prompts = [list(rng.integers(1, 200, n)) for n in (11, 19, 6)]

    def run(attn_impl):
        eng = ContinuousBatchingEngine(
            cfg, params, max_len=48, max_slots=2, page_size=8,
            prefill_chunk=8, attn_impl=attn_impl,
        )
        handles = [eng.submit(Request(f"k{i}", list(p), max_new_tokens=4))
                   for i, p in enumerate(prompts)]
        while not eng.idle:
            eng.step()
        return [h.result().tokens for h in handles]

    kernel, ref_path = run("pallas_interpret"), run("xla_chunked")
    assert kernel == ref_path, (kernel, ref_path)
    assert all(len(t) == 4 for t in kernel)


def test_fused_step_streams_match_interleaved(smollm):
    """The fused step (one mixed dispatch per engine step) must produce
    byte-identical token streams to the interleaved two-dispatch step, on a
    trace that keeps prefill chunks and decodes overlapping (staggered
    arrivals, mixed greedy/sampled rows) — and the fused engine must have
    actually fused (mixed dispatches recorded). Under the forced 4-device
    CI job the same test exercises the mixed kernel per shard inside the
    executor's ``shard_map``."""
    cfg, model, params = smollm
    rng = np.random.default_rng(13)
    reqs = [
        Request(f"f{i}", list(rng.integers(1, 200, int(rng.integers(6, 30)))),
                max_new_tokens=int(rng.integers(4, 12)),
                temperature=0.0 if i % 2 else 0.9)
        for i in range(6)
    ]

    def run(mode, token_budget=None):
        eng = ContinuousBatchingEngine(
            cfg, params, max_len=64, max_slots=3, page_size=8,
            prefill_chunk=8, step_mode=mode, token_budget=token_budget,
            seed=3,
        )
        pending = [Request(r.uid, list(r.prompt), r.max_new_tokens,
                           temperature=r.temperature) for r in reqs]
        handles = []
        # staggered arrivals: a new request every 2 steps keeps chunks
        # landing while other slots decode — the fused regime
        while pending or not eng.idle:
            if pending:
                handles.append(eng.submit(pending.pop(0)))
            eng.step()
            if pending:
                handles.append(eng.submit(pending.pop(0)))
            eng.step()
        return [h.result().tokens for h in handles], eng

    fused, ef = run("fused")
    inter, ei = run("interleaved")
    assert fused == inter, (fused, inter)
    assert all(t for t in fused)
    assert ef.utilization.fused_dispatches > 0   # the mixed path really ran
    assert ei.utilization.fused_dispatches == 0
    # identical model work either way, in fewer dispatches
    assert ef.utilization.dispatches < ei.utilization.dispatches \
        + ef.stats["prefill_chunks"]

    # a token budget reshapes the schedule (chunks get deferred/trimmed)
    # but never the streams
    budget, _ = run("fused", token_budget=6)
    assert budget == fused
