"""Unit tests for the tiered KV cache (``serving/kv_tiers.py`` +
``PagedKVCache`` tier plumbing) — no model, no engine.

Covers the page state machine (live -> parked -> host -> persisted, with
revive and prefetch back), the reclaim cascade over prefix-index
descendants, content-key stability across spill/reload and process
restarts, byte-exactness of a spilled/reloaded page, and the quantized
pool's admission-capacity win at equal device bytes.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.storage import ArtifactStore
from repro.serving import KVTierManager, PagedKVCache
from repro.serving.kv_tiers import chain_key


def _cache(tiers=None, **kw):
    args = dict(num_layers=2, num_kv_heads=2, head_dim=4, dtype=jnp.float32,
                max_slots=3, max_context=64, page_size=8, tiers=tiers)
    args.update(kw)
    return PagedKVCache(**args)


def _fill(cache, pages, seed=0):
    """Write recognizable per-page values into every pool array."""
    rng = np.random.default_rng(seed)
    for key, arr in cache.pages.items():
        host = np.array(arr)
        for p in pages:
            host[:, p] = rng.normal(size=host[:, p].shape).astype(host.dtype)
        cache.pages[key] = jnp.asarray(host)


# ---------------------------------------------------------------------------
# chain keys
# ---------------------------------------------------------------------------


def test_chain_key_names_whole_prefix():
    a = chain_key(b"", range(8))
    b = chain_key(b"", range(8))
    assert a == b and len(a) == 32
    assert chain_key(a, range(8, 16)) != chain_key(b"", range(8, 16))
    assert chain_key(b"", [1, 2]) != chain_key(b"", [2, 1])


# ---------------------------------------------------------------------------
# park / revive / reclaim
# ---------------------------------------------------------------------------


def test_release_parks_prefix_pages_and_rerun_revives():
    tiers = KVTierManager()
    cache = _cache(tiers)
    toks = list(range(100, 125))  # 25 tokens: 3 full pages + tail
    slot, _ = cache.admit(len(toks), toks)
    cache.register_prefix(slot, toks, len(toks))
    pages = list(cache._slot_pages[slot])
    avail = cache.pool.available

    cache.release(slot)
    # 3 indexed pages parked (refcount 0, off the free list), tail freed
    assert set(tiers.parked) == set(pages[:3])
    assert cache.pool.available == avail + 1
    assert all(cache.pool.refcounts[p] == 0 for p in pages[:3])

    # rerun of the same prompt revives the parked pages in place
    slot2, cached = cache.admit(len(toks), toks)
    assert cached == 24
    assert cache._slot_pages[slot2][:3] == pages[:3]
    assert not tiers.parked
    assert tiers.counters["device_hits"] == 3
    assert all(cache.pool.refcounts[p] == 1 for p in pages[:3])


def test_reclaim_under_pressure_cascades_descendants():
    """Allocation pressure reclaims parked pages LRU-first, and reclaiming
    a chain parent always takes its index descendants with it — a surviving
    child entry would dangle behind a recycled parent page id."""
    tiers = KVTierManager()
    # 7 usable pages
    cache = _cache(tiers, num_pages=8, max_slots=2)
    toks = list(range(200, 225))  # 3 full pages + tail
    slot, _ = cache.admit(len(toks), toks)
    cache.register_prefix(slot, toks, len(toks))
    chain = list(cache._slot_pages[slot][:3])
    cache.release(slot)
    assert len(tiers.parked) == 3 and cache.pool.available == 4

    # a 6-page admission cannot be served from the free list alone: the
    # LRU parked page is the chain ROOT, so the whole chain is reclaimed
    slot2, cached = cache.admit(41, list(range(300, 341)))
    assert cached == 0
    assert not tiers.parked
    assert tiers.counters["reclaimed_pages"] == 3
    assert not cache._prefix_index  # no dangling child entries
    assert all(p not in cache._page_ck for p in chain)
    cache.release(slot2)


def test_admission_protects_its_own_matched_prefix():
    """can_admit must never reclaim the parked pages the admission itself
    just matched (reclaim racing its own hit)."""
    tiers = KVTierManager()
    cache = _cache(tiers, num_pages=8, max_slots=2)
    toks = list(range(10, 35))  # 3 full pages + tail
    slot, _ = cache.admit(len(toks), toks)
    cache.register_prefix(slot, toks, len(toks))
    chain = list(cache._slot_pages[slot][:3])
    cache.release(slot)

    # same prompt, longer context: needs 3 matched + 3 fresh = free list
    # holds 4, so no reclaim needed; matched pages must survive and revive
    assert cache.can_admit(41, toks + list(range(500, 517)))
    slot2, cached = cache.admit(41, toks + list(range(500, 517)))
    assert cached == 24 and cache._slot_pages[slot2][:3] == chain
    cache.release(slot2)


# ---------------------------------------------------------------------------
# host spill + prefetch
# ---------------------------------------------------------------------------


def test_spill_to_host_and_prefetch_restores_bytes():
    """A parked page reclaimed into the host tier and prefetched back on a
    prefix hit restores the exact device bytes (all pool arrays)."""
    tiers = KVTierManager(host_pages=8)
    cache = _cache(tiers, num_pages=8, max_slots=2)
    toks = list(range(50, 75))
    slot, _ = cache.admit(len(toks), toks)
    cache.register_prefix(slot, toks, len(toks))
    chain = list(cache._slot_pages[slot][:3])
    _fill(cache, chain, seed=3)
    want = {p: cache._read_page(p) for p in chain}
    cache.release(slot)

    # pressure: spills the chain to host RAM, frees the device pages
    slot2, _ = cache.admit(41, list(range(300, 341)))
    assert tiers.counters["spilled_pages"] == 3
    assert tiers.host_count == 3
    cache.release(slot2)

    # rerun: can_admit prefetches the chain back (pending), a step later
    # the pages are matchable and the admission maps them
    assert not cache.can_admit(len(toks), toks)  # prefetch dispatched, wait
    assert tiers.counters["host_hits"] == 3
    assert tiers.counters["prefetched_pages"] == 3
    assert len(tiers.pending) == 3
    assert cache.match_prefix(toks)[1] == 0  # pending pages stay invisible
    cache.tick_tiers()
    assert cache.can_admit(len(toks), toks)
    slot3, cached = cache.admit(len(toks), toks)
    assert cached == 24
    for i, p in enumerate(cache._slot_pages[slot3][:3]):
        got = cache._read_page(p)
        for key in want[chain[i]]:
            np.testing.assert_array_equal(got[key], want[chain[i]][key])
    # a host hit promotes: the entries left the host LRU
    assert tiers.host_count == 0


def test_host_tier_lru_eviction_caps_entries():
    tiers = KVTierManager(host_pages=2)
    arrays = lambda i: {"k": np.full((2, 8), i, np.float32)}
    for i in range(4):
        tiers.spill(bytes([i]) * 32, arrays(i))
    assert tiers.host_count == 2
    assert set(tiers.host) == {bytes([2]) * 32, bytes([3]) * 32}  # LRU evicted
    assert tiers.counters["spilled_pages"] == 4


def test_flush_tiers_parks_nothing_spills_everything():
    tiers = KVTierManager(host_pages=8)
    cache = _cache(tiers)
    toks = list(range(80, 105))
    slot, _ = cache.admit(len(toks), toks)
    cache.register_prefix(slot, toks, len(toks))
    cache.release(slot)
    assert len(tiers.parked) == 3
    freed = cache.flush_tiers()
    assert freed == 3 and not tiers.parked
    assert tiers.host_count == 3
    assert cache.pool.available == cache.num_pages - 1


# ---------------------------------------------------------------------------
# persisted tier (ArtifactStore write-through, restart re-attach)
# ---------------------------------------------------------------------------


def test_persisted_prefix_survives_restart(tmp_path):
    """Spill with a store attached writes through to the ArtifactStore; a
    FRESH cache + tier manager over the same store directory resolves the
    prefix by content key and restores identical bytes."""
    store = ArtifactStore(tmp_path / "kv")
    tiers = KVTierManager(store=store)
    cache = _cache(tiers)
    toks = list(range(60, 85))
    slot, _ = cache.admit(len(toks), toks)
    cache.register_prefix(slot, toks, len(toks))
    chain = list(cache._slot_pages[slot][:3])
    _fill(cache, chain, seed=7)
    want = [cache._read_page(p) for p in chain]
    cache.release(slot)
    assert cache.flush_tiers() == 3
    assert tiers.persisted_count == 3

    # "restart": new process = new store handle, new manager, empty cache
    tiers2 = KVTierManager(store=ArtifactStore(tmp_path / "kv"))
    assert tiers2.persisted_count == 3  # index re-loaded from disk
    cache2 = _cache(tiers2)
    assert not cache2.can_admit(len(toks), toks)  # prefetch from the store
    assert tiers2.counters["persist_hits"] == 3
    cache2.tick_tiers()
    slot2, cached = cache2.admit(len(toks), toks)
    assert cached == 24
    for i, p in enumerate(cache2._slot_pages[slot2][:3]):
        got = cache2._read_page(p)
        for key in want[i]:
            np.testing.assert_array_equal(got[key], want[i][key])


def test_prefetch_never_starves_its_admission(tmp_path):
    """Prefetch stops while the free pool can still cover the rest of the
    prompt — reloading a long spilled prefix must not consume the pages the
    admission itself needs."""
    store = ArtifactStore(tmp_path / "kv")
    tiers = KVTierManager(store=store)
    cache = _cache(tiers, num_pages=8, max_slots=2)  # 7 usable pages
    toks = list(range(150, 190))  # 40 tokens: exactly 5 full pages
    slot, _ = cache.admit(len(toks), toks)
    cache.register_prefix(slot, toks, len(toks))
    cache.release(slot)
    assert cache.flush_tiers() == 5

    cache.can_admit(len(toks), toks)
    # 5 pages needed in total; the budget rule is the invariant, not the
    # count: after prefetch the pool must still cover the unprefetched
    # remainder of the prompt
    total = 5
    prefetched = tiers.counters["prefetched_pages"]
    assert cache.pool.available >= total - prefetched
    cache.tick_tiers()
    slot2, cached = cache.admit(len(toks), toks)
    assert cached == prefetched * cache.page_size
    cache.release(slot2)


# ---------------------------------------------------------------------------
# quantized pages: capacity at equal pool bytes
# ---------------------------------------------------------------------------


def test_int8_pages_double_admission_at_equal_pool_bytes():
    """Acceptance: at (approximately) equal device pool bytes, an int8 pool
    admits >= 2x the concurrent sequences of an fp32 pool."""
    def build(quant, budget_bytes):
        probe = PagedKVCache(
            num_layers=2, num_kv_heads=2, head_dim=8, dtype=jnp.float32,
            max_slots=64, max_context=64, page_size=8, num_pages=2,
            quant=quant,
        )
        num_pages = max(2, budget_bytes // probe.page_nbytes + 1)
        return PagedKVCache(
            num_layers=2, num_kv_heads=2, head_dim=8, dtype=jnp.float32,
            max_slots=64, max_context=64, page_size=8, num_pages=num_pages,
            quant=quant,
        )

    budget = 1 << 18  # 256 KiB of pool
    admitted = {}
    for quant in ("none", "int8"):
        cache = build(quant, budget)
        n = 0
        while cache.free_slot_count and cache.can_admit(32):
            cache.admit(32)  # 4 pages each
            n += 1
        admitted[quant] = n
    assert admitted["int8"] >= 2 * admitted["none"], admitted


def test_quantized_pool_array_shapes_and_page_bytes():
    fp = _cache()
    q = _cache(quant="int8")
    assert set(q.pages) == {"k", "v", "k_scale", "v_scale"}
    assert q.pages["k"].dtype == jnp.int8
    assert q.pages["k_scale"].shape == q.pages["k"].shape[:-1]
    # int8 + f32 scales must beat fp32 by >= 2x per page for head_dim >= 8
    assert fp.page_nbytes >= 2 * q.page_nbytes


def test_quantized_write_prefill_roundtrip_within_bound():
    """Dense prefill scattered into an int8 pool dequantizes back within
    the documented per-element bound (absmax/127/2 per (pos, head) row)."""
    from repro.serving.kv_cache import write_prefill_pages

    rng = np.random.default_rng(11)
    cache = _cache(quant="int8")
    plen = 20
    slot, _ = cache.admit(plen)
    k = rng.normal(size=(2, plen, 2, 4)).astype(np.float32)
    v = rng.normal(size=(2, plen, 2, 4)).astype(np.float32)
    cache.swap_pages(write_prefill_pages(
        dict(cache.pages), jnp.asarray(k), jnp.asarray(v),
        cache.device_row(slot), jnp.asarray(plen, jnp.int32),
    ))
    got_k, got_v = cache.gather_dense(slot)
    for got, ref_arr in ((got_k, k), (got_v, v)):
        bound = np.abs(ref_arr).max(axis=-1, keepdims=True) / 127.0 / 2 + 1e-6
        assert (np.abs(got - ref_arr) <= bound).all()


def test_parked_page_survives_quantized_spill_reload_exactly():
    """int8 pool: spill + prefetch restores the quantized bytes AND scales
    bit-exactly (no requantization drift across tier moves)."""
    tiers = KVTierManager(host_pages=8)
    cache = _cache(tiers, quant="int8", num_pages=8, max_slots=2)
    toks = list(range(70, 95))
    slot, _ = cache.admit(len(toks), toks)
    cache.register_prefix(slot, toks, len(toks))
    chain = list(cache._slot_pages[slot][:3])
    _fill(cache, chain, seed=13)
    want = {p: cache._read_page(p) for p in chain}
    cache.release(slot)
    slot2, _ = cache.admit(41, list(range(300, 341)))  # forces spill
    cache.release(slot2)
    assert not cache.can_admit(len(toks), toks)
    cache.tick_tiers()
    slot3, cached = cache.admit(len(toks), toks)
    assert cached == 24
    for i, p in enumerate(cache._slot_pages[slot3][:3]):
        got = cache._read_page(p)
        for key, arr in want[chain[i]].items():
            np.testing.assert_array_equal(got[key], arr)


# ---------------------------------------------------------------------------
# tier manager edge cases
# ---------------------------------------------------------------------------


def test_pop_lru_skips_protected_and_pending():
    t = KVTierManager()
    for p in (3, 5, 7):
        t.park(p, bytes([p]) * 32)
    t.pending.add(3)
    assert t.pop_lru({5}) == (7, bytes([7]) * 32)
    assert t.pop_lru({5}) is None  # 3 pending, 5 protected
    t.tick()
    assert t.pop_lru({5}) == (3, bytes([3]) * 32)


def test_no_spill_targets_means_reclaim_drops_bytes():
    """Device-park-only config (host_pages=0, no store): reclaim frees the
    page without reading it back — wants_spill gates the device read."""
    tiers = KVTierManager()
    assert not tiers.wants_spill
    cache = _cache(tiers)
    toks = list(range(40, 65))
    slot, _ = cache.admit(len(toks), toks)
    cache.register_prefix(slot, toks, len(toks))
    cache.release(slot)
    assert cache.flush_tiers() == 3
    assert tiers.counters["spilled_pages"] == 0
    assert tiers.host_count == 0 and tiers.persisted_count == 0
    # the prefix is simply gone: next query is a clean miss
    assert cache.match_prefix(toks)[1] == 0
