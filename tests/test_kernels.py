"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # (b, sq, skv, h, kvh, d, causal)
    (1, 64, 64, 4, 4, 16, True),      # MHA
    (2, 128, 128, 4, 2, 32, True),    # GQA 2x
    (1, 128, 128, 8, 1, 64, True),    # MQA
    (2, 64, 256, 6, 3, 32, True),     # Sq < Skv (prefill continuation)
    (1, 128, 128, 4, 4, 16, False),   # bidirectional (encoder)
    (1, 256, 256, 2, 2, 128, True),   # MXU-width head_dim
]


@pytest.mark.parametrize("b,sq,skv,h,kvh,d,causal", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_vs_naive(b, sq, skv, h, kvh, d, causal, dtype, rng):
    q = rng.standard_normal((b, sq, h, d)).astype(dtype)
    k = rng.standard_normal((b, skv, kvh, d)).astype(dtype)
    v = rng.standard_normal((b, skv, kvh, d)).astype(dtype)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == np.float32 else 2e-2
    got_chunk = ops.flash_attention(q, k, v, causal=causal, impl="xla_chunked", block_kv=64)
    np.testing.assert_allclose(
        np.asarray(got_chunk, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)
    got_pallas = ops.flash_attention(
        q, k, v, causal=causal, impl="pallas", block_q=64, block_kv=64,
        interpret=True)
    np.testing.assert_allclose(
        np.asarray(got_pallas, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol)


def test_flash_attention_grad_matches(rng):
    """The checkpointed chunked path must be differentiable and match."""
    q = rng.standard_normal((1, 64, 2, 16)).astype(np.float32)
    k = rng.standard_normal((1, 64, 2, 16)).astype(np.float32)
    v = rng.standard_normal((1, 64, 2, 16)).astype(np.float32)

    def loss_naive(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v, causal=True) ** 2)

    def loss_chunk(q, k, v):
        return jnp.sum(
            ops.flash_attention(q, k, v, causal=True, impl="xla_chunked",
                                block_kv=32) ** 2)

    g1 = jax.grad(loss_naive)(q, k, v)
    g2 = jax.grad(loss_chunk)(q, k, v)
    np.testing.assert_allclose(g1, g2, atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    sq=st.sampled_from([32, 64]),
    h=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2]),
    d=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_flash_attention_property(sq, h, group, d, seed):
    """Row-stochastic invariant: attention output is a convex combination of
    V rows, so min(V) <= out <= max(V) per feature."""
    rng = np.random.default_rng(seed)
    kvh = max(1, h // group)
    h_eff = kvh * group
    q = rng.standard_normal((1, sq, h_eff, d)).astype(np.float32)
    k = rng.standard_normal((1, sq, kvh, d)).astype(np.float32)
    v = rng.standard_normal((1, sq, kvh, d)).astype(np.float32)
    out = np.asarray(ops.flash_attention(q, k, v, causal=True, impl="xla_chunked", block_kv=32))
    assert out.shape == q.shape
    assert np.isfinite(out).all()
    assert out.max() <= v.max() + 1e-4 and out.min() >= v.min() - 1e-4
    # naive equivalence on the same draw
    want = np.asarray(ref.flash_attention_ref(q, k, v, causal=True))
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (b, s, h, p, n, chunk)
    (1, 64, 2, 16, 16, 16),
    (2, 128, 4, 16, 32, 32),
    (1, 256, 8, 64, 128, 64),   # production-like dims
    (2, 64, 4, 32, 64, 64),     # chunk == s
]


def _ssd_inputs(rng, b, s, h, p, n, dtype=np.float32):
    x = rng.standard_normal((b, s, h, p)).astype(dtype)
    dt = (0.1 + 0.9 * rng.random((b, s, h))).astype(dtype)
    A = (-1.0 * rng.random((h,)) - 0.1).astype(np.float32)
    Bm = (rng.standard_normal((b, s, n)) / np.sqrt(n)).astype(dtype)
    Cm = (rng.standard_normal((b, s, n)) / np.sqrt(n)).astype(dtype)
    return x, dt, A, Bm, Cm


@pytest.mark.parametrize("b,s,h,p,n,chunk", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_ssd_vs_sequential(b, s, h, p, n, chunk, dtype, rng):
    x, dt, A, Bm, Cm = _ssd_inputs(rng, b, s, h, p, n, dtype)
    y_seq, st_seq = ref.ssd_sequential(x, dt, A, Bm, Cm)
    tol = 1e-4 if dtype == np.float32 else 5e-2
    y_chk, st_chk = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk, np.float32),
                               np.asarray(y_seq, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st_chk), np.asarray(st_seq),
                               atol=tol, rtol=tol)
    y_pal, st_pal = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, impl="pallas",
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal, np.float32),
                               np.asarray(y_seq, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(st_pal), np.asarray(st_seq),
                               atol=tol, rtol=tol)


def test_ssd_decode_matches_scan(rng):
    """Token-by-token decode must replay the full-sequence scan exactly."""
    b, s, h, p, n = 2, 32, 4, 16, 32
    x, dt, A, Bm, Cm = _ssd_inputs(rng, b, s, h, p, n)
    y_full, st_full = ref.ssd_sequential(x, dt, A, Bm, Cm)
    state = jnp.zeros((b, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y_t, state = ref.ssd_decode_step(state, x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t])
        ys.append(np.asarray(y_t))
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(st_full), atol=1e-4, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    s=st.sampled_from([32, 64, 128]),
    chunk=st.sampled_from([16, 32]),
    h=st.sampled_from([1, 4]),
    seed=st.integers(0, 2**16),
)
def test_ssd_chunk_invariance(s, chunk, h, seed):
    """The chunk size is a pure performance knob — results must not change."""
    rng = np.random.default_rng(seed)
    x, dt, A, Bm, Cm = _ssd_inputs(rng, 1, s, h, 8, 16)
    y1, st1 = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    y2, st2 = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=2e-4, rtol=2e-4)
    assert np.isfinite(np.asarray(y1)).all()


def test_ssd_init_state_carry(rng):
    """Splitting a sequence in two with a carried state == one long scan."""
    b, s, h, p, n = 1, 64, 2, 8, 16
    x, dt, A, Bm, Cm = _ssd_inputs(rng, b, s, h, p, n)
    y_full, st_full = ref.ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    half = s // 2
    y1, st1 = ref.ssd_chunked(x[:, :half], dt[:, :half], A, Bm[:, :half], Cm[:, :half], chunk=16)
    y2, st2 = ref.ssd_chunked(x[:, half:], dt[:, half:], A, Bm[:, half:], Cm[:, half:],
                              init_state=st1, chunk=16)
    np.testing.assert_allclose(np.concatenate([np.asarray(y1), np.asarray(y2)], 1),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full), atol=1e-4, rtol=1e-4)
