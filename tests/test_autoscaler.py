"""Units for the lag/occupancy autoscaler (``core/autoscaler.py``) with a
fake clock: scale-up is immediate, scale-down waits out the grace window,
an oscillating lag trace cannot thrash replicas, and the serving variant
folds engine occupancy gauges into the decision."""

import pytest

from repro.core.autoscaler import (
    Autoscaler,
    AutoscalerConfig,
    ServingAutoscaler,
)
from repro.core.bus import TopicBus
from repro.core.events import EventLog

TOPIC, GROUP = "work", "workers"


@pytest.fixture
def bus(tmp_path):
    return TopicBus(tmp_path / "bus")


def _set_lag(bus, n: int) -> None:
    """Make the consumer group exactly n messages behind."""
    end = bus.end_offset(TOPIC)
    for _ in range(n - (end - bus.committed(TOPIC, GROUP))):
        bus.publish(TOPIC, {"x": 1})
    bus.commit(TOPIC, GROUP, bus.end_offset(TOPIC) - n)


def _scaler(bus, clock, *, cls=Autoscaler, current=1, events=None, **cfg_kw):
    cfg = AutoscalerConfig(min_replicas=1, max_replicas=4,
                           target_lag_per_replica=2.0,
                           scale_down_grace_s=5.0, **cfg_kw)
    return cls(bus, TOPIC, GROUP, cfg, events=events, current=current,
               clock=lambda: clock["t"])


def test_scale_up_immediate_scale_down_after_grace(bus):
    clock = {"t": 0.0}
    sc = _scaler(bus, clock)

    _set_lag(bus, 8)
    assert sc.observe() == (4, True)  # ceil(8/2) = 4, no hysteresis upward
    assert sc.current == 4

    _set_lag(bus, 0)
    clock["t"] = 1.0
    assert sc.observe() == (4, False)  # wants 1, but grace not elapsed
    clock["t"] = 4.0
    assert sc.observe() == (4, False)
    clock["t"] = 6.0
    assert sc.observe() == (1, True)  # 6s since last scale event >= 5s grace
    assert sc.current == 1


def test_clamping(bus):
    clock = {"t": 0.0}
    sc = _scaler(bus, clock)
    _set_lag(bus, 1000)
    assert sc.desired_replicas() == 4  # max
    _set_lag(bus, 0)
    assert sc.desired_replicas() == 1  # min


def test_no_thrash_on_oscillating_lag(bus):
    """Lag alternating high/empty every second: replicas ride at the high
    watermark — every 0-lag poll inside the grace window is a no-op, and
    each high-lag poll resets the equal-state clock."""
    clock = {"t": 0.0}
    events = EventLog(bus, workflow="scaler-test")
    sc = _scaler(bus, clock, events=events)
    changes = []
    for i in range(10):
        clock["t"] = float(i)
        _set_lag(bus, 8 if i % 2 == 0 else 0)
        desired, changed = sc.observe()
        if changed:
            changes.append((i, desired))
    assert changes == [(0, 4)], f"thrash: {changes}"
    assert sc.current == 4
    hist = events.history("autoscale")
    assert len(hist) == 1 and (hist[0]["old"], hist[0]["new"]) == (1, 4)


def test_scale_down_grace_measured_from_last_event(bus):
    """A scale-up inside the wanted-lower period restarts the grace."""
    clock = {"t": 0.0}
    sc = _scaler(bus, clock)
    _set_lag(bus, 8)
    sc.observe()  # -> 4 at t=0
    _set_lag(bus, 0)
    clock["t"] = 4.0
    assert sc.observe() == (4, False)
    _set_lag(bus, 8)
    clock["t"] = 4.5
    assert sc.observe() == (4, False)  # equal: resets the grace clock
    _set_lag(bus, 0)
    clock["t"] = 8.0
    assert sc.observe() == (4, False)  # only 3.5s since the reset
    clock["t"] = 10.0
    assert sc.observe() == (1, True)


def test_serving_autoscaler_occupancy_bump(bus):
    """Lag alone says 1 replica, but saturated slots with pending lag mean
    the fleet is slot-bound: ask for one more than current."""
    clock = {"t": 0.0}
    gauges = {"slot_occupancy_mean": 0.0}
    sc = _scaler(bus, clock, cls=ServingAutoscaler, current=2,
                 target_occupancy=0.85)
    sc.gauges = lambda: gauges

    _set_lag(bus, 1)  # ceil(1/2) -> 1 replica by lag alone
    assert sc.desired_replicas() == 1
    gauges["slot_occupancy_mean"] = 0.95
    assert sc.desired_replicas() == 3  # current + 1, occupancy-driven

    _set_lag(bus, 0)  # saturated but nothing waiting: no bump
    assert sc.desired_replicas() == 1

    # the bump never exceeds max_replicas
    sc.current = 4
    _set_lag(bus, 1)
    assert sc.desired_replicas() == 4


def test_serving_autoscaler_gauge_term_optional(bus):
    clock = {"t": 0.0}
    sc = _scaler(bus, clock, cls=ServingAutoscaler, current=2)  # no target
    sc.gauges = lambda: {"slot_occupancy_mean": 1.0}
    _set_lag(bus, 1)
    assert sc.desired_replicas() == 1  # target_occupancy=None disables it
