"""Protocol-conformance suite: every engine behind one contract.

Every test below is parameterized over ``GenerationEngine`` (lockstep,
micro-batches chunked into steps), ``ContinuousBatchingEngine`` (paged)
and ``SSMEngine`` (per-slot recurrent state, Mamba2) via a single fixture
— the point of the serving API redesign is that the engines are
indistinguishable through ``submit``/``step``/``cancel``: streaming delta
ordering, cancellation mid-decode, stop-token termination, typed
rejection surfacing, seeded reproducibility, and abort.
"""

import jax
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serving import (
    ContinuousBatchingEngine,
    EngineCore,
    FinishReason,
    GenerationEngine,
    Request,
    SamplingParams,
    SSMEngine,
)


@pytest.fixture(scope="module")
def smollm():
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, params


@pytest.fixture(scope="module")
def mamba2():
    cfg = reduced(ARCHS["mamba2-1.3b"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    return cfg, params


@pytest.fixture(params=["paged", "lockstep", "ssm"])
def make_engine(request, smollm, mamba2):
    kind = request.param
    cfg, params = mamba2 if kind == "ssm" else smollm

    def factory(**kw):
        if kind == "paged":
            return ContinuousBatchingEngine(
                cfg, params, max_len=kw.pop("max_len", 64),
                max_slots=kw.pop("slots", 3), page_size=8, **kw)
        if kind == "ssm":
            return SSMEngine(cfg, params, max_len=kw.pop("max_len", 64),
                             max_slots=kw.pop("slots", 3), **kw)
        return GenerationEngine(cfg, params, max_len=kw.pop("max_len", 64),
                                max_batch=kw.pop("slots", 3), **kw)

    factory.kind = kind
    return factory


def drain(engine):
    events = []
    while not engine.idle:
        events.append(engine.step())
    return events


def test_implements_protocol(make_engine):
    assert isinstance(make_engine(), EngineCore)


def test_streaming_delta_ordering(make_engine):
    """Token deltas stream with consecutive indices, at least one delta
    arrives in an EARLIER step than the finish, and the delta stream
    reassembles exactly into the final result."""
    eng = make_engine()
    ha = eng.submit(Request("a", [1, 2, 3], max_new_tokens=5))
    hb = eng.submit(Request("b", [4, 5, 6, 7], max_new_tokens=3))
    step_batches = drain(eng)

    for h in (ha, hb):
        toks, finish_step, token_steps = [], None, []
        for sno, batch in enumerate(step_batches):
            for ev in batch:
                if ev.uid != h.uid:
                    continue
                if ev.kind == "token":
                    assert finish_step is None, "token after finish"
                    assert ev.index == len(toks)  # consecutive from 0
                    toks.append(ev.token)
                    token_steps.append(sno)
                elif ev.kind == "finish":
                    assert finish_step is None, "duplicate finish"
                    finish_step = sno
                    assert ev.finish_reason == FinishReason.LENGTH
        assert toks == h.tokens == h.result().tokens
        assert finish_step is not None
        # streaming: the first delta is observable before completion
        assert token_steps[0] < finish_step
        assert h.result().finish_reason == FinishReason.LENGTH
        assert h.ttft is not None and h.ttft > 0
        assert len(h.itl) == len(toks) - 1


def test_new_tokens_drains_incrementally(make_engine):
    eng = make_engine()
    h = eng.submit(Request("inc", [1, 2, 3], max_new_tokens=4))
    seen = []
    while not eng.idle:
        eng.step()
        seen.extend(h.new_tokens())
    assert seen == h.tokens and h.new_tokens() == []


def test_cancellation_mid_decode(make_engine):
    """Cancel after a few streamed tokens: typed ``cancelled`` finish, the
    already-streamed tokens survive on the handle, the engine keeps serving
    other requests, and (paged) every page returns to the pool."""
    eng = make_engine()
    victim = eng.submit(Request("victim", [1, 2, 3], max_new_tokens=40))
    other = eng.submit(Request("other", [4, 5, 6], max_new_tokens=6))
    while len(victim.tokens) < 2:
        eng.step()
    n = len(victim.tokens)
    assert victim.cancel() is True
    assert victim.done and victim.finish_reason == FinishReason.CANCELLED
    assert len(victim.tokens) == n  # streamed deltas are kept
    assert victim.cancel() is False  # idempotent: already finished
    events = [e for batch in drain(eng) for e in batch]
    assert any(e.uid == "victim" and e.kind == "finish" and
               e.finish_reason == FinishReason.CANCELLED for e in events)
    assert other.finish_reason == FinishReason.LENGTH
    assert len(other.tokens) == 6
    if hasattr(eng, "cache"):
        assert eng.cache.pool.available == eng.cache.num_pages - 1


def test_cancel_while_queued(make_engine):
    """A request cancelled before it was ever admitted finishes
    ``cancelled`` with zero tokens and never occupies the engine."""
    eng = make_engine()
    h = eng.submit(Request("q", [1, 2, 3], max_new_tokens=8))
    assert eng.cancel("q") is True
    assert h.finish_reason == FinishReason.CANCELLED and h.tokens == []
    events = [e for batch in drain(eng) for e in batch]
    assert [e.kind for e in events if e.uid == "q"] == ["finish"]
    assert eng.idle
    assert eng.cancel("nonexistent") is False


def test_stop_token_termination(make_engine):
    """A stop token terminates the stream at its first occurrence with
    ``FinishReason.STOP``; the stop token itself is not emitted."""
    eng = make_engine()
    base = eng.generate([Request("learn", [9, 8, 7], max_new_tokens=6)])[0]
    stop = base.tokens[-1]
    cut = base.tokens.index(stop)  # first occurrence wins
    h = eng.submit(Request("stopme", [9, 8, 7], sampling=SamplingParams(
        max_new_tokens=6, stop_tokens=(stop,))))
    drain(eng)
    assert h.finish_reason == FinishReason.STOP
    assert h.tokens == base.tokens[:cut]
    assert stop not in h.tokens


def test_rejection_surfaced_as_typed_finish(make_engine):
    """Invalid requests come back as handles already finished ``rejected``
    (submit never raises), the engine stays idle and keeps serving."""
    eng = make_engine()
    bad = [
        Request("empty", [], max_new_tokens=4),
        Request("zeronew", [1, 2], max_new_tokens=0),
        Request("toolong", list(range(1, 100)), max_new_tokens=8),
        Request("badtemp", [1, 2], sampling=SamplingParams(
            temperature=-1.0, max_new_tokens=4)),
        Request("badtopp", [1, 2], sampling=SamplingParams(
            top_p=0.0, max_new_tokens=4)),
    ]
    for r in bad:
        h = eng.submit(r)
        assert h.done and h.finish_reason == FinishReason.REJECTED, r.uid
        assert h.error
        assert h.result().finish_reason == FinishReason.REJECTED
    assert eng.idle  # rejected requests never queue
    assert eng.stats["rejected"] == len(bad)
    assert [u for u, _ in eng.drain_rejections()] == [r.uid for r in bad]
    # the deprecated raise-on-reject wrapper still raises
    with pytest.raises(ValueError, match="empty prompt"):
        eng.enqueue(Request("empty2", [], max_new_tokens=4))
    ok = eng.submit(Request("ok", [1, 2, 3], max_new_tokens=3))
    drain(eng)
    assert ok.finish_reason == FinishReason.LENGTH and len(ok.tokens) == 3


def test_duplicate_uid_rejected(make_engine):
    eng = make_engine()
    first = eng.submit(Request("dup", [1, 2, 3], max_new_tokens=8))
    again = eng.submit(Request("dup", [1, 2, 3], max_new_tokens=8))
    assert again.finish_reason == FinishReason.REJECTED
    assert "uid" in again.error
    drain(eng)
    assert first.finish_reason == FinishReason.LENGTH
    # after the first finished, the uid is free again
    fresh = eng.submit(Request("dup", [1, 2, 3], max_new_tokens=2))
    drain(eng)
    assert fresh.finish_reason == FinishReason.LENGTH


def test_seeded_sampling_batch_independent(make_engine):
    """A seeded request reproduces the same tokens regardless of batch
    composition — the RNG is keyed off (seed, token_index), never engine
    step counters."""
    eng = make_engine()
    sp = SamplingParams(temperature=1.0, seed=123, max_new_tokens=6,
                        top_k=50, top_p=0.9)
    alone = eng.generate([Request("s1", [3, 4, 5], sampling=sp)])[0]
    batched = eng.generate([
        Request("s2", [3, 4, 5], sampling=sp),
        Request("noise", [7, 7, 2], max_new_tokens=6,
                sampling=SamplingParams(temperature=1.0, seed=9,
                                        max_new_tokens=6)),
    ])[0]
    assert alone.tokens == batched.tokens


def test_abort_all(make_engine):
    eng = make_engine()
    hs = [eng.submit(Request(f"x{i}", [1, 2, 3 + i], max_new_tokens=40))
          for i in range(4)]
    eng.step()
    assert eng.abort_all() == 4
    drain(eng)
    assert all(h.finish_reason == FinishReason.CANCELLED for h in hs)
    assert eng.idle
    if hasattr(eng, "cache"):
        assert eng.cache.pool.available == eng.cache.num_pages - 1


def test_generate_wrapper_orders_results(make_engine):
    """The deprecated sync wrapper drains through the protocol and returns
    Results in submission order with typed finish reasons."""
    eng = make_engine()
    reqs = [Request(f"g{i}", [1 + i, 2, 3], max_new_tokens=2 + i)
            for i in range(4)]
    out = eng.generate(reqs)
    assert [r.uid for r in out] == [r.uid for r in reqs]
    for r, o in zip(reqs, out):
        assert len(o.tokens) == r.max_new_tokens
        assert o.finish_reason == FinishReason.LENGTH


def test_lockstep_batch_never_exceeds_max_len(smollm):
    """Lockstep-only: two requests that are individually valid but whose
    padded batch would decode past ``max_len`` (long prompt + long
    max_new) must be split into separate micro-batches — otherwise the
    overflow positions silently clobber the last cache slot."""
    cfg, params = smollm
    eng = GenerationEngine(cfg, params, max_len=48, max_batch=4)
    long_prompt = list(range(1, 31))
    solo = eng.generate([Request("solo", [4, 5, 6, 7], max_new_tokens=40)])[0]
    ha = eng.submit(Request("a", long_prompt, max_new_tokens=8))   # 30+8 ok
    hb = eng.submit(Request("b", [4, 5, 6, 7], max_new_tokens=40))  # 4+40 ok
    while not eng.idle:                       # together: 30+40 > 48 -> split
        eng.step()
    assert ha.finish_reason == FinishReason.LENGTH and len(ha.tokens) == 8
    assert hb.finish_reason == FinishReason.LENGTH
    assert hb.tokens == solo.tokens  # unclobbered: identical to solo run


def test_preempted_finish_reason(smollm):
    """Paged-only: under pool pressure with ``max_preemptions=0``, an
    evicted request finishes ``preempted`` instead of silently requeueing
    forever; survivors still finish exactly."""
    cfg, params = smollm
    eng = ContinuousBatchingEngine(cfg, params, max_len=40, max_slots=2,
                                   page_size=8, num_pages=6,
                                   max_preemptions=0)
    hs = [eng.submit(Request(f"p{i}", [100 + i] + list(range(2, 15)),
                             max_new_tokens=10))
          for i in range(3)]
    drain(eng)
    reasons = [h.finish_reason for h in hs]
    assert FinishReason.PREEMPTED in reasons
    assert FinishReason.LENGTH in reasons
    assert eng.stats["preemptions"] > 0
    preempted = next(h for h in hs if h.finish_reason == FinishReason.PREEMPTED)
    assert "preempted" in preempted.error
    assert (eng.cache.pool.available + eng.cache.parked_count
            == eng.cache.num_pages - 1)


def test_preemption_never_reemits_deltas(smollm):
    """Paged-only: with requeueing allowed, a preempted request's stream is
    seamless — indices stay consecutive, nothing is emitted twice, and the
    regenerated tokens extend (not replace) the streamed prefix."""
    cfg, params = smollm
    eng = ContinuousBatchingEngine(cfg, params, max_len=40, max_slots=2,
                                   page_size=8, num_pages=6)
    hs = [eng.submit(Request(f"p{i}", [100 + i] + list(range(2, 15)),
                             max_new_tokens=10))
          for i in range(3)]
    seen: dict[str, list[int]] = {h.uid: [] for h in hs}
    preempts = 0
    while not eng.idle:
        for ev in eng.step():
            if ev.kind == "token":
                assert ev.index == len(seen[ev.uid])  # no gap, no repeat
                seen[ev.uid].append(ev.token)
            elif ev.kind == "preempted":
                preempts += 1
    assert preempts > 0
    for h in hs:
        assert h.finish_reason == FinishReason.LENGTH
        assert seen[h.uid] == h.tokens and len(h.tokens) == 10
