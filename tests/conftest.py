import sys
import types

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — tests run with the real (1) CPU
# device; only launch/dryrun.py forces 512 placeholder devices.

# ---------------------------------------------------------------------------
# hypothesis is optional: on minimal installs the property tests skip instead
# of breaking collection of every module that imports it.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on installed extras
    def _given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def _settings(*_args, **_kwargs):
        return lambda fn: fn

    def _strategy(*_args, **_kwargs):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _strategy  # any strategy constructor

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__getattr__ = lambda name: _strategy

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)
