import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose — tests run with the real (1) CPU
# device; only launch/dryrun.py forces 512 placeholder devices.


@pytest.fixture
def rng():
    return np.random.default_rng(0)
