"""C4/C5: TopicBus (Kafka analogue) + ArtifactStore (PV/PVC analogue)."""

import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ArtifactStore, TopicBus
from repro.core.bus import Consumer
from repro.core.registry import ServiceRegistry


# ---------------------------------------------------------------------------
# bus
# ---------------------------------------------------------------------------


def test_bus_offsets_monotonic(tmp_path):
    bus = TopicBus(tmp_path)
    offs = [bus.publish("t", {"i": i}) for i in range(10)]
    assert offs == list(range(10))
    msgs = bus.read("t")
    assert [m.value["i"] for m in msgs] == list(range(10))


def test_bus_consumer_groups_independent(tmp_path):
    bus = TopicBus(tmp_path)
    for i in range(5):
        bus.publish("t", i)
    a = bus.consume("t", "groupA")
    assert len(a) == 5
    bus.commit("t", "groupA", 5)
    assert bus.consume("t", "groupA") == []
    assert len(bus.consume("t", "groupB")) == 5  # replay for a new group
    assert bus.lag("t", "groupA") == 0 and bus.lag("t", "groupB") == 5


def test_bus_at_least_once_redelivery(tmp_path):
    bus = TopicBus(tmp_path)
    for i in range(3):
        bus.publish("t", i)
    seen = []

    def flaky(msg):
        seen.append(msg.value)
        if msg.value == 1 and seen.count(1) == 1:
            raise RuntimeError("crash mid-processing")

    c = Consumer(bus, "t", "g")
    with pytest.raises(RuntimeError):
        c.poll(flaky)
    c.poll(flaky)  # redelivers 1 then 2
    assert seen == [0, 1, 1, 2]  # at-least-once: 1 seen twice


def test_bus_concurrent_producers(tmp_path):
    bus = TopicBus(tmp_path)

    def produce(k):
        for i in range(50):
            bus.publish("t", {"k": k, "i": i}, key=str(k))

    threads = [threading.Thread(target=produce, args=(k,)) for k in range(4)]
    [t.start() for t in threads]
    [t.join() for t in threads]
    msgs = bus.read("t")
    assert len(msgs) == 200
    assert [m.offset for m in msgs] == list(range(200))
    # per-producer order preserved
    for k in range(4):
        seq = [m.value["i"] for m in msgs if m.value["k"] == k]
        assert seq == sorted(seq)


def test_registry_resolve_latest(tmp_path):
    bus = TopicBus(tmp_path)
    reg = ServiceRegistry(bus)
    reg.register("svc", "pod://a", "podA")
    reg.register("svc", "pod://b", "podB")
    ep = reg.resolve("svc")
    assert ep.address == "pod://b"
    reg.deregister("svc")
    assert reg.resolve("svc") is None


# ---------------------------------------------------------------------------
# storage
# ---------------------------------------------------------------------------


def test_store_roundtrip_kinds(tmp_path):
    store = ArtifactStore(tmp_path)
    cases = [b"raw-bytes", {"a": [1, 2, {"b": 3}]}, np.arange(12).reshape(3, 4),
             ("tuple", 1, 2.5)]
    for obj in cases:
        ref = store.put(obj)
        got = store.get(ref)
        if isinstance(obj, np.ndarray):
            np.testing.assert_array_equal(got, obj)
        elif isinstance(obj, tuple):
            assert tuple(got) == obj
        else:
            assert got == obj


def test_store_content_addressed_dedup(tmp_path):
    store = ArtifactStore(tmp_path)
    r1 = store.put({"x": 1}, name="a")
    r2 = store.put({"x": 1}, name="b")
    assert r1.split("/")[0] == r2.split("/")[0]  # same digest


def test_store_integrity_check(tmp_path):
    store = ArtifactStore(tmp_path)
    ref = store.put(b"payload")
    digest = ref.split("://")[1].split("/")[0]
    f = tmp_path / "shared" / "objects" / digest / "data"
    f.write_bytes(b"tampered")
    with pytest.raises(IOError, match="integrity"):
        store.get(ref)


def test_store_tiers_and_claims(tmp_path):
    store = ArtifactStore(tmp_path, node_id="nodeX")
    rn = store.put(b"local", tier="node")
    rs = store.put(b"shared", tier="shared")
    assert rn.startswith("node://") and rs.startswith("shared://")
    assert store.get(rn) == b"local"
    claim = store.claim("ckpt", tier="shared", capacity_bytes=1 << 20)
    assert claim.path.exists()
    (claim.path / "f.bin").write_bytes(b"z" * 100)
    assert claim.used_bytes() == 100
    store.release(claim)
    assert not claim.path.exists()


def test_store_tree_roundtrip(tmp_path):
    import jax
    store = ArtifactStore(tmp_path)
    tree = {"a": np.arange(5), "b": {"c": np.ones((2, 2))}}
    ref = store.put_tree(tree)
    meta = store.get(ref)
    leaves = [store.get(r) for r in meta["leaves"]]
    np.testing.assert_array_equal(leaves[0], tree["a"])
    np.testing.assert_array_equal(leaves[1], tree["b"]["c"])


@settings(max_examples=25, deadline=None)
@given(st.binary(min_size=0, max_size=512))
def test_store_bytes_roundtrip_property(blob):
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        store = ArtifactStore(d)
        assert store.get(store.put(blob)) == blob
