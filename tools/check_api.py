"""Serving API surface checker: fail CI on unreviewed drift.

Renders every public name exported by ``repro.serving`` — classes with
their ``__init__`` and public-method signatures, functions, enums with
their members — into a canonical text form and compares it against the
reviewed snapshot in ``tools/serving_api.txt``. Any mismatch (a renamed
method, a changed default, a dropped export) fails with a diff, so the
public serving surface can only change together with an intentional
snapshot update in the same PR.

Check:  PYTHONPATH=src python tools/check_api.py
Update: PYTHONPATH=src python tools/check_api.py --update
"""

from __future__ import annotations

import argparse
import difflib
import enum
import inspect
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SNAPSHOT = ROOT / "tools" / "serving_api.txt"
MODULE = "repro.serving"


def _sig(fn) -> str:
    try:
        return str(inspect.signature(fn))
    except (ValueError, TypeError):
        return "(signature unavailable)"


def _class_lines(name: str, cls: type) -> list[str]:
    if issubclass(cls, enum.Enum):
        members = ", ".join(f"{m.name}={m.value!r}" for m in cls)
        return [f"enum {name}: {members}"]
    lines = [f"class {name}{_sig(cls.__init__)}"]
    seen = set()
    for attr in sorted(dir(cls)):
        if attr.startswith("_") or attr in seen:
            continue
        seen.add(attr)
        member = inspect.getattr_static(cls, attr)
        if isinstance(member, property):
            lines.append(f"  {name}.{attr} [property]")
        elif isinstance(member, staticmethod | classmethod):
            lines.append(f"  {name}.{attr}{_sig(member.__func__)}")
        elif inspect.isfunction(member):
            lines.append(f"  {name}.{attr}{_sig(member)}")
    return lines


def render() -> str:
    mod = __import__(MODULE, fromlist=["__all__"])
    lines = [f"# Public serving API surface of {MODULE} (tools/check_api.py)"]
    for name in sorted(mod.__all__):
        obj = getattr(mod, name)
        if inspect.isclass(obj):
            lines.extend(_class_lines(name, obj))
        elif callable(obj):
            lines.append(f"def {name}{_sig(obj)}")
        else:
            lines.append(f"{name} = {obj!r}")
    return "\n".join(lines) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite the snapshot from the current surface")
    args = ap.parse_args()

    current = render()
    if args.update:
        SNAPSHOT.write_text(current)
        print(f"check_api: snapshot updated "
              f"({len(current.splitlines())} lines)")
        return 0
    if not SNAPSHOT.exists():
        print(f"FAIL check_api: missing snapshot {SNAPSHOT}; "
              f"run with --update and review the diff")
        return 1
    want = SNAPSHOT.read_text()
    if current == want:
        print(f"check_api: serving surface matches snapshot "
              f"({len(current.splitlines())} lines)")
        return 0
    diff = difflib.unified_diff(
        want.splitlines(keepends=True), current.splitlines(keepends=True),
        fromfile="tools/serving_api.txt (reviewed)",
        tofile="repro.serving (current)",
    )
    sys.stdout.writelines(diff)
    print("\nFAIL check_api: public serving surface drifted. If the change "
          "is intentional, re-run with --update and commit the snapshot.")
    return 1


if __name__ == "__main__":
    sys.exit(main())
