"""Docs snippet checker: every ```python fence in README.md and docs/*.md
must at least compile, and its import statements must resolve.

Full execution is out of scope (snippets may train models or spin up
workers); compiling catches syntax rot and running just the imports
catches renamed/moved modules — the most common way docs go stale.

Run: PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def snippets(path: Path):
    for i, block in enumerate(FENCE.findall(path.read_text())):
        yield f"{path.relative_to(ROOT)}[{i}]", block


def check(name: str, code: str) -> list[str]:
    errors = []
    try:
        tree = ast.parse(code)
    except SyntaxError as e:
        return [f"{name}: syntax error: {e}"]
    imports = [
        n for n in tree.body if isinstance(n, (ast.Import, ast.ImportFrom))
    ]
    for node in imports:
        src = ast.unparse(node)
        try:
            exec(compile(ast.Module([node], []), name, "exec"), {})
        except Exception as e:  # noqa: BLE001 - report every failure kind
            errors.append(f"{name}: `{src}` failed: {type(e).__name__}: {e}")
    return errors


def main() -> int:
    files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    errors, checked = [], 0
    for f in files:
        if not f.exists():
            continue
        for name, code in snippets(f):
            checked += 1
            errors.extend(check(name, code))
    for e in errors:
        print(f"FAIL {e}")
    print(f"check_docs: {checked} snippet(s), {len(errors)} error(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
