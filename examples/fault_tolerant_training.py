"""End-to-end driver: train a (reduced) smollm-360m for a few hundred steps
under the Jup2Kub runtime with chaos injection — the train pod is killed
twice mid-run and must recover from checkpoints, finish, and improve.

This is the assignment's "end-to-end driver" example; the full-size version
of the same pipeline is `python -m repro.launch.train --arch <id> --steps N`.

Run: PYTHONPATH=src python examples/fault_tolerant_training.py
"""

import subprocess
import sys


def main():
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "smollm-360m", "--reduced",
        "--steps", "200", "--batch", "16", "--seq-len", "64",
        "--ckpt-every", "20", "--chaos",
        "--workdir", "experiments/ft_training",
    ]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
