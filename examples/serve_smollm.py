"""Serving example: batched requests through the bus with autoscaling.

Requests flow through the Kafka-analogue topic, engine workers batch and
generate, the HPA-analogue scales workers with consumer lag.

Run: PYTHONPATH=src python examples/serve_smollm.py
"""

import subprocess
import sys


def main():
    cmd = [
        sys.executable, "-m", "repro.launch.serve",
        "--arch", "smollm-360m", "--reduced",
        "--requests", "32", "--max-new", "8", "--max-batch", "4",
        "--workdir", "experiments/serving",
    ]
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
