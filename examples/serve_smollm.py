"""Streaming serving example: submit -> RequestHandle -> watch TTFT live.

Drives the continuous-batching engine through the request-lifecycle API
(``repro.serving.api``): requests are submitted with per-request
SamplingParams, the engine is stepped explicitly, and tokens are printed AS
THEY ARRIVE — the first token of each request is flagged with its measured
time-to-first-token, which is the whole point of a streaming serving API
(the old example only saw tokens after a request fully completed). One
request is cancelled mid-stream to show the typed lifecycle.

For the bus-driven multi-worker driver with autoscaling, see
``python -m repro.launch.serve``.

Run: PYTHONPATH=src python examples/serve_smollm.py
"""

import time

import jax

from repro.configs import ARCHS, reduced
from repro.models import build_model
from repro.serving import (
    ContinuousBatchingEngine,
    Request,
    SamplingParams,
)


def main():
    cfg = reduced(ARCHS["smollm-360m"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ContinuousBatchingEngine(cfg, params, max_len=128, max_slots=4)

    handles = {
        h.uid: h
        for h in (
            engine.submit(Request("greedy", prompt=[1, 2, 3],
                                  max_new_tokens=10)),
            engine.submit(Request("sampled", prompt=[5, 6, 7, 8],
                                  sampling=SamplingParams(
                                      temperature=0.8, top_k=50, top_p=0.9,
                                      seed=42, max_new_tokens=10))),
            engine.submit(Request("doomed", prompt=[9, 10, 11],
                                  max_new_tokens=64)),
        )
    }
    print(f"submitted {len(handles)} requests; streaming:\n")

    t0 = time.perf_counter()
    while not engine.idle:
        for ev in engine.step():
            h = handles[ev.uid]
            if ev.kind == "token":
                if ev.index == 0:  # first token: TTFT is now measurable
                    print(f"[{ev.uid:>7}] FIRST token {ev.token:4d} "
                          f"(ttft {h.ttft * 1e3:.1f} ms)")
                else:
                    print(f"[{ev.uid:>7}] token {ev.token:4d} (#{ev.index})")
            elif ev.kind == "finish":
                print(f"[{ev.uid:>7}] finished: {ev.finish_reason.value}")
        # show cancellation mid-decode: stop `doomed` once it has streamed
        # a few tokens (its 64-token budget would otherwise dominate)
        doomed = handles["doomed"]
        if not doomed.done and len(doomed.tokens) >= 3:
            print(f"[ doomed] cancelling after {len(doomed.tokens)} tokens")
            doomed.cancel()
    wall = time.perf_counter() - t0

    print(f"\nall requests settled in {wall * 1e3:.0f} ms:")
    for uid, h in handles.items():
        r = h.result()
        itl = (f", itl_mean {sum(r.itl) / len(r.itl) * 1e3:.1f} ms"
               if r.itl else "")
        print(f"  {uid:>7}: {r.finish_reason.value:<9} tokens={r.tokens} "
              f"ttft {r.ttft * 1e3:.1f} ms{itl}")


if __name__ == "__main__":
    main()
